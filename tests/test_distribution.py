"""Partitioning rules + distributed retrieval (subprocess with host devices,
so the main pytest process keeps its single CPU device)."""
import subprocess
import sys
import textwrap

import pytest

from repro.common import partitioning as pt
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model_api import Model


def test_spec_divisibility_guard_and_head_fallback():
    mesh = make_host_mesh(1, 1)   # sizes 1: everything trivially shards
    rules = pt.standard_rules(mesh)
    spec = rules.spec_for(("embed", "heads", "head_dim"), (100, 40, 128))
    assert len(spec) == 3


def test_param_specs_shardable_on_production_shape():
    """Every param of every arch must yield a valid PartitionSpec under the
    production axis sizes (divisibility checked arithmetically, no devices)."""
    import numpy as np

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    rules = pt.MeshRules(mesh=FakeMesh(), rules={
        "layers": None, "vocab": "model", "embed": None, "heads": "model",
        "kv_heads": "model", "head_dim": None, "ff": "model",
        "experts": "model", "expert_cap": "data", "batch": "data",
        "seq": None, "state": "model", "bank": ("data", "model"),
        "topk": None,
    })
    from repro.common.module import is_spec
    import jax
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        specs = Model(cfg).param_specs()
        leaves = [s for s in jax.tree.leaves(
            specs, is_leaf=is_spec) if is_spec(s)]
        for s in leaves:
            p = rules.spec_for(s.axes, s.shape)
            for dim, phys in zip(s.shape, tuple(p) + (None,) * len(s.shape)):
                if phys is None:
                    continue
                size = np.prod([rules.mesh.shape[a] for a in
                                (phys if isinstance(phys, tuple) else (phys,))])
                assert dim % size == 0, (arch, s.shape, p)


# (sharded_topk parity moved to tests/test_distributed_parity.py, which
# also covers the k > shard_rows edge and the Pallas-kernel comparison)


@pytest.mark.slow
def test_dryrun_smoke_subprocess():
    """A miniature dry-run on 8 host devices: lower+compile one reduced arch
    per family on a (4, 2) mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax
        from repro.configs import get_config
        from repro.launch.sharding import build_step
        from repro.models.config import INPUT_SHAPES
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for arch in ("internlm2-1.8b", "mamba2-2.7b", "phi3.5-moe-42b-a6.6b"):
            cfg = get_config(arch).reduced()
            for sh_name, bat, sq in (("train_4k", 8, 64), ("decode_32k", 8, 64)):
                shape = dataclasses.replace(
                    INPUT_SHAPES[sh_name], global_batch=bat, seq_len=sq)
                with mesh:
                    b = build_step(cfg, shape, mesh)
                    c = b.fn.lower(*b.args).compile()
                    assert c.cost_analysis() is not None
        print("DRYRUN_SMOKE_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "DRYRUN_SMOKE_OK" in out.stdout, out.stderr[-2000:]
