"""Deterministic hashing tokenizer.

Word-level with punctuation splitting; token ids are FNV-1a hashes into the
vocab range, so tokenization is stable across runs/processes with no vocab
file (the offline container has none).  A reversible side-table supports
decode for text that has been seen by this instance (enough for tests,
examples and the synthetic benchmark; token *counting* — the paper's Table 2
metric — needs no decoding at all).
"""
from __future__ import annotations

import re
from typing import Iterable, List

from repro.common.utils import stable_hash

_SPLIT = re.compile(r"\w+|[^\w\s]")

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
UNK_ID = 3
N_SPECIAL = 8


class HashTokenizer:
    def __init__(self, vocab_size: int = 32768):
        assert vocab_size > N_SPECIAL
        self.vocab_size = vocab_size
        self._reverse: dict[int, str] = {}

    # -- core ------------------------------------------------------------
    def word_id(self, word: str) -> int:
        wid = N_SPECIAL + stable_hash(word.lower(), self.vocab_size - N_SPECIAL)
        self._reverse.setdefault(wid, word.lower())
        return wid

    def words(self, text: str) -> List[str]:
        return _SPLIT.findall(text)

    def encode(self, text: str, *, bos: bool = False, eos: bool = False) -> List[int]:
        ids = [self.word_id(w) for w in self.words(text)]
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        out = []
        for i in ids:
            i = int(i)
            if i in (PAD_ID, BOS_ID, EOS_ID):
                continue
            out.append(self._reverse.get(i, "<unk>"))
        return " ".join(out)

    def count(self, text: str) -> int:
        """Token count — the Table 2 cost metric."""
        return len(self.words(text))


_DEFAULT = HashTokenizer()


def default_tokenizer() -> HashTokenizer:
    return _DEFAULT


def count_tokens(text: str) -> int:
    return _DEFAULT.count(text)
