"""Advanced Augmentation — the paper's memory-creation pipeline (§2.1).

Distills raw dialogue sessions into the dual-layer memory asset:
semantic triples (precise, token-efficient facts, embedded + BM25-indexed)
and conversation summaries (narrative context), with triples linked to the
summary of the session they came from.

Designed as a *background* pipeline: `enqueue` is cheap; `process_pending`
runs extraction/embedding/indexing in batches (in production this is the
async worker; the benchmark calls it synchronously).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.bm25 import BM25Index
from repro.core.extraction import Extractor, Message, RuleExtractor
from repro.core.summaries import Summary, SummaryStore
from repro.core.triples import Triple, TripleStore
from repro.core.vector_index import VectorIndex


class AdvancedAugmentation:
    def __init__(self, embedder, extractor: Optional[Extractor] = None,
                 dim: int = 256, use_kernel: bool = True):
        self.embedder = embedder
        self.extractor = extractor or RuleExtractor()
        self.triples = TripleStore()
        self.summaries = SummaryStore()
        self.vindex = VectorIndex(dim=dim, use_kernel=use_kernel)
        self.bm25 = BM25Index()
        self._pending: List[Tuple[str, str, Sequence[Message]]] = []

    # -- background pipeline surface ------------------------------------
    def enqueue(self, conversation_id: str, session_id: str,
                messages: Sequence[Message]) -> None:
        self._pending.append((conversation_id, session_id, list(messages)))

    def process_pending(self) -> int:
        n = 0
        while self._pending:
            conv, sess, msgs = self._pending.pop(0)
            self._process(conv, sess, msgs)
            n += 1
        return n

    def ingest(self, conversation_id: str, session_id: str,
               messages: Sequence[Message]) -> Tuple[List[Triple], Summary]:
        """Synchronous enqueue+process of one session."""
        return self._process(conversation_id, session_id, messages)

    # -- internals --------------------------------------------------------
    def _process(self, conv: str, sess: str, msgs: Sequence[Message]):
        triples, summary = self.extractor.extract(conv, sess, msgs)
        self.summaries.add(summary)
        if triples:
            texts = [t.text() for t in triples]
            vecs = self.embedder.embed_texts(texts)
            vids = self.vindex.add(vecs)
            bids = self.bm25.add(texts)
            for t, vi, bi in zip(triples, vids, bids):
                tid = self.triples.add(t)
                # the three indices stay aligned: tid == vi == bi
                assert tid == int(vi) == int(bi), (tid, vi, bi)
        return triples, summary

    # -- stats -------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "triples": len(self.triples),
            "summaries": len(self.summaries),
            "bank_rows": self.vindex.n,
            "pending": len(self._pending),
        }
