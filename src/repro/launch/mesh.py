"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries pure
data parallelism (gradient all-reduce is the only DCN-crossing collective).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~)
HBM_BYTES = 16 * 1024**3        # 16 GiB
