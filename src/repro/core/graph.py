"""MemoryGraph — the device-resident entity graph over the triple store.

The paper's bet is that memory quality comes from *structured*
representations, yet flat top-k retrieval never traverses the structure it
already extracts: triples name entities and version chains, sessions order
facts in time.  This module packs that structure into device-resident
adjacency lanes next to the bank and turns retrieval's seed rows into a
batched k-hop expansion — the `graph` stage of RetrievalPlan.

**Nodes** are interned entities: one node per (namespace id, normalized
entity text), where normalization is `triples.normalize_entity` (the same
canonicalization `Triple.key` uses, so aliased mentions collapse to one
node).  Interning is per-namespace by construction — no edge can ever
connect two tenants, which is the first layer of the namespace-isolation
guarantee (the expansion kernel masks by node and row namespace anyway).

**Edges** are typed and directed (every upsert inserts both directions):

* ``entity`` (0)   — subject ↔ object of every triple (co-occurrence),
* ``temporal`` (1) — consecutive triples' object nodes within one session's
  extraction order (succession: "went to X" then "started Y"),
* ``causal`` (2)   — version chains: when a triple supersedes an earlier
  value of the same `Triple.key`, the old object links to the new one
  ("used to be a teacher" → "is a nurse").

**Row incidence lanes** map every global bank row to its subject/object
node ids (-1 when a row's text interned no entity), so seed rows become
seed nodes and expanded node activations become an expanded row ranking.
Row lanes are remapped through `compact()` exactly like row ids everywhere
else in the store; node/edge lanes are append-only (evicting rows removes
them from every ranking via the bank's -1 labels, but the entities they
mentioned remain traversable — an entity does not un-exist when one mention
of it is evicted).

**Device residency** follows `core/vector_index.py` to the letter: host
mirrors are the source of truth (snapshot/compact/oracle), the device lanes
live in capacity-doubling pow2 buffers updated in place by donated jitted
appends with pow2-padded update widths, and the live counts ride into the
expansion as traced scalars — so the steady state issues zero recompiles
and zero lane re-uploads while the graph grows within a capacity bucket
(spy-asserted in tests/test_graph.py).

**Expansion semantics** (`expand`, oracle: `kernels/ref.graph_expand_ref`):
seed rows activate their incident nodes at 1.0; each hop relaxes every edge
once —

    contribution(dst) = ((F[src] * (type_w[b, type] * edge_w)) * decay)
                        / out_degree(src)

— combined by max (best-path / max-product semiring), so the batched
scatter-max is order-independent and matches the scalar BFS oracle
bit-exactly in float32 (the explicit multiply order above is part of the
contract).  The degree normalization damps hub nodes (a speaker who said
forty things) so specific chains outrank hub fan-out.  A row's score is the
max over its incident nodes' activations, masked to the request's
namespace; rows rank by (-score, row id) — the store-wide lexicographic
tie-break.  Per-request hop counts ride in as a traced vector (requests in
one batch may expand to different depths inside one set of launches);
the hop loop is unrolled at a pow2-bucketed static depth.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2 as _next_pow2
from repro.core.triples import normalize_entity

EDGE_ENTITY = 0
EDGE_TEMPORAL = 1
EDGE_CAUSAL = 2
N_EDGE_TYPES = 3
EDGE_TYPE_NAMES = ("entity", "temporal", "causal")
EDGE_TYPE_IDS = {n: i for i, n in enumerate(EDGE_TYPE_NAMES)}


def _next_capacity(n: int, floor: int = 64) -> int:
    return max(floor, _next_pow2(max(1, n)))


# ---------------------------------------------------------------------------
# Device-side primitives: donated in-place lane updates (the vector index's
# append idiom — jit cache keyed on (capacity, padded update width) only).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _dev_append_nodes(node_ns, ns_new, start):
    return jax.lax.dynamic_update_slice(node_ns, ns_new, (start,))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _dev_append_edges(src, dst, et, w, s_new, d_new, t_new, w_new, start):
    src = jax.lax.dynamic_update_slice(src, s_new, (start,))
    dst = jax.lax.dynamic_update_slice(dst, d_new, (start,))
    et = jax.lax.dynamic_update_slice(et, t_new, (start,))
    w = jax.lax.dynamic_update_slice(w, w_new, (start,))
    return src, dst, et, w


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_append_rows(rs, ro, s_new, o_new, start):
    rs = jax.lax.dynamic_update_slice(rs, s_new, (start,))
    ro = jax.lax.dynamic_update_slice(ro, o_new, (start,))
    return rs, ro


@functools.partial(jax.jit, donate_argnums=(0,))
def _dev_scatter_w(w, idx, vals):
    """Edge-weight upsert: re-linking an existing (src, dst, type) edge
    updates its weight lane in place (pow2-padded idempotent scatter)."""
    return w.at[idx].set(vals)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_compact_rows(rs, ro, gather, n_new):
    """Repack the row-incidence lanes through a compaction's old->new map:
    new row r takes old row gather[r]; the tail clears to -1.  Donated
    in-place gather, sticky capacity — the expansion executable survives."""
    live = jnp.arange(rs.shape[0]) < n_new
    return (jnp.where(live, rs[gather], -1),
            jnp.where(live, ro[gather], -1))


@functools.partial(jax.jit,
                   static_argnames=("hops", "k", "seed_k", "decay"))
def _expand_device(edge_src, edge_dst, edge_type, edge_w, node_ns,
                   row_sub, row_obj, row_labels, rankings, q_ns, type_w,
                   hops_b, n_edges, n_rows, *, hops: int, k: int,
                   seed_k: int, decay: float):
    """Batched k-hop expansion: ONE gather/scatter-max launch per hop over
    the full edge lanes, whole batch at once.  All counts are traced
    (`n_edges`, `n_rows`) and the executable is keyed only on the pow2 lane
    capacities and the (hops, k, seed_k) bucket — appends within a capacity
    bucket reuse it.  Returns (row ids (B, kk) i32 best-first -1-padded,
    scores (B, kk) f32, frontier sizes (hops,) i32, edges touched (hops,)
    i32).  Float32 op order here is the oracle contract — see
    kernels/ref.graph_expand_ref, which mirrors it expression by
    expression."""
    B = q_ns.shape[0]
    Ncap = node_ns.shape[0]
    Ecap = edge_src.shape[0]
    Rcap = row_sub.shape[0]
    Lcap = row_labels.shape[0]
    decay32 = jnp.float32(decay)
    bidx = jnp.arange(B)[:, None]
    # -- seeds: top seed_k of every upstream ranking -> incident nodes ------
    seeds = jnp.concatenate(
        [r[:, : min(seed_k, r.shape[1])] for r in rankings], axis=1)
    ok = (seeds >= 0) & (seeds < n_rows)
    srow = jnp.where(ok, seeds, 0)
    ok = ok & (row_labels[jnp.clip(srow, 0, Lcap - 1)] == q_ns[:, None])
    sub = jnp.where(ok, row_sub[jnp.clip(srow, 0, Rcap - 1)], -1)
    obj = jnp.where(ok, row_obj[jnp.clip(srow, 0, Rcap - 1)], -1)
    F = jnp.zeros((B, Ncap), jnp.float32)
    for nodes in (sub, obj):
        F = F.at[bidx, jnp.clip(nodes, 0, Ncap - 1)].max(
            jnp.where(nodes >= 0, jnp.float32(1.0), jnp.float32(0.0)))
    ns_ok = node_ns[None, :] == q_ns[:, None]            # (B, Ncap)
    F = jnp.where(ns_ok, F, 0.0)
    # Seed nodes deliberately never score rows — not their hop-0 activation
    # and not any hop>=1 re-activation (a hub seed like a speaker's name
    # round-trips back at full strength and would tie every row it touches,
    # crowding the actual discoveries out of the top-k).  The expanded
    # ranking is rows reached through NEWLY discovered nodes only; the seed
    # rows themselves are the upstream rankings' job.
    seed_mask = F > 0
    acc = jnp.zeros_like(F)
    # -- static per-expansion edge terms ------------------------------------
    e_ok = jnp.arange(Ecap) < n_edges
    src_c = jnp.clip(edge_src, 0, Ncap - 1)
    dst_c = jnp.clip(edge_dst, 0, Ncap - 1)
    deg = jnp.zeros((Ncap,), jnp.int32).at[src_c].add(
        jnp.where(e_ok, 1, 0))
    deg_f = jnp.maximum(deg, 1).astype(jnp.float32)
    we = type_w[:, jnp.clip(edge_type, 0, N_EDGE_TYPES - 1)] \
        * edge_w[None, :]                                 # (B, Ecap)
    frontier_sizes, edges_touched = [], []
    for h in range(1, hops + 1):
        c = F[:, src_c] * we          # float32 op order = oracle contract
        c = c * decay32
        c = c / deg_f[src_c][None, :]
        c = jnp.where(e_ok[None, :], c, 0.0)
        newF = jnp.zeros((B, Ncap), jnp.float32).at[bidx, dst_c[None, :]
                                                   ].max(c)
        newF = jnp.where(ns_ok, newF, 0.0)
        live = (hops_b >= h)[:, None]
        newF = jnp.where(live, newF, 0.0)
        acc = jnp.maximum(acc, newF)
        F = newF
        edges_touched.append(jnp.sum((c > 0).astype(jnp.int32)))
        frontier_sizes.append(jnp.sum((newF > 0).astype(jnp.int32)))
    # -- node activations -> row ranking ------------------------------------
    acc = jnp.where(seed_mask, 0.0, acc)
    r_idx = jnp.arange(Rcap, dtype=jnp.int32)
    rl = row_labels[jnp.clip(r_idx, 0, Lcap - 1)]
    r_ok = (r_idx[None, :] < n_rows) & (rl[None, :] == q_ns[:, None])
    rs = jnp.where(row_sub[None, :] >= 0,
                   acc[:, jnp.clip(row_sub, 0, Ncap - 1)], 0.0)
    ro = jnp.where(row_obj[None, :] >= 0,
                   acc[:, jnp.clip(row_obj, 0, Ncap - 1)], 0.0)
    score = jnp.where(r_ok, jnp.maximum(rs, ro), 0.0)    # (B, Rcap)
    hit = score > 0
    neg = jnp.where(hit, -score, jnp.inf)
    sid = jnp.where(hit, r_idx[None, :], jnp.iinfo(jnp.int32).max)
    out = jnp.where(hit, r_idx[None, :], -1)
    # lexicographic (-score, row id): descending score, ties to lower row
    neg_s, _, ids_s = jax.lax.sort((neg, sid, out), dimension=1,
                                   num_keys=2, is_stable=True)
    kk = min(k, Rcap)
    alive = neg_s[:, :kk] < jnp.inf
    return (jnp.where(alive, ids_s[:, :kk], -1),
            jnp.where(alive, -neg_s[:, :kk], 0.0),
            jnp.stack(frontier_sizes), jnp.stack(edges_touched))


class GraphInvariantError(RuntimeError):
    """A graph-internal alignment invariant was violated (lane drift).
    The store wraps this into StoreInvariantError at its boundary."""


class MemoryGraph:
    """Entity/temporal/causal graph with host-mirror truth and in-place
    device lanes.  All writes land host-side immediately; `sync_device()`
    pushes the accumulated delta to the device lanes in one pow2-padded
    donated append per lane family (the store calls it once per flush)."""

    def __init__(self):
        # host truth: nodes
        self._node_text: List[str] = []
        self._node_ns = np.full((64,), -1, np.int32)
        self._intern: Dict[Tuple[int, str], int] = {}
        # host truth: edges (directed COO lanes; CSR offsets are derived on
        # demand by the oracle/tests — the device expansion relaxes the COO
        # lanes directly, which is what keeps appends O(delta))
        self._edge_src = np.zeros((64,), np.int32)
        self._edge_dst = np.zeros((64,), np.int32)
        self._edge_type = np.zeros((64,), np.int32)
        self._edge_w = np.zeros((64,), np.float32)
        self._n_edges = 0
        self._edge_idx: Dict[Tuple[int, int, int], int] = {}
        # host truth: row incidence
        self._row_sub = np.full((64,), -1, np.int32)
        self._row_obj = np.full((64,), -1, np.int32)
        self._n_rows = 0
        # per-(ns, triple-key) version-chain tail: last object node
        self._tail: Dict[Tuple[int, str], int] = {}
        # device lanes (lazily materialized, then updated in place)
        self._dev = None                     # dict of jnp lanes
        self._synced = (0, 0, 0)             # (nodes, edges, rows) on device
        self._pending_w: List[int] = []      # edge ids with re-set weights
        self.counters = {"expansions": 0, "edges_upserted": 0}

    # -- sizes --------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self._node_text)

    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def n_rows(self) -> int:
        return self._n_rows

    def edge_type_counts(self) -> Dict[str, int]:
        et = self._edge_type[: self._n_edges]
        return {name: int((et == i).sum())
                for i, name in enumerate(EDGE_TYPE_NAMES)}

    # -- host mirrors (oracle / snapshot readers) ---------------------------
    def node_ns(self) -> np.ndarray:
        return self._node_ns[: self.n_nodes].copy()

    def edges(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        m = self._n_edges
        return (self._edge_src[:m].copy(), self._edge_dst[:m].copy(),
                self._edge_type[:m].copy(), self._edge_w[:m].copy())

    def row_incidence(self) -> Tuple[np.ndarray, np.ndarray]:
        return (self._row_sub[: self._n_rows].copy(),
                self._row_obj[: self._n_rows].copy())

    def csr_offsets(self) -> np.ndarray:
        """(n_nodes + 1,) int64 CSR row offsets of the out-adjacency,
        derived from the COO lanes (docs/STORAGE.md documents the layout;
        tests cross-check the device degree normalization against it)."""
        counts = np.bincount(self._edge_src[: self._n_edges],
                             minlength=self.n_nodes)
        return np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)

    # -- writes (host first, device delta on sync) --------------------------
    def intern(self, ns_id: int, text: str) -> int:
        """Create-or-get the node for (namespace, normalized entity)."""
        key = (int(ns_id), normalize_entity(text))
        node = self._intern.get(key)
        if node is not None:
            return node
        node = self.n_nodes
        if node >= self._node_ns.shape[0]:
            cap = _next_capacity(node + 1, floor=2 * self._node_ns.shape[0])
            grown = np.full((cap,), -1, np.int32)
            grown[:node] = self._node_ns[:node]
            self._node_ns = grown
            self._invalidate_device()
        self._node_text.append(key[1])
        self._node_ns[node] = key[0]
        self._intern[key] = node
        return node

    def _grow_edges(self, need: int) -> None:
        cap = self._edge_src.shape[0]
        if need <= cap:
            return
        cap = _next_capacity(need, floor=2 * cap)
        for name in ("_edge_src", "_edge_dst", "_edge_type"):
            grown = np.zeros((cap,), np.int32)
            grown[: self._n_edges] = getattr(self, name)[: self._n_edges]
            setattr(self, name, grown)
        w = np.zeros((cap,), np.float32)
        w[: self._n_edges] = self._edge_w[: self._n_edges]
        self._edge_w = w
        self._invalidate_device()

    def add_edge(self, src: int, dst: int, etype: int,
                 weight: float = 1.0) -> None:
        """Upsert ONE directed edge.  A new (src, dst, type) appends; an
        existing one keeps its lane slot and re-sets its weight (the device
        weight lane is patched by the next sync)."""
        if src == dst:
            return
        key = (int(src), int(dst), int(etype))
        eid = self._edge_idx.get(key)
        w32 = np.float32(weight)
        if eid is not None:
            if self._edge_w[eid] != w32:
                self._edge_w[eid] = w32
                self._pending_w.append(eid)
            return
        self._grow_edges(self._n_edges + 1)
        eid = self._n_edges
        self._edge_src[eid], self._edge_dst[eid] = key[0], key[1]
        self._edge_type[eid], self._edge_w[eid] = key[2], w32
        self._edge_idx[key] = eid
        self._n_edges += 1
        self.counters["edges_upserted"] += 1

    def link_nodes(self, src: int, dst: int, etype: int,
                   weight: float = 1.0) -> None:
        """Symmetric upsert: both directions (the expansion is directed)."""
        self.add_edge(src, dst, etype, weight)
        self.add_edge(dst, src, etype, weight)

    def append_row(self, row: int, sub_node: int, obj_node: int) -> None:
        """Record row `row`'s incidence.  Rows MUST arrive in global-row
        order — the lane position IS the row id (the store's alignment
        invariant; drift raises GraphInvariantError)."""
        if row != self._n_rows:
            raise GraphInvariantError(
                f"row-incidence drift: appending row {row}, lane holds "
                f"{self._n_rows}")
        cap = self._row_sub.shape[0]
        if row >= cap:
            cap = _next_capacity(row + 1, floor=2 * cap)
            for name in ("_row_sub", "_row_obj"):
                grown = np.full((cap,), -1, np.int32)
                grown[: self._n_rows] = getattr(self, name)[: self._n_rows]
                setattr(self, name, grown)
            self._invalidate_device()
        self._row_sub[row] = int(sub_node)
        self._row_obj[row] = int(obj_node)
        self._n_rows += 1

    def ingest_session(self, ns_id: int, triples: Sequence,
                       rows: Sequence[int]) -> None:
        """Ingest one flushed session's triples (with their freshly
        assigned global rows, in order): intern entities, append row
        incidence, and upsert the three edge families.  Deterministic given
        prior graph state — WAL replay of the same flush records rebuilds
        the graph bit-identically (asserted in tests)."""
        prev_obj = None
        for tr, row in zip(triples, rows):
            sub = self.intern(ns_id, tr.subject)
            obj = self.intern(ns_id, tr.object)
            self.append_row(int(row), sub, obj)
            self.link_nodes(sub, obj, EDGE_ENTITY)
            if prev_obj is not None:
                self.link_nodes(prev_obj, obj, EDGE_TEMPORAL)
            prev_obj = obj
            tail_key = (int(ns_id), tr.key())
            last = self._tail.get(tail_key)
            if last is not None and last != obj:
                self.link_nodes(last, obj, EDGE_CAUSAL)
            self._tail[tail_key] = obj

    # -- device residency ---------------------------------------------------
    def _invalidate_device(self) -> None:
        self._dev = None

    def _ensure_device(self) -> None:
        """Materialize the device lanes from the host mirror — first
        expansion and after capacity changes only, never steady-state."""
        if self._dev is not None:
            return
        self._dev = {
            "node_ns": jnp.asarray(self._node_ns),
            "edge_src": jnp.asarray(self._edge_src),
            "edge_dst": jnp.asarray(self._edge_dst),
            "edge_type": jnp.asarray(self._edge_type),
            "edge_w": jnp.asarray(self._edge_w),
            "row_sub": jnp.asarray(self._row_sub),
            "row_obj": jnp.asarray(self._row_obj),
        }
        self._synced = (self.n_nodes, self._n_edges, self._n_rows)
        self._pending_w = []

    def sync_device(self) -> None:
        """Push the host-side delta since the last sync to the device lanes
        in place: one pow2-padded donated append per lane family plus one
        weight scatter when upserts re-weighted existing edges.  A no-op
        until the first expansion materializes the lanes."""
        if self._dev is None:
            return
        d = self._dev
        sn, se, sr = self._synced
        if self.n_nodes > sn:
            m = self.n_nodes - sn
            pad = max(m, min(_next_pow2(m), self._node_ns.shape[0] - sn))
            up = np.full((pad,), -1, np.int32)
            up[:m] = self._node_ns[sn: sn + m]
            d["node_ns"] = _dev_append_nodes(d["node_ns"], jnp.asarray(up),
                                             jnp.int32(sn))
        if self._n_edges > se:
            m = self._n_edges - se
            pad = max(m, min(_next_pow2(m), self._edge_src.shape[0] - se))
            ups = []
            for lane, fill, dt in ((self._edge_src, 0, np.int32),
                                   (self._edge_dst, 0, np.int32),
                                   (self._edge_type, 0, np.int32),
                                   (self._edge_w, 0.0, np.float32)):
                up = np.full((pad,), fill, dt)
                up[:m] = lane[se: se + m]
                ups.append(jnp.asarray(up))
            d["edge_src"], d["edge_dst"], d["edge_type"], d["edge_w"] = \
                _dev_append_edges(d["edge_src"], d["edge_dst"],
                                  d["edge_type"], d["edge_w"], *ups,
                                  jnp.int32(se))
        if self._n_rows > sr:
            m = self._n_rows - sr
            pad = max(m, min(_next_pow2(m), self._row_sub.shape[0] - sr))
            up_s = np.full((pad,), -1, np.int32)
            up_o = np.full((pad,), -1, np.int32)
            up_s[:m] = self._row_sub[sr: sr + m]
            up_o[:m] = self._row_obj[sr: sr + m]
            d["row_sub"], d["row_obj"] = _dev_append_rows(
                d["row_sub"], d["row_obj"], jnp.asarray(up_s),
                jnp.asarray(up_o), jnp.int32(sr))
        if self._pending_w:
            # only already-synced edges need the patch (fresh appends above
            # carried their final weight)
            idx = sorted({e for e in self._pending_w if e < se})
            if idx:
                pad = _next_pow2(len(idx))
                idx_up = np.asarray(
                    idx + [idx[-1]] * (pad - len(idx)), np.int32)
                d["edge_w"] = _dev_scatter_w(
                    d["edge_w"], jnp.asarray(idx_up),
                    jnp.asarray(self._edge_w[idx_up]))
        self._synced = (self.n_nodes, self._n_edges, self._n_rows)
        self._pending_w = []

    # -- the read path ------------------------------------------------------
    def expand(self, rankings: Sequence, q_ns, row_labels, type_w, hops_b,
               *, k: int, max_hops: int, seed_k: int = 8,
               decay: float = 0.5):
        """Batched expansion over the device lanes.  `rankings` are the
        upstream (B, P_i) device id matrices (dense/sparse, -1-padded,
        best-first); their first `seed_k` columns seed the frontier.
        `row_labels` is the bank's cached (capacity,) effective-label
        device buffer (tombstones/demoted rows -1 — they neither seed nor
        surface).  `type_w` (B, 3) f32 per-request edge-type weights,
        `hops_b` (B,) i32 per-request hop counts (0 = seeds only).
        `max_hops` is the static unrolled depth (pow2-bucketed by the
        caller); `k` the ranking width.  Returns (ids (B, k) i32 device,
        scores (B, k) f32 device, per-hop frontier sizes, per-hop edges
        touched — both small host lists)."""
        self._ensure_device()
        self.sync_device()
        d = self._dev
        hops = max(1, int(max_hops))
        ids, scores, fsz, etc = _expand_device(
            d["edge_src"], d["edge_dst"], d["edge_type"], d["edge_w"],
            d["node_ns"], d["row_sub"], d["row_obj"], row_labels,
            tuple(jnp.asarray(r, jnp.int32) for r in rankings),
            jnp.asarray(q_ns, jnp.int32),
            jnp.asarray(type_w, jnp.float32),
            jnp.asarray(hops_b, jnp.int32),
            jnp.int32(self._n_edges), jnp.int32(self._n_rows),
            hops=hops, k=int(k), seed_k=int(seed_k), decay=float(decay))
        self.counters["expansions"] += 1
        if ids.shape[1] < k:
            ids = jnp.pad(ids, ((0, 0), (0, k - ids.shape[1])),
                          constant_values=-1)
            scores = jnp.pad(scores, ((0, 0), (0, k - scores.shape[1])))
        return ids, scores, [int(x) for x in np.asarray(fsz)], \
            [int(x) for x in np.asarray(etc)]

    # -- compaction / persistence -------------------------------------------
    def compact_rows(self, old_to_new: np.ndarray) -> None:
        """Remap the row-incidence lanes through a store compaction's
        old->new row map ((n_old,) with -1 for dropped rows).  Kept rows
        keep their incidence; dropped rows' incidences vanish with them.
        Sticky capacity; the device lanes repack via a donated gather."""
        old_to_new = np.asarray(old_to_new, np.int64)
        n_old = old_to_new.shape[0]
        if n_old != self._n_rows:
            raise GraphInvariantError(
                f"compaction drift: map covers {n_old} rows, lanes hold "
                f"{self._n_rows}")
        keep = np.where(old_to_new >= 0)[0]
        n_new = int(keep.size)
        cap = self._row_sub.shape[0]
        new_sub = np.full((cap,), -1, np.int32)
        new_obj = np.full((cap,), -1, np.int32)
        new_sub[:n_new] = self._row_sub[keep]
        new_obj[:n_new] = self._row_obj[keep]
        self._row_sub, self._row_obj = new_sub, new_obj
        self._n_rows = n_new
        if self._dev is not None:
            gather = np.zeros((cap,), np.int32)
            gather[:n_new] = keep
            self._dev["row_sub"], self._dev["row_obj"] = _dev_compact_rows(
                self._dev["row_sub"], self._dev["row_obj"],
                jnp.asarray(gather), jnp.int32(n_new))
            self._synced = (self._synced[0], self._synced[1], n_new)

    def snapshot_arrays(self) -> Dict[str, np.ndarray]:
        """Numeric lanes for checkpoint/io.py (tight, not capacity-padded)."""
        m, r = self._n_edges, self._n_rows
        return {
            "graph_node_ns": self._node_ns[: self.n_nodes].copy(),
            "graph_edge_src": self._edge_src[:m].copy(),
            "graph_edge_dst": self._edge_dst[:m].copy(),
            "graph_edge_type": self._edge_type[:m].copy(),
            "graph_edge_w": self._edge_w[:m].copy(),
            "graph_row_sub": self._row_sub[:r].copy(),
            "graph_row_obj": self._row_obj[:r].copy(),
        }

    def snapshot_meta(self) -> dict:
        """Non-numeric state: node texts (interning rebuilds from them) and
        the version-chain tails (so post-restore ingest keeps extending the
        same causal chains the writer would have)."""
        return {
            "nodes": list(self._node_text),
            "tail": [[int(ns), key, int(node)]
                     for (ns, key), node in sorted(self._tail.items())],
        }

    @classmethod
    def from_snapshot(cls, arrays: Dict[str, np.ndarray],
                      meta: dict) -> "MemoryGraph":
        g = cls()
        node_ns = np.asarray(arrays["graph_node_ns"], np.int32)
        texts = [str(t) for t in meta["nodes"]]
        if len(texts) != node_ns.shape[0]:
            raise GraphInvariantError(
                f"restore: {len(texts)} node texts vs "
                f"{node_ns.shape[0]} node labels")
        g._node_ns = np.full((_next_capacity(len(texts)),), -1, np.int32)
        g._node_ns[: len(texts)] = node_ns
        g._node_text = texts
        g._intern = {(int(ns), t): i
                     for i, (ns, t) in enumerate(zip(node_ns, texts))}
        src = np.asarray(arrays["graph_edge_src"], np.int32)
        m = src.shape[0]
        ecap = _next_capacity(m)
        g._edge_src = np.zeros((ecap,), np.int32)
        g._edge_dst = np.zeros((ecap,), np.int32)
        g._edge_type = np.zeros((ecap,), np.int32)
        g._edge_w = np.zeros((ecap,), np.float32)
        g._edge_src[:m] = src
        g._edge_dst[:m] = np.asarray(arrays["graph_edge_dst"], np.int32)
        g._edge_type[:m] = np.asarray(arrays["graph_edge_type"], np.int32)
        g._edge_w[:m] = np.asarray(arrays["graph_edge_w"], np.float32)
        g._n_edges = m
        g._edge_idx = {(int(g._edge_src[i]), int(g._edge_dst[i]),
                        int(g._edge_type[i])): i for i in range(m)}
        sub = np.asarray(arrays["graph_row_sub"], np.int32)
        r = sub.shape[0]
        rcap = _next_capacity(r)
        g._row_sub = np.full((rcap,), -1, np.int32)
        g._row_obj = np.full((rcap,), -1, np.int32)
        g._row_sub[:r] = sub
        g._row_obj[:r] = np.asarray(arrays["graph_row_obj"], np.int32)
        g._n_rows = r
        g._tail = {(int(ns), str(key)): int(node)
                   for ns, key, node in meta.get("tail", [])}
        return g

    def stats(self) -> dict:
        """Durable-state gauges only (snapshot-identical across restore —
        session-local counters like expansion counts live in telemetry)."""
        return {
            "nodes": self.n_nodes,
            "edges": self._n_edges,
            "rows_with_incidence": int(
                (self._row_sub[: self._n_rows] >= 0).sum()),
            **{f"edges_{n}": c for n, c in self.edge_type_counts().items()},
        }
