"""Production serving launcher: pjit'd prefill + decode on a real mesh, with
the Memori memory layer in front.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b [--multipod]
    PYTHONPATH=src python -m repro.launch.serve --host-demo
    PYTHONPATH=src python -m repro.launch.serve --host-demo \
        --snapshot-path /tmp/memori.d --flush-interval 0.5 \
        --snapshot-interval 30 --max-pending 256

`--snapshot-path` mounts the memory layer on a lifecycle runtime rooted at
that durable directory: the service recovers from it on boot (newest valid
snapshot + WAL replay — a restarted server answers bit-identically up to
the last durable flush) and every flush appends to the write-ahead log.
`--flush-interval` runs the background flusher (seconds); `--max-pending`
bounds the queue with blocking backpressure; `--snapshot-interval` rotates
full snapshots (retaining `--snapshot-retain` generations and truncating
the WAL).  SIGTERM/SIGINT trigger a final flush + snapshot before exit, so
a container shutdown loses nothing that reached the queue drain.
`--tick-interval` mounts the cross-client MemoryScheduler: concurrent
handlers' single retrieves coalesce into one batched device launch per
tick (`--max-batch` caps the tick; see docs/API.md).

`--http-port` exposes the memory layer over HTTP (serving/frontend.py):

    PYTHONPATH=src python -m repro.launch.serve --host-demo \
        --tick-interval 0.002 --http-port 8080 \
        --api-keys secret1=acme,secret2=beta \
        --qos-rate 50 --qos-burst 100 --qos-max-queued 256

`--api-keys` maps each api key to its tenant; every request's namespace is
scoped under its tenant, and the tenant is the QoS identity admission
control charges.  The `--qos-*` flags set the default per-tenant contract
(token-bucket rate limit, backlog cap) and the global shed threshold —
rejections surface as HTTP 429 + Retry-After (see docs/OPERATIONS.md for
tuning).  QoS needs the scheduler, so `--qos-*` requires --tick-interval.
"""
import argparse
import os
import signal


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="memori-agent")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--host-demo", action="store_true")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--snapshot-path", default=None,
                    help="durable directory for the lifecycle runtime "
                         "(rotating snapshots + WAL); recovered on boot, "
                         "snapshotted on shutdown incl. SIGTERM/SIGINT")
    ap.add_argument("--flush-interval", type=float, default=None,
                    help="background flusher period in seconds "
                         "(policy.flush_interval_s); default: synchronous "
                         "record")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bound the pending queue (blocking backpressure)")
    ap.add_argument("--snapshot-interval", type=float, default=None,
                    help="periodic full-snapshot rotation period in seconds")
    ap.add_argument("--snapshot-retain", type=int, default=2,
                    help="snapshot generations kept by rotation")
    ap.add_argument("--tick-interval", type=float, default=None,
                    help="mount a MemoryScheduler: micro-batch window in "
                         "seconds collecting concurrent clients' requests "
                         "into one device launch per tick")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="scheduler tick size cap (use a power of two: "
                         "batches pad to pow2 Q buckets anyway)")
    ap.add_argument("--http-port", type=int, default=None,
                    help="serve the memory layer over HTTP on this port "
                         "(0 = ephemeral); requires --api-keys")
    ap.add_argument("--http-host", default="0.0.0.0",
                    help="HTTP bind address (default 0.0.0.0)")
    ap.add_argument("--api-keys", default=None,
                    help="comma-separated key=tenant pairs; the key "
                         "authenticates, the tenant scopes namespaces and "
                         "is the QoS identity")
    ap.add_argument("--qos-rate", type=float, default=None,
                    help="default per-tenant rate limit in req/s "
                         "(token bucket; rejections are 429 on the wire)")
    ap.add_argument("--qos-burst", type=int, default=32,
                    help="token-bucket burst capacity per tenant")
    ap.add_argument("--qos-max-queued", type=int, default=None,
                    help="per-tenant backlog cap (shed above it)")
    ap.add_argument("--qos-max-queued-global", type=int, default=None,
                    help="global backlog cap; tenants above their "
                         "weight-proportional fair share are shed first")
    args = ap.parse_args()
    if args.snapshot_interval is not None and args.snapshot_path is None:
        ap.error("--snapshot-interval needs --snapshot-path (rotation "
                 "without a durable directory would silently no-op)")
    if args.http_port is not None and not args.api_keys:
        ap.error("--http-port needs --api-keys (an unauthenticated frontend "
                 "would serve every tenant's memory to anyone)")
    wants_qos = (args.qos_rate is not None or args.qos_max_queued is not None
                 or args.qos_max_queued_global is not None)
    if wants_qos and args.tick_interval is None:
        ap.error("--qos-* flags need --tick-interval (admission control "
                 "lives in the scheduler's submit path)")

    if args.host_demo:
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                                   + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.configs import get_config
    from repro.core import LifecyclePolicy, MemoriClient, MemoryService
    from repro.core.embedder import HashEmbedder
    from repro.data.tokenizer import HashTokenizer
    from repro.models.model_api import Model
    from repro.serving.engine import Engine
    from repro.serving.sampler import SamplerConfig

    cfg = get_config(args.arch)
    if args.host_demo:
        cfg = cfg.reduced(layers=2, d_model=128)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashTokenizer(cfg.vocab_size)
    engine = Engine(model, params, max_len=args.max_len, slots=2,
                    sampler=SamplerConfig(temperature=0.8, top_k=40),
                    tokenizer=tok)
    policy = LifecyclePolicy(
        flush_interval_s=args.flush_interval,
        max_pending=args.max_pending,
        snapshot_interval_s=args.snapshot_interval,
        snapshot_retain=args.snapshot_retain,
    )
    wants_runtime = args.snapshot_path is not None or policy.wants_daemon \
        or args.max_pending is not None
    # one multi-tenant service fronts every conversation on this host;
    # with --snapshot-path it picks up exactly where the last run stopped
    if args.snapshot_path is not None:
        if os.path.isfile(args.snapshot_path):
            raise SystemExit(
                f"--snapshot-path {args.snapshot_path} is a legacy "
                "single-file snapshot; the lifecycle runtime needs a "
                "directory (restore the file once via "
                "MemoryService.restore, then serve with a directory)")
        service = MemoryService.recover(
            args.snapshot_path, HashEmbedder(), policy=policy,
            use_kernel=False, budget=800)
        print(f"recovered memory store from {args.snapshot_path}: "
              f"{service.stats()}")
    else:
        service = MemoryService(HashEmbedder(), budget=800, use_kernel=False,
                                policy=policy if wants_runtime else None)
    if args.tick_interval is not None:
        # every handler / SDK client request from here on coalesces with
        # its concurrent peers into one batched launch per scheduler tick
        admission = None
        if wants_qos:
            from repro.core import AdmissionPolicy, TenantPolicy
            admission = AdmissionPolicy(
                default=TenantPolicy(rate=args.qos_rate,
                                     burst=args.qos_burst,
                                     max_queued=args.qos_max_queued),
                max_queued_global=args.qos_max_queued_global)
        service.start_scheduler(tick_interval_s=args.tick_interval,
                                max_batch=args.max_batch,
                                admission=admission)

    def _shutdown(signum, frame):
        # container shutdown: unwind via SystemExit (flush's all-or-nothing
        # guard restores the queue if we land mid-batch) and let the
        # `finally` below run the single close path — the handler itself
        # must NOT flush/rotate, it may be interrupting a commit
        print(f"signal {signum}: shutting down")
        raise SystemExit(0)

    signal.signal(signal.SIGTERM, _shutdown)
    signal.signal(signal.SIGINT, _shutdown)

    llm = lambda p: engine.generate([p[-500:]], max_new_tokens=12)[0]  # noqa: E731
    client = MemoriClient(llm, service.namespace("u0/demo"))

    frontend = None
    try:
        if args.http_port is not None:
            from repro.serving.frontend import MemoryFrontend
            keys = dict(pair.split("=", 1)
                        for pair in args.api_keys.split(","))
            frontend = MemoryFrontend(service, keys, host=args.http_host,
                                      port=args.http_port)
            print(f"memory layer serving on {frontend.address} "
                  f"({len(keys)} api keys)")
            frontend.serve_forever()       # until SIGTERM/SIGINT
        else:
            print(client.chat("I work as a translator and I live in Cusco."))
            client.end_session()
            [ctx] = service.retrieve_batch(
                [("u0/demo", "Where does the user live?")])
            print(f"retrieved {len(ctx.triples)} triples, "
                  f"{ctx.token_count} tokens")
            print("service:", service.stats())
            if service.scheduler is not None:
                print("scheduler:", service.scheduler.stats())
            print("engine:", engine.stats)
    finally:
        if frontend is not None:
            frontend.close()
        try:
            service.close(final_snapshot=args.snapshot_path is not None)
            if args.snapshot_path is not None:
                print(f"final snapshot rotation -> {args.snapshot_path}")
        except Exception as e:
            # the WAL already holds every durable flush; recovery replays
            # it even when the final rotation could not be written
            print(f"clean close failed ({e!r}); durable WAL state in "
                  f"{args.snapshot_path} remains recoverable")


if __name__ == "__main__":
    main()
