import os
import sys

# never inherit the dry-run's 512-device flag into unit tests
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
