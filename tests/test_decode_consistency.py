"""The serving-correctness invariant: prefill + decode_step reproduces the
full-forward logits for EVERY architecture (KV caches, SSM states, RG-LRU
states, MLA latent caches, ring buffers and cross-attention all round-trip)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer
from repro.models.layers import embedding
from repro.models.model_api import Model

KEY = jax.random.PRNGKey(3)


def _setup(arch, S=16, extra=1):
    cfg = get_config(arch).reduced()
    if cfg.use_moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = Model(cfg)
    params = model.init_params(KEY)
    toks = jax.random.randint(KEY, (2, S + extra), 4, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if cfg.num_image_tokens:
        batch["images"] = jax.random.normal(KEY, (2, cfg.num_image_tokens, 1152))
    if cfg.is_encoder_decoder:
        batch["audio"] = jax.random.normal(KEY, (2, cfg.encoder_seq_len,
                                                 cfg.d_model))
    return cfg, model, params, toks, batch


def _full_logits(cfg, model, params, batch):
    x, pos, pl, enc, encp = model._embed_inputs(params, batch)
    h, _, _ = transformer.decoder_apply(
        params, cfg, x, mode="train", positions=pos,
        mask_kind="prefix" if pl else "causal", prefix_len=pl,
        enc_out=enc, enc_positions=encp,
        use_rope=not cfg.is_encoder_decoder, remat=False)
    return embedding.logits(params["embed"], cfg, h[:, -1:])


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_then_decode_matches_full_forward(arch, S=16):
    cfg, model, params, toks, batch = _setup(arch, S)
    batch_full = dict(batch)
    batch_full["tokens"] = toks
    want = _full_logits(cfg, model, params, batch_full)

    _, caches = model.prefill(params, batch)
    P = cfg.num_image_tokens or 0
    caches = model.prepare_decode_caches(caches, P + S, P + S + 8)
    got, _ = model.decode_step(params, toks[:, S:S + 1], caches,
                               jnp.int32(P + S))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["stablelm-3b", "mamba2-2.7b",
                                  "recurrentgemma-9b", "deepseek-v3-671b"])
def test_multistep_decode_matches_full_forward(arch):
    """Decode 4 tokens autoregressively == 4 teacher-forced full forwards."""
    S = 12
    cfg, model, params, toks, batch = _setup(arch, S, extra=5)
    P = cfg.num_image_tokens or 0
    _, caches = model.prefill(params, batch)
    caches = model.prepare_decode_caches(caches, P + S, P + S + 8)
    for step in range(4):
        cur = S + step
        batch_full = dict(batch)
        batch_full["tokens"] = toks[:, : cur + 1]
        want = _full_logits(cfg, model, params, batch_full)
        got, caches = model.decode_step(
            params, toks[:, cur: cur + 1], caches, jnp.int32(P + cur))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-3, atol=3e-3)


def test_ring_cache_matches_full_cache_window_decode():
    """Sliding-window decode with a ring cache == window attention with the
    full cache (dense arch, window < sequence)."""
    arch = "qwen3-8b"
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, sliding_window=8)
    model = Model(cfg)
    params = model.init_params(KEY)
    S = 20
    toks = jax.random.randint(KEY, (1, S + 3), 4, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}

    # ring path: window_override = 8 -> ring cache of size 8
    _, c1 = model.prefill(params, batch, window_override=8)
    ring = model.prepare_decode_caches(c1, S, S + 8, window_override=8)
    # full path: same window masking, full-size cache
    _, c2 = model.prefill(params, batch)
    full = model.prepare_decode_caches(c2, S, S + 8)

    for step in range(3):
        cur = S + step
        t = toks[:, cur: cur + 1]
        got_ring, ring = model.decode_step(params, t, ring, jnp.int32(cur),
                                           window_override=8)
        got_full, full = model.decode_step(params, t, full, jnp.int32(cur),
                                           window_override=8)
        np.testing.assert_allclose(np.asarray(got_ring), np.asarray(got_full),
                                   rtol=2e-4, atol=2e-4)
