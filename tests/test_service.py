"""MemoryService: namespace isolation, batched==sequential retrieval,
tombstone/eviction correctness, and the index-layer primitives under it."""
import warnings

import numpy as np
import pytest

from repro.core import MemoriClient, MemoryService, Message, Triple, TripleStore
from repro.core.bm25 import BM25Index
from repro.core.embedder import HashEmbedder
from repro.core.hybrid import rrf_fuse
from repro.core.vector_index import VectorIndex

EMB = HashEmbedder()


def _svc(**kw):
    kw.setdefault("use_kernel", False)   # pure-jnp search: fast on CPU
    return MemoryService(EMB, **kw)


def _session(texts, speaker="Caroline", ts=1700000000.0):
    return [Message(speaker, t, ts) for t in texts]


def _fill(svc):
    svc.record("alice/c0", "s0", _session(
        ["I work as a botanist and I live in Tallinn.",
         "I adopted a hedgehog named Biscuit."], speaker="Alice"))
    svc.record("bob/c0", "s0", _session(
        ["I work as a welder and I live in Porto.",
         "I adopted a parrot named Olive."], speaker="Bob"))
    svc.record("carol/c0", "s0", _session(
        ["I work as a pilot and I live in Cusco."], speaker="Carol"))
    return svc


# -- namespace isolation ------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_namespace_isolation(use_kernel):
    svc = _svc(use_kernel=use_kernel)
    _fill(svc)
    for q in ["Which city does the user live in?",
              "What pet was adopted?", "What is the user's job?"]:
        ctx_a = svc.retrieve("alice/c0", q)
        ctx_b = svc.retrieve("bob/c0", q)
        assert ctx_a.triples, q
        assert all(t.conversation_id == "alice/c0" for t in ctx_a.triples)
        assert all(s.conversation_id == "alice/c0" for s in ctx_a.summaries)
        assert all(t.conversation_id == "bob/c0" for t in ctx_b.triples)
    # and the facts themselves stay per-tenant
    ctx = svc.retrieve("alice/c0", "Which city does the user live in?")
    objs = {t.object for t in ctx.triples}
    assert "tallinn" in objs and "porto" not in objs


def test_unknown_namespace_is_empty_not_leaky():
    svc = _fill(_svc())
    before = svc.stats()["namespaces"]
    ctx = svc.retrieve("mallory/c0", "Which city does the user live in?")
    assert ctx.triples == [] and ctx.summaries == []
    # reads must not allocate tenant state for arbitrary namespaces
    assert svc.stats()["namespaces"] == before
    assert "mallory/c0" not in svc.namespaces()


def test_evicted_namespace_stays_evicted_after_reads():
    svc = _fill(_svc())
    svc.evict("carol/c0")
    svc.retrieve("carol/c0", "anything?")
    assert "carol/c0" not in svc.namespaces()


# -- batched == sequential ----------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_retrieve_batch_equals_sequential(use_kernel):
    svc = _svc(use_kernel=use_kernel)
    _fill(svc)
    batch = [("alice/c0", "Which city does the user live in?"),
             ("bob/c0", "Which city does the user live in?"),
             ("carol/c0", "What is the user's job?"),
             ("alice/c0", "What pet was adopted?"),
             ("mallory/c0", "anything at all?")]
    batched = svc.retrieve_batch(batch)
    sequential = [svc.retrieve(ns, q) for ns, q in batch]
    assert len(batched) == len(sequential) == len(batch)
    for got, want in zip(batched, sequential):
        assert [t.text() for t in got.triples] == \
            [t.text() for t in want.triples]
        assert [s.render() for s in got.summaries] == \
            [s.render() for s in want.summaries]
        assert got.text == want.text
        assert got.token_count == want.token_count


def test_retrieve_batch_empty_and_single():
    svc = _fill(_svc())
    assert svc.retrieve_batch([]) == []
    [ctx] = svc.retrieve_batch([("alice/c0", "Which city?")])
    assert ctx.triples


# -- eviction / tombstones -----------------------------------------------------

def test_evict_superseded_removes_old_conflicting_version():
    svc = _svc()
    svc.record("a/c0", "s0", _session(["I work as a nurse."], ts=1.0))
    svc.record("a/c0", "s1", _session(["I work as a chef."], ts=2.0))
    assert svc.stats()["alive_rows"] == 2
    n = svc.evict_superseded("a/c0")
    assert n == 1
    st = svc.stats()
    assert st["alive_rows"] == 1 and st["tombstones"] == 1
    ctx = svc.retrieve("a/c0", "What is the user's job?")
    objs = [t.object for t in ctx.triples]
    assert "chef" in objs and "nurse" not in objs
    # idempotent: nothing left to evict
    assert svc.evict_superseded("a/c0") == 0
    # physically gone: the tombstoned vector row is zeroed
    assert svc.vindex.n_dead == 1
    dead = np.where(~svc.vindex.alive())[0]
    assert (svc.vindex.bank[dead] == 0).all()


def test_evict_namespace_drops_tenant_but_not_others():
    svc = _fill(_svc())
    before = svc.stats()["alive_rows"]
    n = svc.evict("bob/c0")
    assert n > 0
    st = svc.stats()
    assert st["alive_rows"] == before - n
    assert "bob/c0" not in st["per_namespace"]
    assert svc.retrieve("bob/c0", "Which city?").triples == []
    # other tenants unaffected
    ctx = svc.retrieve("alice/c0", "Which city does the user live in?")
    assert any(t.object == "tallinn" for t in ctx.triples)
    # a re-created namespace starts clean (old rows stay tombstoned)
    svc.record("bob/c0", "s9", _session(["I live in Sapporo."], speaker="Bob"))
    ctx = svc.retrieve("bob/c0", "Which city does the user live in?")
    objs = {t.object for t in ctx.triples}
    assert "sapporo" in objs and "porto" not in objs


# -- SDK on the service ---------------------------------------------------------

def test_memori_client_runs_on_namespace_view():
    svc = _svc()
    seen = []

    def llm(prompt):
        seen.append(prompt)
        return "ok"

    client = MemoriClient(llm, svc.namespace("u1/c0"))
    client.chat("My favorite food is ramen.", timestamp=1.0)
    client.end_session()
    client.chat("Do you remember my favorite food?")
    assert "ramen" in seen[-1].lower()
    other = MemoriClient(llm, svc.namespace("u2/c0"))
    other.chat("Do you remember my favorite food?")
    assert "ramen" not in seen[-1].lower(), "memory leaked across namespaces"


def test_namespace_view_warns_when_conversation_scopes_merge():
    svc = _svc()
    view = svc.namespace("u1/c0")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        view.record_session("c0", "s0", _session(["I live in Oslo."]))
        view.record_session("c0", "s1", _session(["I own a canoe."]))
    with pytest.warns(UserWarning, match="separate"):
        view.record_session("c1", "s2", _session(["I collect stamps."]))


def test_service_stats_shape():
    svc = _fill(_svc())
    st = svc.stats()
    assert st["namespaces"] == 3
    assert st["bank_rows"] == st["alive_rows"] == st["bm25_docs"]
    assert st["per_namespace"]["alice/c0"]["triples"] > 0
    assert svc.namespace("alice/c0").stats()["triples"] > 0


# -- index-layer primitives ------------------------------------------------------

def test_vector_index_delete_excludes_tombstones_exactly():
    rng = np.random.default_rng(0)
    vi = VectorIndex(dim=16, use_kernel=False)
    vecs = rng.standard_normal((20, 16)).astype(np.float32)
    vi.add(vecs)
    dead = [0, 3, 7, 19]
    assert vi.delete(dead) == 4
    assert vi.delete(dead) == 0          # idempotent
    assert vi.n_alive == 16 and vi.n_dead == 4
    q = rng.standard_normal((3, 16)).astype(np.float32)
    s, ids = vi.search(q, k=5)
    assert not (set(np.asarray(ids).ravel().tolist()) & set(dead))
    # exact: equals brute force over the alive rows only
    alive = np.setdiff1d(np.arange(20), dead)
    dots = q @ vecs[alive].T
    for r in range(3):
        want = alive[np.argsort(-dots[r], kind="stable")[:5]]
        np.testing.assert_array_equal(np.asarray(ids)[r], want)


def test_vector_index_kernel_search_after_delete_pads_with_sentinels():
    """Regression (single-tenant route to the masked-kernel ghost bug): once
    delete() leaves fewer alive rows than k in a bank spanning several kernel
    blocks, search must pad with -1, not duplicate the alive ids."""
    rng = np.random.default_rng(1)
    vi = VectorIndex(dim=8, use_kernel=True)
    vi.add(rng.standard_normal((600, 8)).astype(np.float32))
    vi.delete(np.arange(3, 600))          # 3 alive rows, 2 bank blocks of 512
    s, ids = vi.search(rng.standard_normal((2, 8)).astype(np.float32), k=8)
    ids = np.asarray(ids)
    for r in range(2):
        assert sorted(ids[r][:3].tolist()) == [0, 1, 2]
        assert (ids[r][3:] == -1).all()


def test_rrf_fuse_counts_each_doc_once_per_ranking():
    """A duplicated id inside one ranking must not accumulate score — that
    amplification is exactly how upstream duplicate bugs distort fusion."""
    assert rrf_fuse([[5, 7, 5, 5, 5], [7]]) == rrf_fuse([[5, 7], [7]])
    # best (first) occurrence is the one that counts
    dup = dict(rrf_fuse([[3, 9, 3], [9]]))
    clean = dict(rrf_fuse([[3, 9], [9]]))
    assert dup[3] == clean[3] and dup[9] == clean[9]


def test_vector_index_delete_all_rows_safe():
    vi = VectorIndex(dim=8, use_kernel=False)
    vi.add(np.eye(4, 8, dtype=np.float32))
    vi.delete([0, 1, 2, 3])
    s, ids = vi.search(np.ones((1, 8), np.float32), k=3)
    assert (np.asarray(ids) == -1).all()


def test_bm25_namespace_scoping_matches_isolated_index():
    shared = BM25Index()
    solo = BM25Index()
    a_docs = ["alpha beta gamma", "beta beta delta", "gamma epsilon"]
    b_docs = ["alpha alpha alpha", "zeta eta"]
    ids_a = shared.add(a_docs, namespace=0)
    shared.add(b_docs, namespace=1)
    solo.add(a_docs)
    for q in ["alpha beta", "gamma", "zeta"]:
        s_shared, i_shared = shared.topk(q, k=5, namespace=0)
        s_solo, i_solo = solo.topk(q, k=5)
        # scoped ranking == isolated index's ranking, with global doc ids
        np.testing.assert_allclose(s_shared, s_solo, rtol=1e-5)
        np.testing.assert_array_equal(i_shared,
                                      np.asarray(ids_a)[i_solo])
        assert set(i_shared.tolist()) <= set(ids_a)


def test_bm25_device_side_compact_matches_fresh_index():
    """compact() on a warm index repacks the device doc block in place
    (donated gather, no re-upload): scoring afterwards must equal a fresh
    index built from the surviving docs."""
    idx = BM25Index()
    idx.add(["apple pie", "banana split", "apple tart", "cherry cake"],
            namespace=[0, 0, 1, 0])
    idx.topk("apple", k=4, namespace=0)       # warm the device buffers
    idx.remove([1])
    assert idx._docs_dev is not None
    idx.compact()                             # device-side repack path
    fresh = BM25Index()
    fresh.add(["apple pie", "apple tart", "cherry cake"],
              namespace=[0, 1, 0])
    for q in ["apple", "cherry cake", "banana"]:
        for ns in (None, 0, 1):
            s1, i1 = idx.topk(q, k=4, namespace=ns)
            s2, i2 = fresh.topk(q, k=4, namespace=ns)
            np.testing.assert_allclose(s1, s2, rtol=1e-6)
            np.testing.assert_array_equal(i1, i2)


def test_bm25_remove_tombstones_docs():
    idx = BM25Index()
    idx.add(["apple pie", "apple tart", "banana split"])
    assert idx.remove([0]) == 1 and idx.remove([0]) == 0
    assert idx.alive_count == 2 and len(idx) == 3
    _, ids = idx.topk("apple", k=3)
    assert 0 not in ids.tolist() and 1 in ids.tolist()


def test_triple_store_superseded_ids():
    store = TripleStore()
    store.add(Triple("a", "works as", "nurse", timestamp=1.0))
    keep = store.add(Triple("a", "works as", "chef", timestamp=2.0))
    store.add(Triple("a", "lives in", "porto", timestamp=1.0))
    sup = store.superseded_ids()
    assert sup == [0]
    assert store.latest_for_key("a|works as").object == "chef"
    assert keep not in sup
