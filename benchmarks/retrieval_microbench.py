"""Retrieval hot-path microbenchmark.

Two modes:

* quick (default; what `benchmarks/run.py` invokes): the original
  kernel-vs-oracle wall-clock rows on growing bank sizes plus the v5e
  roofline terms (CPU wall-clock is indicative only — EXPERIMENTS.md
  §Roofline has the TPU numbers).

* steady (`--steady`): the device-resident engine acceptance benchmark.
  A bank of `--rows` rows is grown one append at a time while a batch of
  tenant queries is answered after every append — the serving pattern.
  Two implementations of the same read path are timed (warmup first, then
  `block_until_ready` timing):

    - host-roundtrip: the pre-engine code path, faithfully preserved —
      host numpy bank, per-call `jnp.asarray(bank)` upload, per-call
      row-namespace rebuild from a Python list, eager masked-oracle
      scoring;
    - device-resident: `VectorIndex.search_batch` — capacity-padded device
      buffers updated in place, cached device labels, one stable-shape
      jitted launch with the live-row count as a traced scalar.

  A compile counter (jax_log_compiles capture) runs over the growth window
  and the benchmark ASSERTS zero recompiles for the device path while the
  bank grows within one power-of-two capacity bucket.

    PYTHONPATH=src python benchmarks/retrieval_microbench.py --steady
        [--rows 65000] [--batch 8] [--iters 5] [--json BENCH_retrieval.json]
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import count_compiles
from repro.core.vector_index import VectorIndex
from repro.kernels import ops, ref as kref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

D = 256


class HostRoundtripIndex:
    """The pre-engine read path, kept verbatim for comparison: the bank
    lives in host numpy, every search re-uploads it (`jnp.asarray`) and
    rebuilds the row->namespace array from a Python list, and the masked
    oracle runs eagerly (the use_kernel=False service configuration)."""

    def __init__(self, dim: int, capacity: int = 1024):
        self.dim, self.n = dim, 0
        self._bank = np.zeros((capacity, dim), np.float32)
        self._row_ns: list = []

    def add(self, vecs, ns):
        m = vecs.shape[0]
        while self.n + m > self._bank.shape[0]:
            self._bank = np.concatenate(
                [self._bank, np.zeros_like(self._bank)], axis=0)
        self._bank[self.n: self.n + m] = vecs
        self._row_ns.extend(int(x) for x in np.broadcast_to(ns, (m,)))
        self.n += m

    def search(self, queries, q_ns, k: int):
        bank = jnp.asarray(self._bank[: self.n])          # per-call upload
        row_ns = np.asarray(self._row_ns, np.int32)       # per-call rebuild
        s, i = kref.topk_mips_masked_ref(
            jnp.asarray(queries), bank, jnp.asarray(q_ns, jnp.int32),
            jnp.asarray(row_ns), k=k)
        return s, i


def _grow_and_search_loop(add_fn, search_fn, rows_per_iter: int, iters: int,
                          warmup: int = 2):
    """The serving pattern: append, then answer a query batch.  Returns
    seconds/iteration (device work fenced by block_until_ready)."""
    for _ in range(warmup):
        add_fn()
        search_fn()[1].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        add_fn()
        out = search_fn()
    out[1].block_until_ready()
    return (time.perf_counter() - t0) / iters


def run_steady(csv_rows, rows: int = 65000, batch: int = 8, iters: int = 5,
               k: int = 64, n_tenants: int = 32, json_out=None):
    print(f"\n# Retrieval steady state — device-resident engine vs "
          f"host-roundtrip path (N={rows}, B={batch}, k={k}, D={D}, CPU)")
    rng = np.random.default_rng(0)
    base = rng.standard_normal((rows, D)).astype(np.float32)
    base_ns = (np.arange(rows) % n_tenants).astype(np.int32)
    q = rng.standard_normal((batch, D)).astype(np.float32)
    q_ns = (np.arange(batch) % n_tenants).astype(np.int32)
    new_row = rng.standard_normal((1, D)).astype(np.float32)

    legacy = HostRoundtripIndex(D)
    legacy.add(base, base_ns)
    t_host = _grow_and_search_loop(
        lambda: legacy.add(new_row, [0]),
        lambda: legacy.search(q, q_ns, k), 1, iters)

    vi = VectorIndex(dim=D, use_kernel=False)
    vi.add(base, ns=base_ns)
    cap = vi.capacity
    assert vi.n + iters + 8 <= cap, \
        f"growth window {iters + 8} would cross the {cap} capacity bucket"
    t_dev = _grow_and_search_loop(
        lambda: vi.add(new_row, ns=[0]),
        lambda: vi.search_batch(q, q_ns, k=k), 1, iters)

    # zero-recompile assertion across further growth within the bucket
    with count_compiles() as cc:
        for _ in range(4):
            vi.add(new_row, ns=[0])
            _, i = vi.search_batch(q, q_ns, k=k)
        i.block_until_ready()
    if cc.count:
        raise AssertionError(
            f"device-resident search recompiled {cc.count}x while the bank "
            f"grew inside the {cap}-row capacity bucket: {cc.msgs[:3]}")

    speedup = t_host / t_dev
    print(f"rows {rows:7d} (capacity {cap}): host-roundtrip "
          f"{t_host*1e3:8.1f}ms/iter | device-resident {t_dev*1e3:8.1f}ms/iter"
          f" | speedup {speedup:5.2f}x | recompiles during growth: 0")
    csv_rows.append((f"retrieval/steady_N{rows}", t_dev * 1e6,
                     f"{speedup:.2f}x vs host-roundtrip"))
    if json_out is not None:
        json_out.append({
            "rows": rows, "capacity": cap, "batch": batch, "k": k,
            "t_host_roundtrip_ms": t_host * 1e3,
            "t_device_resident_ms": t_dev * 1e3,
            "speedup": speedup,
            "grow_steps_checked": 4, "recompiles": cc.count,
        })
    return csv_rows


def run_quick(csv_rows):
    print("\n# Retrieval microbench — fused topk_mips vs jnp oracle")
    key = jax.random.PRNGKey(0)
    K = 32
    for N in (1024, 8192, 32768):
        q = jax.random.normal(key, (64, D))
        bank = jax.random.normal(jax.random.fold_in(key, 1), (N, D))
        t_ref = _time(lambda a, b: kref.topk_mips_ref(a, b, k=K), q, bank)
        flops = 2 * 64 * N * D
        bytes_ = (64 * D + N * D) * 4
        # v5e roofline for this op (exact MIPS is bandwidth-bound at Q=64)
        t_compute = flops / PEAK_FLOPS_BF16
        t_mem = bytes_ / HBM_BW
        print(f"N={N:6d}: jnp_ref {t_ref*1e6:9.0f}us/call | v5e roofline "
              f"compute {t_compute*1e6:6.2f}us, memory {t_mem*1e6:6.2f}us "
              f"(bound: {'memory' if t_mem > t_compute else 'compute'})")
        csv_rows.append((f"retrieval/topk_N{N}", t_ref * 1e6,
                         f"{t_mem*1e6:.2f}"))
    return csv_rows


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out[0].block_until_ready()
    return (time.time() - t0) / iters


def run(csv_rows, steady: bool = False, rows: int = 65000, batch: int = 8,
        iters: int = 5, json_path=None):
    report = {"steady_state": []}
    if steady:
        run_steady(csv_rows, rows=rows, batch=batch, iters=iters,
                   json_out=report["steady_state"])
    else:
        run_quick(csv_rows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\nwrote {json_path}")
    return csv_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steady", action="store_true",
                    help="steady-state device-resident vs host-roundtrip "
                         "comparison + zero-recompile assertion")
    ap.add_argument("--rows", type=int, default=65000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_retrieval.json artifact")
    args = ap.parse_args()
    run([], steady=args.steady, rows=args.rows, batch=args.batch,
        iters=args.iters, json_path=args.json)
