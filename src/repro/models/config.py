"""ModelConfig: a single config dataclass spanning the whole model zoo
(dense / MoE / SSM / hybrid / enc-dec audio / VLM) plus the layer-plan
machinery that turns a per-layer kind list into scannable segments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2
    # dispatch = "global": one global capacity ranking + scatter (baseline —
    # simple, but SPMD materialises cross-shard traffic for the buffers).
    # dispatch = "local": per-data-shard ranking/capacity with vmap'd local
    # scatter; only the (E, cap, d) buffers cross chips (the true all-to-all).
    # See EXPERIMENTS.md §Perf.
    dispatch: str = "global"
    # number of data shards the local dispatch assumes (set by the launcher
    # to mesh batch-axis size; 1 == degenerate/local single shard)
    local_shards: int = 1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 128
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    width: int = 0            # 0 => d_model
    conv_width: int = 4
    local_window: int = 2048
    c_exponent: float = 8.0   # the RG-LRU "c" constant


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // num_heads
    source: str = ""                  # citation bracket from the assignment

    # Attention flavour ------------------------------------------------------
    attention: str = "causal"         # causal | sliding | prefix_lm
    sliding_window: int = 0           # 0 => full
    rope_theta: float = 10000.0
    rope_pct: float = 1.0             # partial rotary (stablelm = 0.25)
    qkv_bias: bool = False
    qk_norm: bool = False
    use_mla: bool = False
    mla: MLAConfig = MLAConfig()

    # Block pattern ----------------------------------------------------------
    # kinds: "attn" | "ssm" | "rglru" (rglru layers use local attention when
    # the pattern says "attn" in a hybrid). FFN kind is attached per layer.
    hybrid_period: int = 0            # recurrentgemma: every Nth layer = attn
    first_k_dense: int = 0            # deepseek: first k layers use dense FFN

    # Norm / MLP -------------------------------------------------------------
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu | gelu
    mlp_gated: bool = True
    tie_embeddings: bool = False
    parallel_residual: bool = False   # stablelm-style parallel attn+mlp

    # MoE / SSM / RG-LRU -----------------------------------------------------
    use_moe: bool = False
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    rglru: RGLRUConfig = RGLRUConfig()

    # Multi-token prediction (deepseek-v3) ------------------------------------
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.3

    # §Perf variants -----------------------------------------------------------
    # MLA absorbed-form attention in train/prefill too (never materialise the
    # decompressed (B,S,H,Dqk) K — trades score FLOPs for bytes).
    mla_absorbed_train: bool = False
    # Quantised KV cache for decode ("int8" or "" = compute dtype).
    kv_cache_quant: str = ""

    # Encoder-decoder (whisper) ----------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500       # whisper: 30s of audio -> 1500 frames
    # VLM (paligemma) ---------------------------------------------------------
    num_image_tokens: int = 0         # >0 => prefix-LM over image embeddings

    # Long-context policy -----------------------------------------------------
    # For full-attention archs, long_500k decode runs with this window (the
    # documented sliding-window variant); 0 = arch is natively sub-quadratic
    # or long_500k is skipped (see DESIGN.md §9).
    long_context_window: int = 8192
    supports_long_context: bool = True

    # Numerics ----------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logits_dtype: str = "float32"

    # Dry-run probe mode: unroll scanned segments so XLA cost analysis counts
    # every layer (used by launch/roofline.py probes; see EXPERIMENTS.md).
    force_unroll: bool = False

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.num_heads))

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """Per-layer (mixer_kind, ffn_kind) for the decoder stack."""
        kinds = []
        for i in range(self.num_layers):
            if self.arch_type == "ssm":
                mixer = "ssm"
            elif self.hybrid_period > 0:
                mixer = "attn" if (i % self.hybrid_period == self.hybrid_period - 1) else "rglru"
            else:
                mixer = "attn"
            if self.use_moe and i >= self.first_k_dense:
                ffn = "moe"
            elif self.d_ff > 0 or (self.use_moe and i < self.first_k_dense):
                ffn = "mlp"
            else:
                ffn = "none"   # mamba2: the block IS the mixer
            kinds.append((mixer, ffn))
        return tuple(kinds)

    def reduced(self, *, layers: int = 2, d_model: int = 256, experts: int = 4,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (mandated: <=2 layers,
        d_model<=512, <=4 experts)."""
        heads = max(2, min(4, self.num_heads))
        kvh = max(1, min(heads, self.num_kv_heads if self.num_kv_heads < self.num_heads else heads))
        changes = dict(
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kvh,
            head_dim=d_model // heads,
            d_ff=0 if self.d_ff == 0 else d_model * 2,
            vocab_size=vocab,
            encoder_layers=min(self.encoder_layers, layers),
            encoder_seq_len=min(self.encoder_seq_len, 32),
            num_image_tokens=min(self.num_image_tokens, 16),
            first_k_dense=min(self.first_k_dense, 1),
            mtp_depth=min(self.mtp_depth, 1),
            hybrid_period=min(self.hybrid_period, 3) if self.hybrid_period else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.use_moe:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(experts, self.moe.num_experts),
                experts_per_token=min(2, self.moe.experts_per_token),
                d_ff_expert=d_model * 2,
            )
        if self.use_mla:
            changes["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32)
            changes["head_dim"] = 0
        if self.arch_type == "ssm" or self.hybrid_period:
            changes["ssm"] = dataclasses.replace(self.ssm, state_dim=16, head_dim=16, chunk_size=16)
            changes["rglru"] = dataclasses.replace(self.rglru, width=0, local_window=16)
        return dataclasses.replace(self, **changes)

    # Parameter count (analytic; used for MODEL_FLOPS = 6 N D) ---------------
    def param_count(self, active_only: bool = False) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.resolved_head_dim
        n = 0
        emb = self.vocab_size * d
        n += emb if self.tie_embeddings else 2 * emb
        for mixer, ffn in self.layer_kinds():
            if mixer == "attn":
                if self.use_mla:
                    m = self.mla
                    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    n += d * m.q_lora_rank + m.q_lora_rank * h * qk_hd
                    n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    n += m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
                    n += h * m.v_head_dim * d
                else:
                    n += d * h * hd + 2 * d * kv * hd + h * hd * d
            elif mixer == "ssm":
                di = self.ssm.expand * d
                nh = di // self.ssm.head_dim
                bc = 2 * self.ssm.n_groups * self.ssm.state_dim
                n += d * (2 * di + bc + nh)        # in_proj (z,x,B,C,dt)
                n += (di + bc) * self.ssm.conv_width
                n += di * d                         # out_proj
                n += 2 * nh                         # A_log, D
            elif mixer == "rglru":
                w = self.rglru.width or d
                n += d * 2 * w + w * d              # in/out proj
                n += w * self.rglru.conv_width
                n += 2 * w + 2 * w * w // 1         # gates (diag-ish; approx block)
            if ffn == "mlp":
                ff = self.d_ff
                n += d * ff * (3 if self.mlp_gated else 2)
            elif ffn == "moe":
                e = self.moe.experts_per_token if active_only else self.moe.num_experts
                ff = self.moe.d_ff_expert or self.d_ff
                n += (e + self.moe.num_shared_experts) * d * ff * (3 if self.mlp_gated else 2)
                n += d * self.moe.num_experts       # router
        if self.is_encoder_decoder:
            # encoder self-attn + mlp, decoder cross-attn
            enc = self.encoder_layers * (d * h * hd + 2 * d * kv * hd + h * hd * d
                                         + d * self.d_ff * (3 if self.mlp_gated else 2))
            cross = self.num_layers * (d * h * hd + 2 * d * kv * hd + h * hd * d)
            n += enc + cross
        return n


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def plan_segments(kinds: Tuple) -> Tuple[Tuple[Tuple, int], ...]:
    """Partition a per-layer kind list into (period_kinds, repeats) segments,
    greedily maximising scanned coverage.  Homogeneous stacks -> one segment;
    recurrentgemma's (r, r, a)*12 + (r, r) -> two segments; deepseek's
    3 dense + 58 moe -> two segments."""
    segments = []
    i, n = 0, len(kinds)
    while i < n:
        # Prefer genuinely repeating patterns (r >= 2); a period-p segment
        # with r == 1 is just p unrolled layers and blocks a better scan of
        # the suffix (e.g. deepseek: 3 dense then 58 scanned moe layers).
        best_p, best_r = 1, 1
        for p in range(1, min(8, (n - i) // 2) + 1):
            pat = kinds[i:i + p]
            r = 1
            while i + (r + 1) * p <= n and kinds[i + r * p: i + (r + 1) * p] == pat:
                r += 1
            if r >= 2 and (r * p > best_p * best_r
                           or (r * p == best_p * best_r and p < best_p)):
                best_p, best_r = p, r
        segments.append((kinds[i:i + best_p], best_r))
        i += best_p * best_r
    assert sum(len(p) * r for p, r in segments) == n
    return tuple(segments)
