"""Config registry: one module per assigned architecture (+ the paper's own
serving/embedding configs).  ``get_config(arch_id)`` resolves the exact
assignment ids (e.g. "phi3.5-moe-42b-a6.6b")."""
from __future__ import annotations

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401


def _load(modname: str):
    import importlib
    return importlib.import_module(f"repro.configs.{modname}").get_config


_REGISTRY = {
    "stablelm-3b": "stablelm_3b",
    "mamba2-2.7b": "mamba2_2p7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2.5-14b": "qwen2_5_14b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "qwen3-8b": "qwen3_8b",
    "whisper-small": "whisper_small",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "internlm2-1.8b": "internlm2_1p8b",
    "paligemma-3b": "paligemma_3b",
    "memori-agent": "memori_agent",
    "memori-embedder": "memori_embedder",
}

ASSIGNED_ARCHS = tuple(k for k in _REGISTRY if not k.startswith("memori-"))


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _load(_REGISTRY[arch_id])()


def list_archs():
    return sorted(_REGISTRY)
