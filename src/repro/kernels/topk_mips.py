"""Fused top-k maximum-inner-product search over the Memori triple bank.

This is the TPU-native replacement for the paper's FAISS index (DESIGN.md
§3): the embedding bank is streamed HBM→VMEM in (block_n, D) tiles, scored
against the resident query tile on the MXU, and a running top-k (scores +
global indices) is maintained in the revisited output block across the
sequential bank-block grid dimension.

Exact search is deliberate: Advanced Augmentation compresses dialogue to
~10⁶-scale triples, small enough that exact MIPS beats pointer-chasing ANN
structures on TPU.

Grid: (num_q_blocks, num_bank_blocks)   — bank dim innermost/sequential.
Per-step top-k merge is an unrolled k-iteration argmax sweep (Pallas-TPU
friendly: no sort, no scatter).

Multi-tenant extension: when per-query and per-bank-row namespace ids are
supplied, cross-namespace hits are masked to NEG_INF *before* the top-k
merge, so one kernel launch serves a whole batch of tenants against one
packed bank (the MemoryService batched-retrieval path).  Rows with
namespace -1 are tombstones and match no query.  Without namespaces the
original kernel runs unchanged.

Stable-shape contract (the device-resident retrieval engine): the number of
valid bank rows rides along as a *traced* SMEM scalar, never a trace-time
constant.  Callers may hand in a capacity-padded bank (rows >= n_valid are
garbage) and grow `n_valid` append after append without triggering a single
recompile — the executable is keyed only on the padded shapes, which the
VectorIndex changes exclusively at power-of-two capacity boundaries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _merge_topk(scores_ref, idx_ref, s, col, k: int):
    """Merge block scores s (Qb, Nb) with the running (Qb, k) top-k refs."""
    all_s = jnp.concatenate([scores_ref[...], s], axis=1)
    all_i = jnp.concatenate([idx_ref[...], col], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, all_s.shape, 1)
    for j in range(k):
        m = jnp.max(all_s, axis=1)
        am = jnp.argmax(all_s, axis=1)
        hit = cols == am[:, None]
        sel_i = jnp.sum(jnp.where(hit, all_i, 0), axis=1)
        scores_ref[:, j] = m
        # once a query's candidates are exhausted, every remaining max is the
        # NEG_INF sentinel and argmax degenerates to column 0 — whose all_i
        # entry is a previously-selected index at grid steps nb > 0.  Emit -1
        # instead (matching the oracle); real dot products never reach the
        # sentinel, so live slots are unaffected.
        idx_ref[:, j] = jnp.where(m > NEG_INF / 2, sel_i, -1)
        all_s = jnp.where(hit, NEG_INF, all_s)


def _kernel(nvalid_ref, q_ref, bank_ref, scores_ref, idx_ref, *, block_n: int,
            k: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        scores_ref[...] = jnp.full_like(scores_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...]
    b = bank_ref[...]
    s = jax.lax.dot_general(q, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Qb, Nb)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + nb * block_n
    s = jnp.where(col < nvalid_ref[0], s, NEG_INF)  # mask padded bank rows
    _merge_topk(scores_ref, idx_ref, s, col, k)


def _kernel_masked(nvalid_ref, q_ref, bank_ref, qns_ref, bns_ref, scores_ref,
                   idx_ref, *, block_n: int, k: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        scores_ref[...] = jnp.full_like(scores_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...]
    b = bank_ref[...]
    s = jax.lax.dot_general(q, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Qb, Nb)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + nb * block_n
    # (Qb, 1) == (1, Nb) broadcast: a hit survives only within its namespace
    ok = (col < nvalid_ref[0]) & (qns_ref[...] == bns_ref[...])
    s = jnp.where(ok, s, NEG_INF)
    _merge_topk(scores_ref, idx_ref, s, col, k)


def topk_mips(queries, bank, k: int = 32, *, n_valid=None, q_ns=None,
              bank_ns=None, block_q: int = 128, block_n: int = 512,
              interpret: bool = False):
    """queries (Q, D) · bank (N, D) -> (scores (Q, k) f32, indices (Q, k) i32).

    `n_valid` (traced i32 scalar, default N) bounds the live bank prefix:
    rows >= n_valid never appear (NEG_INF score, index -1 if nothing live
    fills the slot).  Passing a capacity-padded bank plus a traced n_valid
    keeps the compiled executable stable while the bank grows.

    Optional namespace mask: q_ns (Q,) i32 and bank_ns (N,) i32 (both or
    neither).  Bank rows whose namespace differs from the query's score
    NEG_INF and keep index -1 if nothing in-namespace fills the slot; q_ns
    must be >= 0, bank_ns == -1 marks tombstoned rows."""
    Q, D = queries.shape
    N = bank.shape[0]
    if n_valid is None:
        n_valid = N
    nv = jnp.asarray(n_valid, jnp.int32).reshape(1)
    bq = min(block_q, max(8, Q))
    bn = min(block_n, max(8, N))
    Qp = -(-Q // bq) * bq
    Np = -(-N // bn) * bn
    qp = jnp.pad(queries, ((0, Qp - Q), (0, 0)))
    bp = jnp.pad(bank, ((0, Np - N), (0, 0)))

    grid = (Qp // bq, Np // bn)
    nv_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    out_specs = [
        pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((Qp, k), jnp.float32),
        jax.ShapeDtypeStruct((Qp, k), jnp.int32),
    ]
    if q_ns is None and bank_ns is None:
        scores, idx = pl.pallas_call(
            functools.partial(_kernel, block_n=bn, k=k),
            grid=grid,
            in_specs=[
                nv_spec,
                pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(nv, qp, bp)
        return scores[:Q], idx[:Q]
    assert q_ns is not None and bank_ns is not None, \
        "q_ns and bank_ns must be given together"
    # namespace ids ride along as 2-D blocks: (Qp, 1) column / (1, Np) row
    qns = jnp.pad(jnp.asarray(q_ns, jnp.int32), (0, Qp - Q),
                  constant_values=-1).reshape(Qp, 1)
    bns = jnp.pad(jnp.asarray(bank_ns, jnp.int32), (0, Np - N),
                  constant_values=-2).reshape(1, Np)
    scores, idx = pl.pallas_call(
        functools.partial(_kernel_masked, block_n=bn, k=k),
        grid=grid,
        in_specs=[
            nv_spec,
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(nv, qp, bp, qns, bns)
    return scores[:Q], idx[:Q]
