"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def topk_mips_ref(queries, bank, k: int = 32, n_valid=None):
    """queries (Q,D), bank (N,D) -> (scores (Q,k) f32, indices (Q,k) i32).
    With `n_valid` (traced i32 scalar), rows >= n_valid are padding: they
    score NEG_INF and report index -1 — matching the kernel's stable-shape
    contract over capacity-padded banks."""
    s = jnp.einsum("qd,nd->qn", queries.astype(jnp.float32),
                   bank.astype(jnp.float32))
    if n_valid is not None:
        col = jnp.arange(bank.shape[0], dtype=jnp.int32)[None, :]
        s = jnp.where(col < n_valid, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    if n_valid is not None:
        idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def topk_mips_masked_ref(queries, bank, q_ns, bank_ns, k: int = 32,
                         n_valid=None):
    """Namespace-masked MIPS oracle: cross-namespace scores become NEG_INF
    and their indices -1 (matching the kernel, whose running top-k never
    admits a masked column).  q_ns (Q,) i32 >= 0; bank_ns (N,) i32 with -1
    marking tombstoned rows.  `n_valid` bounds the live bank prefix of a
    capacity-padded bank, as in topk_mips_ref."""
    s = jnp.einsum("qd,nd->qn", queries.astype(jnp.float32),
                   bank.astype(jnp.float32))
    ok = jnp.asarray(q_ns, jnp.int32)[:, None] == \
        jnp.asarray(bank_ns, jnp.int32)[None, :]
    if n_valid is not None:
        col = jnp.arange(bank.shape[0], dtype=jnp.int32)[None, :]
        ok = ok & (col < n_valid)
    s = jnp.where(ok, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None):
    """q: (B,K,G,S,D); k,v: (B,K,T,D) -> (B,K,G,S,D)."""
    B, K, G, S, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bkgsd,bktd->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window > 0:
        ok = ok & (k_pos > q_pos - window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len, *, scale=None, window: int = 0):
    """q: (B,K,G,D); k,v: (B,K,T,D); kv_len (B,) -> (B,K,G,D)."""
    B, K, G, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(T)[None, None, None, :]
    kl = kv_len[:, None, None, None]
    ok = pos < kl
    if window > 0:
        ok = ok & (pos > kl - 1 - window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
