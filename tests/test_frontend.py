"""HTTP serving surface (serving/frontend.py) end to end: a real
ThreadingHTTPServer over a real service + scheduler, driven through
urllib — record -> retrieve -> stream round trips, api-key tenancy
isolation, the error contract (401 / 400 / 404 / 429 + Retry-After), and
the SDK's HttpMemory client speaking the same wire format."""
import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.core import (AdmissionPolicy, MemoriClient, MemoryScheduler,
                        MemoryService, TenantPolicy)
from repro.core.embedder import HashEmbedder
from repro.core.sdk import AdmissionError, HttpMemory
from repro.serving.frontend import MemoryFrontend

EMB = HashEmbedder()
KEYS = {"key-acme": "acme", "key-beta": "beta"}


@pytest.fixture()
def frontend():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(svc, tick_interval_s=0.002, max_batch=16)
    fe = MemoryFrontend(svc, KEYS).start()
    yield fe
    fe.close()
    sched.close()


def _call(fe, path, body=None, key="key-acme", method=None):
    req = urllib.request.Request(
        fe.address + path,
        data=None if body is None else json.dumps(body).encode(),
        headers={"Authorization": f"Bearer {key}"},
        method=method or ("GET" if body is None else "POST"))
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, json.loads(r.read().decode()), r.headers
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode()), e.headers


def _record_body(city="Lisbon"):
    return {"namespace": "conv0", "session_id": "s0",
            "messages": [{"speaker": "U", "text": f"I live in {city}.",
                          "timestamp": 1.0},
                         {"speaker": "U", "text": "I work as a welder.",
                          "timestamp": 2.0}]}


# -- the acceptance path: record -> retrieve -> stream through real HTTP ------

def test_record_then_retrieve_round_trip(frontend):
    st, env, _ = _call(frontend, "/v1/record", _record_body())
    assert st == 200 and env["status"] == "ok"
    assert env["op"] == "record" and env["payload"]["flushed"]

    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0",
                        "query": "Which city does the user live in?"})
    assert st == 200 and env["status"] == "ok"
    pay = env["payload"]
    assert pay["kind"] == "retrieved_context"
    assert any("lisbon" in t["object"] for t in pay["triples"])
    assert pay["token_count"] == env["token_count"] > 0
    assert env["batch_size"] >= 1


def test_streaming_retrieve_ndjson(frontend):
    _call(frontend, "/v1/record", _record_body())
    req = urllib.request.Request(
        frontend.address + "/v1/retrieve",
        data=json.dumps({"namespace": "conv0", "stream": True,
                         "queries": [{"query": "Which city?"},
                                     {"query": "What job?"},
                                     {"query": "Any pets?"}]}).encode(),
        headers={"Authorization": "Bearer key-acme"})
    with urllib.request.urlopen(req, timeout=30) as r:
        assert r.headers["Content-Type"] == "application/x-ndjson"
        events = [json.loads(line) for line in r.read().decode().splitlines()
                  if line.strip()]
    assert events[0] == {"event": "accepted", "count": 3}
    results = [e for e in events if e["event"] == "result"]
    assert sorted(e["index"] for e in results) == [0, 1, 2]
    assert all(e["response"]["status"] == "ok" for e in results)
    assert events[-1]["event"] == "done" and events[-1]["errors"] == 0


def test_batch_retrieve_preserves_submission_order(frontend):
    _call(frontend, "/v1/record", _record_body())
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0",
                        "queries": [{"query": "city", "top_k": 1},
                                    {"query": "job"}]})
    assert st == 200 and len(env["responses"]) == 2
    assert all(r["status"] == "ok" for r in env["responses"])


# -- tenancy ------------------------------------------------------------------

def test_api_keys_isolate_tenants(frontend):
    _call(frontend, "/v1/record", _record_body("Quito"), key="key-acme")
    # beta uses the SAME namespace string but sees nothing of acme's
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0", "query": "Which city?"},
                       key="key-beta")
    assert st == 200
    assert env["payload"]["triples"] == []
    # and beta's evict of "conv0" cannot touch acme's rows
    st, env, _ = _call(frontend, "/v1/evict", {"namespace": "conv0"},
                       key="key-beta")
    assert st == 200 and env["payload"] == 0
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0", "query": "Which city?"},
                       key="key-acme")
    assert any("quito" in t["object"] for t in env["payload"]["triples"])


def test_unknown_key_is_401(frontend):
    st, env, _ = _call(frontend, "/v1/stats", key="nope")
    assert st == 401 and env["status"] == "error"


# -- error contract -----------------------------------------------------------

def test_bad_bodies_are_400(frontend):
    st, env, _ = _call(frontend, "/v1/record", {"namespace": "c"})
    assert st == 400 and "messages" in env["error"]
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"query": "q", "stages": ["bm42"]})
    assert st == 400 and "unknown retrieval stages" in env["error"]


def test_unknown_route_is_404(frontend):
    st, env, _ = _call(frontend, "/v1/nope", {})
    assert st == 404


def test_rate_limited_tenant_gets_429_with_retry_after():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(
        svc, tick_interval_s=0.002,
        admission=AdmissionPolicy(
            tenants={"acme": TenantPolicy(rate=0.001, burst=2)}))
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        for _ in range(2):
            st, _, _ = _call(fe, "/v1/retrieve",
                             {"namespace": "c", "query": "q"})
            assert st == 200
        st, env, headers = _call(fe, "/v1/retrieve",
                                 {"namespace": "c", "query": "q"})
        assert st == 429
        assert env["reason"] == "rate_limited"
        assert int(headers["Retry-After"]) >= 1
        assert env["retry_after_s"] > 0
        # beta is untouched by acme's limit
        st, _, _ = _call(fe, "/v1/retrieve",
                         {"namespace": "c", "query": "q"}, key="key-beta")
        assert st == 200
    finally:
        fe.close()
        sched.close()


# -- stats --------------------------------------------------------------------

def test_stats_reports_all_layers(frontend):
    _call(frontend, "/v1/record", _record_body())
    st, stats, _ = _call(frontend, "/v1/stats")
    assert st == 200
    assert stats["tenant"] == "acme"
    assert stats["service"]["bank_rows"] >= 1
    assert stats["scheduler"]["ticks"] >= 1
    assert "acme" in stats["scheduler"]["admission"]["tenants"]
    assert stats["frontend"]["requests"] >= 2


# -- SDK client over the wire -------------------------------------------------

def test_http_memory_client_round_trip(frontend):
    mem = HttpMemory(frontend.address, "key-acme", namespace="conv9")
    out = mem.record_session("conv9", "s0", [
        type("M", (), {"speaker": "U", "text": "I live in Osaka.",
                       "timestamp": 1.0})(),
        type("M", (), {"speaker": "U", "text": "I adopted a cat.",
                       "timestamp": 2.0})()])
    assert out["flushed"]
    ctx = mem.retrieve("Which city does the user live in?")
    assert any("osaka" in t.object for t in ctx.triples)
    assert ctx.token_count > 0
    prompt, ctx2 = mem.answer_prompt("Which city?")
    assert ctx2.text in prompt and "Which city?" in prompt
    # the full SDK wrapper composes over the HTTP transport unchanged
    client = MemoriClient(lambda p: "a reply", mem)
    assert client.chat("What pets do I have?") == "a reply"
    client.end_session()


def test_http_memory_raises_admission_error_on_429():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(
        svc, tick_interval_s=0.002,
        admission=AdmissionPolicy(
            tenants={"acme": TenantPolicy(rate=0.001, burst=1)}))
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        mem = HttpMemory(fe.address, "key-acme")
        mem.retrieve("q")
        with pytest.raises(AdmissionError) as ei:
            mem.retrieve("q")
        assert ei.value.reason == "rate_limited"
        assert ei.value.retry_after_s > 0
    finally:
        fe.close()
        sched.close()


# -- concurrency: many handler threads funnel into shared ticks ---------------

def test_concurrent_http_clients_share_scheduler_ticks(frontend):
    _call(frontend, "/v1/record", _record_body())
    n, errs = 24, []
    barrier = threading.Barrier(n)

    def worker():
        barrier.wait()
        st, env, _ = _call(frontend, "/v1/retrieve",
                           {"namespace": "conv0", "query": "Which city?"})
        if st != 200 or env["status"] != "ok":
            errs.append(env)

    threads = [threading.Thread(target=worker) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    st, stats, _ = _call(frontend, "/v1/stats")
    # batching happened: fewer launches than retrieves
    assert stats["scheduler"]["retrieve_launches"] \
        < stats["scheduler"]["retrieves"]


def _scrape(fe, key="key-acme"):
    req = urllib.request.Request(
        fe.address + "/v1/metrics",
        headers={"Authorization": f"Bearer {key}"})
    with urllib.request.urlopen(req, timeout=30) as r:
        return r.status, r.read().decode(), r.headers


def test_metrics_prometheus_exposition(frontend):
    _call(frontend, "/v1/record", _record_body())
    _call(frontend, "/v1/retrieve",
          {"namespace": "conv0", "query": "Which city?"})
    st, text, headers = _scrape(frontend)
    assert st == 200
    assert headers["Content-Type"].startswith("text/plain")
    lines = text.splitlines()
    samples = {}
    for ln in lines:
        if ln.startswith("#"):
            assert ln.startswith("# TYPE memori_") and ln.endswith(" gauge")
            continue
        name, val = ln.split(" ")
        float(val)                       # every sample parses as a number
        samples[name] = val
    # one sample line per TYPE line, no duplicates
    assert len(samples) == sum(1 for ln in lines if ln.startswith("#"))
    # the layers the dashboard needs are all present
    for want in ("memori_namespaces", "memori_bank_hot_rows",
                 "memori_bank_quant_searches",
                 "memori_scheduler_retrieves",
                 "memori_frontend_requests"):
        assert want in samples, f"missing {want}\n{sorted(samples)[:40]}"
    assert samples["memori_scheduler_retrieves"] == "1"
    assert int(samples["memori_frontend_requests"]) >= 2
    # quantization off in this fixture: the knob is still visible as 0
    assert samples["memori_bank_quantized"] == "0"


def test_metrics_requires_auth(frontend):
    req = urllib.request.Request(frontend.address + "/v1/metrics")
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=30)
    assert ei.value.code == 401


def test_metrics_reports_tier_counters():
    """With quantization + tiering mounted the scrape carries the tier
    gauges a capacity dashboard alerts on."""
    from repro.core.lifecycle import LifecyclePolicy
    from repro.core.tiering import TierPolicy
    svc = MemoryService(EMB, use_kernel=False, budget=800, quantize="int8",
                        policy=LifecyclePolicy(
                            tier=TierPolicy(max_hot_rows=4)))
    svc.runtime._stop.set()
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        _call(fe, "/v1/record", _record_body())
        svc.runtime.run_maintenance_once()
        _, text, _ = _scrape(fe)
        samples = dict(ln.split(" ") for ln in text.splitlines()
                       if not ln.startswith("#"))
        assert samples["memori_bank_quantized"] == "1"
        assert "memori_tiering_demotions" in samples
        assert "memori_tiering_hot_rows" in samples
        assert int(samples["memori_tiering_max_hot_rows"]) == 4
    finally:
        fe.close()
        svc.close(final_snapshot=False)
