"""AdamW + cosine schedule + global-norm clipping, dependency-free.

State dtype is configurable: fp32 moments by default; bf16 for the largest
dry-run configs (deepseek-v3), where 14 bytes/param of fp32 optimizer state
cannot physically fit 256 v5e chips — recorded honestly in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: OptimizerConfig, params: PyTree) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def global_norm(tree: PyTree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """Weight decay applies to matrices, not norms/biases/scalars."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return not any(s in name for s in ("scale", "bias", "norm", "lam",
                                       "A_log", "dt_bias", "D", "b"))


def update(cfg: OptimizerConfig, params: PyTree, grads: PyTree,
           state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state.mu, state.nu)
    # unzip the (param, mu, nu) triples (leaf = tuple of arrays)
    _is3 = lambda x: (isinstance(x, tuple) and len(x) == 3
                      and hasattr(x[0], "dtype"))
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=_is3)
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=_is3)
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=_is3)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics
