"""End-to-end driver: train the ~100M-parameter memori-agent LM for a few
hundred steps on the synthetic conversation stream, checkpoint it, and sample
from it.

    PYTHONPATH=src python examples/train_100m.py [--steps 200] [--small]

(--small trains the reduced config: CI-friendly minutes instead of hours on
this CPU-only container; the full 12L/768d config is the default.)
"""
import argparse
import os

import jax

from repro.checkpoint import io as ckpt
from repro.configs import get_config
from repro.data.pipeline import batches
from repro.data.tokenizer import HashTokenizer
from repro.models.model_api import Model
from repro.serving.engine import Engine
from repro.serving.sampler import SamplerConfig
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--out", default="artifacts/memori_agent.msgpack")
    args = ap.parse_args()

    cfg = get_config("memori-agent")
    if args.small:
        cfg = cfg.reduced(layers=2, d_model=128)
    model = Model(cfg)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashTokenizer(cfg.vocab_size)
    data = batches(args.batch, args.seq, tokenizer=tok)
    tc = TrainConfig(
        steps=args.steps, log_every=max(1, args.steps // 20),
        opt=opt.OptimizerConfig(peak_lr=6e-4, warmup_steps=args.steps // 10,
                                total_steps=args.steps))
    params, hist = train(model, params, data, tc,
                         log_fn=lambda s, m: print(
                             f"step {s:4d} ce={m['ce']:.3f} "
                             f"acc={m['accuracy']:.3f} lr={m['lr']:.2e} "
                             f"({m['wall']:.0f}s)"))

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    n = ckpt.save(args.out, params)
    print(f"checkpoint: {args.out} ({n/1e6:.1f} MB)")

    eng = Engine(model, params, max_len=args.seq, slots=2,
                 sampler=SamplerConfig(temperature=0.8, top_k=40),
                 tokenizer=tok)
    outs = eng.generate(["Caroline: My favorite food is",
                         "Ben: I went to"], max_new_tokens=12)
    for o in outs:
        print("sample:", o)


if __name__ == "__main__":
    main()
