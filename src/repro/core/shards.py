"""ShardedBank — shard-wise device placement of the memory bank.

The single-device `VectorIndex` packs rows in append order; this module
re-lays the LIVE rows out **shard-major** so the bank can be placed over a
device mesh and searched by the namespace-masked `sharded_topk` in one
launch.  Placement is namespace-affine — shard = ns_id % n_shards — so a
tenant's rows live together on one shard: losing a shard degrades a known
subset of tenants instead of a random subset of every tenant's memory, and
marking the shard down is one label-slab write.

Layout: shard `s` owns the slot range `[s*C, (s+1)*C)` for a uniform pow2
per-shard capacity `C`, so the flattened `(S*C, D)` bank divides evenly
over the mesh's bank axes (`common/partitioning.py` "bank" rules) and each
device holds whole shards' slabs.  The total device bank is `S*C` rows —
with S shards on S devices this is the "8x beyond single-device capacity"
shape: each device materializes only its `(C, D)` slab.

Three host arrays mirror the device state: the slab-packed bank, the
per-slot namespace labels (-1 = empty/tombstone), and the slot -> global
row map.  Search returns device (scores, slots); slots map back to global
row ids with one tiny O(Q*k) host gather — no device gather, no extra
collective, and the row-id space stays identical to the unsharded path.

Steady state mirrors the VectorIndex contract: appends scatter into live
device buffers in place (pow2-padded widths, bounded executables, no bank
re-upload), deletes scatter -1 labels, and only capacity growth or
compaction re-uploads.  A down shard is a `(C,)` label-slab write of -1 —
retrieval keeps answering from the surviving shards (the service stamps
those responses `degraded`); `mark_up` writes the real labels back.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2
from repro.core.vector_index import _search_device, sharded_topk

MIN_SHARD_CAPACITY = 64


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_scatter(bank, labels, slots, vecs, ns):
    return bank.at[slots].set(vecs), labels.at[slots].set(ns)


@functools.partial(jax.jit, donate_argnums=(0,))
def _dev_set_slab(labels, slab, start):
    return jax.lax.dynamic_update_slice(labels, slab, (start,))


class ShardedBank:
    def __init__(self, dim: int, n_shards: int, mesh=None,
                 use_kernel: bool = True):
        if n_shards < 2:
            raise ValueError("ShardedBank needs n_shards >= 2")
        self.dim = dim
        self.n_shards = int(n_shards)
        self.mesh = mesh
        self.use_kernel = use_kernel
        self.C = MIN_SHARD_CAPACITY          # per-shard slot capacity (pow2)
        self.down: Set[int] = set()
        # stale=True until rebuild(): the bank starts life re-derived from
        # the VectorIndex host mirror (the ground truth), and falls back to
        # stale after compaction re-packs the global row-id space
        self.stale = True
        self._alloc_host()
        self._slot_of_row = np.full((0,), -1, np.int64)
        self._count = np.zeros((self.n_shards,), np.int64)
        self._bank_dev = None
        self._labels_dev = None
        self._mesh_fns = {}                  # k -> jitted sharded_topk
        self.counters = {"rebuilds": 0, "grows": 0, "searches": 0}

    # -- host layout ---------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return self.n_shards * self.C

    def _alloc_host(self) -> None:
        self._bank_host = np.zeros((self.n_slots, self.dim), np.float32)
        self._labels_host = np.full((self.n_slots,), -1, np.int32)
        self._rows_host = np.full((self.n_slots,), -1, np.int32)

    def shard_of(self, ns_id: int) -> int:
        return int(ns_id) % self.n_shards

    def invalidate(self) -> None:
        """Global row ids moved (compaction) — the layout must be re-derived
        from the VectorIndex before the next search."""
        self.stale = True
        self._bank_dev = None
        self._labels_dev = None

    def rebuild(self, vindex) -> None:
        """Re-derive the shard-major layout from the index's host mirror:
        live rows only, packed per shard in global-row order (deterministic,
        so two replicas that replayed the same WAL lay out identically)."""
        n = vindex.n
        ns = np.asarray(vindex.row_namespaces(), np.int32)
        alive = np.asarray(vindex.alive(), bool) if n else \
            np.zeros((0,), bool)
        shard = ns % self.n_shards if n else np.zeros((0,), np.int64)
        counts = np.bincount(shard[alive], minlength=self.n_shards) if n \
            else np.zeros((self.n_shards,), np.int64)
        self.C = max(MIN_SHARD_CAPACITY,
                     next_pow2(int(counts.max()) if n else 0))
        self._alloc_host()
        self._slot_of_row = np.full((n,), -1, np.int64)
        self._count = np.zeros((self.n_shards,), np.int64)
        bank = vindex.bank
        for s in range(self.n_shards):
            rows = np.nonzero(alive & (shard == s))[0]
            cnt = rows.size
            if cnt:
                slots = s * self.C + np.arange(cnt)
                self._bank_host[slots] = bank[rows]
                self._labels_host[slots] = ns[rows]
                self._rows_host[slots] = rows
                self._slot_of_row[rows] = slots
            self._count[s] = cnt
        self.stale = False
        self._bank_dev = None
        self._labels_dev = None
        self.counters["rebuilds"] += 1

    def _grow(self, need: int) -> None:
        new_c = next_pow2(int(need))
        old_c, S = self.C, self.n_shards
        old_bank, old_labels, old_rows = (self._bank_host, self._labels_host,
                                          self._rows_host)
        self.C = new_c
        self._alloc_host()
        for s in range(S):
            cnt = int(self._count[s])
            if cnt:
                self._bank_host[s * new_c: s * new_c + cnt] = \
                    old_bank[s * old_c: s * old_c + cnt]
                self._labels_host[s * new_c: s * new_c + cnt] = \
                    old_labels[s * old_c: s * old_c + cnt]
                self._rows_host[s * new_c: s * new_c + cnt] = \
                    old_rows[s * old_c: s * old_c + cnt]
        live = self._slot_of_row >= 0
        old_slots = self._slot_of_row[live]
        self._slot_of_row[live] = (old_slots // old_c) * new_c \
            + old_slots % old_c
        self._bank_dev = None                # re-upload once per doubling
        self._labels_dev = None
        self.counters["grows"] += 1

    # -- writes --------------------------------------------------------------
    def append(self, rows, vecs, ns_ids) -> None:
        """Mirror a VectorIndex append into the shard layout.  No-op while
        stale (the next rebuild sees the rows in the host mirror anyway).
        Device buffers update in place with pow2-padded scatter widths."""
        if self.stale:
            return
        rows = np.asarray(rows, np.int64).ravel()
        if rows.size == 0:
            return
        vecs = np.asarray(vecs, np.float32).reshape(rows.size, self.dim)
        ns = np.asarray(ns_ids, np.int32).ravel()
        shard = ns % self.n_shards
        need = self._count + np.bincount(shard, minlength=self.n_shards)
        if int(need.max()) > self.C:
            self._grow(int(need.max()))
        slots = np.empty((rows.size,), np.int64)
        for s in range(self.n_shards):
            m = shard == s
            cnt = int(m.sum())
            if cnt:
                slots[m] = s * self.C + int(self._count[s]) + np.arange(cnt)
                self._count[s] += cnt
        self._bank_host[slots] = vecs
        self._labels_host[slots] = ns
        self._rows_host[slots] = rows
        hi = int(rows.max()) + 1
        if hi > self._slot_of_row.shape[0]:
            grown = np.full((hi,), -1, np.int64)
            grown[: self._slot_of_row.shape[0]] = self._slot_of_row
            self._slot_of_row = grown
        self._slot_of_row[rows] = slots
        if self._bank_dev is not None:
            # a down shard's device labels stay -1 (its host truth keeps
            # accumulating; mark_up rewrites the slab)
            ns_dev = np.where(np.isin(shard, list(self.down)), -1, ns) \
                if self.down else ns
            self._scatter_dev(slots, vecs, ns_dev)

    def delete(self, rows) -> None:
        """Tombstone rows in the shard layout (slots are not reused — the
        next rebuild re-packs)."""
        if self.stale:
            return
        rows = np.asarray(rows, np.int64).ravel()
        rows = rows[(rows >= 0) & (rows < self._slot_of_row.shape[0])]
        slots = self._slot_of_row[rows]
        slots = slots[slots >= 0]
        if slots.size == 0:
            return
        self._bank_host[slots] = 0.0
        self._labels_host[slots] = -1
        self._rows_host[slots] = -1
        self._slot_of_row[rows] = -1
        if self._bank_dev is not None:
            self._scatter_dev(slots,
                              np.zeros((slots.size, self.dim), np.float32),
                              np.full((slots.size,), -1, np.int32))

    def _scatter_dev(self, slots, vecs, ns) -> None:
        m = slots.size
        pad = next_pow2(m)
        if pad > m:        # duplicate trailing slot: idempotent scatter
            slots = np.concatenate(
                [slots, np.full((pad - m,), slots[-1], np.int64)])
            vecs = np.concatenate([vecs, np.repeat(vecs[-1:], pad - m, 0)])
            ns = np.concatenate([ns, np.full((pad - m,), ns[-1], np.int32)])
        self._bank_dev, self._labels_dev = _dev_scatter(
            self._bank_dev, self._labels_dev, jnp.asarray(slots),
            jnp.asarray(vecs), jnp.asarray(ns))

    # -- shard liveness ------------------------------------------------------
    def mark_down(self, shard: int) -> None:
        """Take a shard out of retrieval: its device label slab goes to -1
        (the namespace mask hides every row) while the host truth is kept —
        this is the graceful-degradation switch, one (C,) slab write."""
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} of {self.n_shards}")
        if shard in self.down:
            return
        self.down.add(shard)
        if self._labels_dev is not None:
            slab = jnp.asarray(np.full((self.C,), -1, np.int32))
            self._labels_dev = _dev_set_slab(self._labels_dev, slab,
                                             jnp.int32(shard * self.C))

    def mark_up(self, shard: int) -> None:
        """Bring a shard back: rewrite its label slab from host truth (a
        (C,) upload — a recovery event, not steady state)."""
        if shard not in self.down:
            return
        self.down.discard(shard)
        if self._labels_dev is not None:
            slab = jnp.asarray(
                self._labels_host[shard * self.C: (shard + 1) * self.C])
            self._labels_dev = _dev_set_slab(self._labels_dev, slab,
                                             jnp.int32(shard * self.C))

    # -- device residency ----------------------------------------------------
    def _effective_labels(self) -> np.ndarray:
        if not self.down:
            return self._labels_host
        eff = self._labels_host.copy()
        for s in self.down:
            eff[s * self.C: (s + 1) * self.C] = -1
        return eff

    def _ensure_device(self) -> None:
        if self._bank_dev is not None:
            return
        eff = self._effective_labels()
        if self.mesh is not None:
            from repro.common.partitioning import standard_rules
            n_dev = int(np.prod(list(self.mesh.shape.values())))
            if self.n_slots % n_dev != 0:
                raise ValueError(
                    f"{self.n_slots} slots do not divide over {n_dev} mesh "
                    "devices")
            rules = standard_rules(self.mesh)
            self._bank_dev = jax.device_put(
                self._bank_host,
                rules.sharding_for(("bank", None), (self.n_slots, self.dim)))
            self._labels_dev = jax.device_put(
                np.ascontiguousarray(eff),
                rules.sharding_for(("bank",), (self.n_slots,)))
        else:
            self._bank_dev = jnp.asarray(self._bank_host)
            self._labels_dev = jnp.asarray(eff)

    def bank_device(self):
        """The live device bank (tests assert its sharding layout)."""
        self._ensure_device()
        return self._bank_dev

    def _mesh_fn(self, k: int):
        fn = self._mesh_fns.get(k)
        if fn is None:
            mesh, uk = self.mesh, self.use_kernel
            axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.axis_names)

            def run(bank, labels, q, qns):
                return sharded_topk(q, bank, k, mesh, axis_names=axes,
                                    q_ns=qns, bank_ns=labels, use_kernel=uk)
            fn = self._mesh_fns[k] = jax.jit(run)
        return fn

    # -- search --------------------------------------------------------------
    def search(self, queries, q_ns, k: int):
        """One namespace-masked top-k launch over the sharded bank.
        Returns (scores (Q,k) DEVICE f32, rows (Q,k) HOST i32 global ids,
        -1 for empty).  Requires a non-stale layout (`rebuild` first)."""
        if self.stale:
            raise RuntimeError("ShardedBank is stale; rebuild() first")
        queries = jnp.asarray(queries, jnp.float32)
        if queries.ndim == 1:
            queries = queries[None]
        Q = queries.shape[0]
        if int(self._count.sum()) == 0:
            return (jnp.full((Q, k), -jnp.inf, jnp.float32),
                    np.full((Q, k), -1, np.int32))
        self._ensure_device()
        self.counters["searches"] += 1
        q_ns = jnp.asarray(q_ns, jnp.int32)
        kk = min(k, self.n_slots)
        if self.mesh is not None:
            s, i = self._mesh_fn(kk)(self._bank_dev, self._labels_dev,
                                     queries, q_ns)
        else:
            s, i = _search_device(self._bank_dev, self._labels_dev, queries,
                                  q_ns, jnp.int32(self.n_slots), k=kk,
                                  use_kernel=self.use_kernel, interpret=None,
                                  uniform=False)
        if kk < k:
            s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
            i = jnp.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
        return s, self.slots_to_rows(i)

    def slots_to_rows(self, slot_ids) -> np.ndarray:
        """Map device slot ids back to global row ids: one tiny O(Q*k) host
        gather (the id space downstream — fusion, triple lookup — is the
        same as the unsharded path)."""
        i = np.asarray(slot_ids)
        safe = np.clip(i, 0, self.n_slots - 1)
        return np.where(i >= 0, self._rows_host[safe], -1).astype(np.int32)

    # -- stats ---------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "per_shard_capacity": self.C,
            "total_slots": self.n_slots,
            "per_shard_rows": [int(c) for c in self._count],
            "down": sorted(self.down),
            "stale": self.stale,
            "meshed": self.mesh is not None,
            **self.counters,
        }
