"""HTTP serving surface for the memory layer — the network face of the
typed API (the ROADMAP's "network serving surface with streaming +
per-tenant QoS").

Stdlib-only (`http.server.ThreadingHTTPServer`; one handler thread per
connection, all of them funneling into the scheduler's micro-batch ticks —
the thread-per-request frontend and the batched backend compose exactly
like the SDK clients do).  Four endpoints:

    POST /v1/retrieve   {"query": ...} or {"queries": [{...}, ...]}
    POST /v1/record     {"session_id", "messages": [{speaker,text,ts}]}
    POST /v1/evict      {"namespace", "superseded_only": false}
    GET  /v1/stats      service + scheduler + admission + frontend counters
    GET  /v1/metrics    Prometheus text exposition: every numeric leaf of
                        service/scheduler/frontend stats as a `memori_<path>`
                        gauge, plus the telemetry registry's latency
                        histograms and monotonic counters
                        (obs/telemetry.py), all with `# HELP`/`# TYPE`
    GET  /v1/healthz    liveness (unauthenticated): 200 while serving
    GET  /v1/readyz     readiness (unauthenticated): 503 while any
                        placement shard is down or the lifecycle queue is
                        in reject-backpressure

**Observability**: every request gets a request id — `X-Request-Id` is
honored when the client sends one (sanitized), minted otherwise, echoed
as a response header and as `request_id` in the JSON envelope.  The op
endpoints open a telemetry `Trace` at the edge; the id rides with the
request through admission, the scheduler tick and every plan stage, and
the finished span tree lands in the registry's ring buffer —
`GET /v1/admin/trace/<request_id>` (admin keyring) fetches it, and
`"debug": true` on /v1/retrieve returns it inline.  The response envelope
carries the server-side split (`queued_s` / `service_s` / `batch_size`),
so remote clients see where the time went, not just wall clock.

**Tenancy** is workspace/api-key shaped (the MemoryLayer SDK surface):
every request authenticates with `Authorization: Bearer <key>` (or
`X-Api-Key`), the key maps to a *tenant*, and every namespace the body
names is scoped to `<tenant>/<namespace>` before it touches the service —
a key can never read, write, or evict outside its own prefix, and the
tenant is also the QoS identity the scheduler's admission control
charges.

**Requests/responses are the typed API on the wire**: bodies decode
through `core/api.py`'s `*_from_json` codecs (same validation as direct
callers) and every reply is the `MemoryResponse` envelope via
`response_to_json`.  Errors use the same envelope with `status="error"`:
400 for validation, 401 for a bad key, 404 for an unknown route, 429 +
`Retry-After` when admission control rejects (rate limit / shed /
backpressure), 504 when a request times out in the queue.

**Streaming**: `{"stream": true}` on /v1/retrieve switches the response
to chunked transfer, NDJSON framed — one `accepted` event as soon as the
batch is admitted, one `result` event per request *as its future
resolves* (completion order, `index` maps back to the submitted order),
and a final `done` event.  A client fanning one batch across namespaces
renders early results while late ones still sit in a tick.
"""
from __future__ import annotations

import json
import math
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Mapping, Optional, Tuple

from concurrent.futures import TimeoutError as FutureTimeoutError
from repro.core.admission import AdmissionError, admission_policy_from_json
from repro.core.api import (CompactRequest, EvictRequest, MemoryResponse,
                            RecordRequest, RetrieveRequest,
                            record_request_from_json, response_to_json,
                            retrieve_request_from_json)
from repro.core.lifecycle import BackpressureError
from repro.obs.telemetry import get_telemetry, new_request_id

_MAX_BODY = 8 << 20          # one request body; sessions are small
# client-supplied X-Request-Id values must be log/header-safe; anything
# else is replaced with a minted id (never rejected — ids are advisory)
_REQ_ID_RE = re.compile(r"^[A-Za-z0-9._\-]{1,64}$")


class _HttpError(Exception):
    def __init__(self, code: int, message: str,
                 retry_after_s: Optional[float] = None):
        super().__init__(message)
        self.code = code
        self.retry_after_s = retry_after_s


def _json_default(o):
    """stats() dicts can carry numpy scalars; render them, never crash."""
    item = getattr(o, "item", None)
    return item() if callable(item) else repr(o)


def _metric_name(*parts: str) -> str:
    name = "_".join(re.sub(r"[^a-zA-Z0-9_]", "_", str(p)) for p in parts)
    return re.sub(r"__+", "_", name)


def flatten_metrics(stats: Mapping, prefix: str = "memori") -> List[Tuple[str, float]]:
    """Flatten a nested stats dict into Prometheus gauge samples: every
    numeric leaf becomes `<prefix>_<path> <value>` (bools as 0/1, numpy
    scalars unwrapped, None/str/unbounded-cardinality subtrees skipped).
    Deterministic order — scrapes diff cleanly."""
    out: List[Tuple[str, float]] = []
    for k in stats:
        v = stats[k]
        if k == "per_namespace":       # unbounded label cardinality
            continue
        name = _metric_name(prefix, k)
        if isinstance(v, Mapping):
            out.extend(flatten_metrics(v, prefix=name))
            continue
        item = getattr(v, "item", None)
        if callable(item) and not isinstance(v, (bool, int, float)):
            try:
                v = item()
            except Exception:
                continue
        if isinstance(v, bool):
            out.append((name, 1.0 if v else 0.0))
        elif isinstance(v, (int, float)) and math.isfinite(v):
            out.append((name, float(v)))
    return out


def render_prometheus(samples: List[Tuple[str, float]],
                      metrics: Tuple = ()) -> str:
    """Prometheus text exposition: `samples` are point-in-time gauges
    (flattened stats leaves, each with `# HELP`/`# TYPE`); `metrics` are
    telemetry registry objects (Counter/Histogram from obs/telemetry.py)
    rendered through their own `exposition()` — counters get the `_total`
    suffix and `counter` type, histograms emit cumulative
    `_bucket`/`_sum`/`_count` series."""
    lines = []
    for name, value in samples:
        lines.append(f"# HELP {name} point-in-time gauge "
                     "(stats() leaf)")
        lines.append(f"# TYPE {name} gauge")
        if value == int(value) and abs(value) < 2 ** 53:
            lines.append(f"{name} {int(value)}")
        else:
            lines.append(f"{name} {value}")
    for m in metrics:
        lines.extend(m.exposition())
    return "\n".join(lines) + "\n"


class MemoryFrontend:
    """The server object: owns the ThreadingHTTPServer, the api-key ->
    tenant map, and the request counters.  `service` is a MemoryService;
    when it has a MemoryScheduler mounted every handler thread submits
    through it (admission control + cross-client batching), otherwise
    requests run on the direct engine."""

    def __init__(self, service, api_keys: Mapping[str, str],
                 host: str = "127.0.0.1", port: int = 0,
                 request_timeout_s: float = 60.0,
                 admin_keys: Optional[Mapping[str, str]] = None):
        if not api_keys:
            raise ValueError("MemoryFrontend needs at least one api key "
                             "(api_key -> tenant)")
        self.service = service
        self.api_keys: Dict[str, str] = dict(api_keys)
        # the admin keyring (admin_key -> operator label) is DISJOINT from
        # tenant keys: a tenant key can never reach the admin surface, and
        # an admin key is not a tenant.  No admin_keys = no admin surface.
        self.admin_keys: Dict[str, str] = dict(admin_keys or {})
        overlap = set(self.api_keys) & set(self.admin_keys)
        if overlap:
            raise ValueError("api_keys and admin_keys must be disjoint "
                             f"({len(overlap)} shared keys)")
        self.request_timeout_s = float(request_timeout_s)
        self.counters = {"requests": 0, "unauthorized": 0, "bad_requests": 0,
                         "rejected": 0, "errors": 0, "timeouts": 0,
                         "streams": 0, "policy_reloads": 0}
        self._counter_lock = threading.Lock()
        frontend = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, fmt, *args):   # keep stdout clean
                pass

            def do_GET(self):
                frontend._dispatch(self, "GET")

            def do_POST(self):
                frontend._dispatch(self, "POST")

        class Server(ThreadingHTTPServer):
            daemon_threads = True
            # socketserver's default listen backlog of 5 RSTs concurrent
            # connects the moment a fleet of clients arrives together
            request_queue_size = 128

        self.server = Server((host, port), Handler)
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------
    @property
    def address(self) -> str:
        host, port = self.server.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "MemoryFrontend":
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self.server.serve_forever,
                                            name="memori-http", daemon=True)
            self._thread.start()
        return self

    def serve_forever(self) -> None:
        self.server.serve_forever()

    def close(self) -> None:
        self.server.shutdown()
        self.server.server_close()

    def __enter__(self) -> "MemoryFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- plumbing -----------------------------------------------------------
    def _count(self, key: str) -> None:
        with self._counter_lock:
            self.counters[key] += 1

    def _auth(self, handler) -> str:
        auth = handler.headers.get("Authorization", "")
        key = auth[7:] if auth.startswith("Bearer ") else \
            handler.headers.get("X-Api-Key", "")
        tenant = self.api_keys.get(key)
        if tenant is None:
            self._count("unauthorized")
            raise _HttpError(401, "unknown api key")
        return tenant

    def _admin_auth(self, handler) -> str:
        if not self.admin_keys:
            # no keyring mounted: the admin surface does not exist — 404,
            # not 401, so probing cannot distinguish "wrong key" from
            # "no surface"
            raise _HttpError(404, "admin surface not enabled")
        auth = handler.headers.get("Authorization", "")
        key = auth[7:] if auth.startswith("Bearer ") else \
            handler.headers.get("X-Api-Key", "")
        operator = self.admin_keys.get(key)
        if operator is None:
            self._count("unauthorized")
            raise _HttpError(401, "unknown admin key")
        return operator

    @staticmethod
    def _body(handler) -> dict:
        length = int(handler.headers.get("Content-Length") or 0)
        if length > _MAX_BODY:
            raise _HttpError(413, f"body over {_MAX_BODY} bytes")
        raw = handler.rfile.read(length) if length else b"{}"
        try:
            obj = json.loads(raw or b"{}")
        except json.JSONDecodeError as e:
            raise _HttpError(400, f"invalid JSON body: {e}")
        if not isinstance(obj, dict):
            raise _HttpError(400, "body must be a JSON object")
        return obj

    @staticmethod
    def _scope(tenant: str, namespace) -> str:
        ns = str(namespace if namespace not in (None, "") else "default")
        return f"{tenant}/{ns}"

    def _send_json(self, handler, code: int, obj: dict,
                   retry_after_s: Optional[float] = None) -> None:
        rid = getattr(handler, "memori_request_id", None)
        if rid is not None:
            obj.setdefault("request_id", rid)
        blob = json.dumps(obj, default=_json_default).encode()
        handler.send_response(code)
        handler.send_header("Content-Type", "application/json")
        handler.send_header("Content-Length", str(len(blob)))
        if rid is not None:
            handler.send_header("X-Request-Id", rid)
        if retry_after_s is not None:
            handler.send_header("Retry-After",
                                str(max(1, math.ceil(retry_after_s))))
        handler.end_headers()
        handler.wfile.write(blob)

    def _error_body(self, message: str, **extra) -> dict:
        body = {"status": "error", "error": message}
        body.update(extra)
        return body

    def _dispatch(self, handler, method: str) -> None:
        self._count("requests")
        # honor a sane client X-Request-Id, mint one otherwise; the id is
        # echoed on every response (header + envelope) and keys the trace
        rid = handler.headers.get("X-Request-Id", "")
        if not _REQ_ID_RE.match(rid):
            rid = new_request_id()
        handler.memori_request_id = rid
        try:
            path = handler.path.split("?", 1)[0]
            route = (method, path)
            if route == ("GET", "/v1/healthz"):
                # liveness, unauthenticated: answering at all is the signal
                self._send_json(handler, 200, {"status": "ok"})
                return
            if route == ("GET", "/v1/readyz"):
                self._handle_readyz(handler)
                return
            if route == ("POST", "/v1/admin/policy"):
                # admin routes authenticate against their own keyring, so
                # they match BEFORE tenant auth (a tenant key must 401
                # here, not fall through to "unknown route")
                self._handle_admin_policy(handler)
                return
            if method == "GET" and path.startswith("/v1/admin/trace/"):
                self._handle_admin_trace(
                    handler, path[len("/v1/admin/trace/"):])
                return
            tenant = self._auth(handler)
            if route == ("POST", "/v1/retrieve"):
                self._handle_retrieve(handler, tenant)
            elif route == ("POST", "/v1/record"):
                self._handle_record(handler, tenant)
            elif route == ("POST", "/v1/evict"):
                self._handle_evict(handler, tenant)
            elif route == ("GET", "/v1/stats"):
                self._handle_stats(handler, tenant)
            elif route == ("GET", "/v1/metrics"):
                self._handle_metrics(handler)
            else:
                raise _HttpError(404, f"unknown route {method} "
                                      f"{handler.path}")
        except _HttpError as e:
            body = self._error_body(str(e))
            if e.retry_after_s is not None:
                body["retry_after_s"] = e.retry_after_s
            self._send_json(handler, e.code, body,
                            retry_after_s=e.retry_after_s)
        except AdmissionError as e:
            # QoS rejection: the one error a well-behaved client must
            # treat as backoff, not failure
            self._count("rejected")
            self._send_json(handler, 429, self._error_body(
                str(e), reason=e.reason, retry_after_s=e.retry_after_s),
                retry_after_s=e.retry_after_s)
        except (ValueError, TypeError) as e:
            self._count("bad_requests")
            self._send_json(handler, 400, self._error_body(str(e)))
        except BrokenPipeError:
            pass                                  # client went away
        except Exception as e:                    # pragma: no cover
            self._count("errors")
            self._send_json(handler, 500, self._error_body(repr(e)))

    # -- submission ---------------------------------------------------------
    def _submit(self, requests: List, tenant: str, trace=None) -> List:
        """Route typed requests through the mounted scheduler (admission +
        batching) and return futures; without one, run directly and return
        pre-resolved envelopes.  `trace` (the edge Trace, may be None) gets
        an `admission` span around the submit and rides with each request
        so the executing tick records into it."""
        tel = get_telemetry()
        sched = getattr(self.service, "scheduler", None)
        if sched is not None and sched.can_submit():
            with tel.activate([trace]):
                with tel.span("admission", tenant=tenant,
                              requests=len(requests)):
                    return sched.submit_many(
                        requests, tenant=tenant,
                        traces=[trace] * len(requests))
        # schedulerless: the engine runs on this thread — activate here so
        # execute()'s plan-stage spans still land in the tree
        with tel.activate([trace]):
            return [self._direct(r) for r in requests]

    def _direct(self, req) -> "_Resolved":
        t0 = time.monotonic()
        try:
            if isinstance(req, RetrieveRequest):
                payload = self.service.execute([req])[0]
                resp = MemoryResponse(
                    payload=payload, op="retrieve",
                    service_s=time.monotonic() - t0,
                    token_count=getattr(payload, "token_count", None),
                    degraded=getattr(payload, "degraded", False))
            elif isinstance(req, RecordRequest):
                self.service.record(req.namespace, req.session_id,
                                    list(req.messages))
                durable = getattr(self.service, "runtime", None) is not None \
                    and self.service.runtime.wal is not None
                resp = MemoryResponse(
                    payload={"queued": True, "flushed": True,
                             "durable": durable},
                    op="record", service_s=time.monotonic() - t0)
            elif isinstance(req, EvictRequest):
                n = (self.service.evict_superseded(req.namespace)
                     if req.superseded_only
                     else self.service.evict(req.namespace))
                resp = MemoryResponse(payload=n, op="evict",
                                      service_s=time.monotonic() - t0)
            elif isinstance(req, CompactRequest):
                resp = MemoryResponse(payload=self.service.compact(),
                                      op="compact",
                                      service_s=time.monotonic() - t0)
            else:                                 # pragma: no cover
                raise TypeError(type(req).__name__)
        except AdmissionError:
            raise
        except BaseException as e:
            resp = MemoryResponse(payload=None, op=type(req).__name__,
                                  status="error", error=repr(e), exception=e)
        return _Resolved(resp)

    def _wait(self, fut) -> MemoryResponse:
        try:
            return fut.result(timeout=self.request_timeout_s)
        except FutureTimeoutError:
            self._count("timeouts")
            raise _HttpError(
                504, f"request timed out after {self.request_timeout_s}s "
                     "in the scheduler queue")

    def _respond_envelope(self, handler, resp: MemoryResponse,
                          extra: Optional[dict] = None) -> None:
        body = response_to_json(resp)
        if extra:
            body.update(extra)
        if resp.ok:
            self._send_json(handler, 200, body)
        elif isinstance(resp.exception, (BackpressureError, AdmissionError)):
            # capacity, not failure: same backoff contract as admission
            self._count("rejected")
            retry = getattr(resp.exception, "retry_after_s", 1.0)
            body["retry_after_s"] = retry
            self._send_json(handler, 429, body, retry_after_s=retry)
        else:
            self._count("errors")
            self._send_json(handler, 500, body)

    # -- endpoints ----------------------------------------------------------
    def _handle_retrieve(self, handler, tenant: str) -> None:
        tel = get_telemetry()
        trace = tel.start_trace(handler.memori_request_id, op="retrieve")
        try:
            with tel.activate([trace]):
                with tel.span("frontend", tenant=tenant) as sp:
                    body = self._body(handler)
                    queries = body.get("queries")
                    single = queries is None
                    if single:
                        queries = [body]
                    if not isinstance(queries, list) or not queries:
                        raise _HttpError(400,
                                         "'queries' must be a non-empty "
                                         "list")
                    default_ns = body.get("namespace")
                    reqs = [retrieve_request_from_json(
                                q, self._scope(tenant,
                                               q.get("namespace",
                                                     default_ns)))
                            for q in queries]
                    sp.set(queries=len(reqs))
            futs = self._submit(reqs, tenant, trace=trace)
            if body.get("stream"):
                self._stream_results(handler, futs)
                return
            resps = [self._wait(f) for f in futs]
            # the tick span closed before any future resolved, so the tree
            # is complete (and no longer being written) by the time it is
            # finished + serialized here
            tel.finish_trace(trace)
            debug = (trace.to_dict() if body.get("debug")
                     and trace is not None else None)
            if single:
                self._respond_envelope(
                    handler, resps[0],
                    extra={"trace": debug} if debug else None)
            else:
                ok = all(r.ok for r in resps)
                out = {"responses": [response_to_json(r) for r in resps]}
                if debug:
                    out["trace"] = debug
                self._send_json(handler, 200 if ok else 207, out)
        finally:
            # error paths (timeouts, 4xx) still land the partial trace in
            # the ring buffer; idempotent after the happy path above
            tel.finish_trace(trace)

    def _handle_record(self, handler, tenant: str) -> None:
        tel = get_telemetry()
        trace = tel.start_trace(handler.memori_request_id, op="record")
        try:
            with tel.activate([trace]):
                with tel.span("frontend", tenant=tenant):
                    body = self._body(handler)
                    req = record_request_from_json(
                        body, self._scope(tenant, body.get("namespace")))
            [fut] = self._submit([req], tenant, trace=trace)
            self._respond_envelope(handler, self._wait(fut))
        finally:
            tel.finish_trace(trace)

    def _handle_evict(self, handler, tenant: str) -> None:
        tel = get_telemetry()
        trace = tel.start_trace(handler.memori_request_id, op="evict")
        try:
            with tel.activate([trace]):
                with tel.span("frontend", tenant=tenant):
                    body = self._body(handler)
                    req = EvictRequest(
                        self._scope(tenant, body.get("namespace")),
                        superseded_only=bool(body.get("superseded_only",
                                                      False)))
            [fut] = self._submit([req], tenant, trace=trace)
            self._respond_envelope(handler, self._wait(fut))
        finally:
            tel.finish_trace(trace)

    def _handle_admin_policy(self, handler) -> None:
        """POST /v1/admin/policy — swap the scheduler's AdmissionPolicy
        without a restart.  Authenticated against the admin keyring; the
        body is the `admission_policy_from_json` shape.  Traffic in flight
        keeps its queues; the next submit/select runs under the new
        limits."""
        operator = self._admin_auth(handler)
        body = self._body(handler)
        policy = admission_policy_from_json(body)
        sched = getattr(self.service, "scheduler", None)
        if sched is None or sched.closed:
            raise _HttpError(409, "no scheduler mounted: admission policy "
                                  "reload needs one running")
        sched.set_admission_policy(policy)
        self._count("policy_reloads")
        self._send_json(handler, 200,
                        {"status": "ok", "op": "policy_reload",
                         "operator": operator,
                         "tenants": sorted(policy.tenants)})

    def _handle_readyz(self, handler) -> None:
        """Readiness (unauthenticated): 503 while the deployment is
        degraded — any placement shard marked down, or the lifecycle
        queue rejecting writes under backpressure — so a load balancer
        stops routing here before clients see degraded answers."""
        sharded = getattr(self.service.store, "sharded", None)
        shards_down = (sorted(sharded.down)
                       if sharded is not None and sharded.down else [])
        rt = getattr(self.service, "runtime", None)
        rejecting = bool(rt is not None and rt.rejecting)
        if shards_down or rejecting:
            self._send_json(handler, 503, {
                "status": "unavailable",
                "shards_down": shards_down,
                "backpressure_reject": rejecting})
            return
        self._send_json(handler, 200, {"status": "ok"})

    def _handle_admin_trace(self, handler, request_id: str) -> None:
        """GET /v1/admin/trace/<request_id> — fetch a recent finished
        trace from the telemetry ring buffer (admin keyring)."""
        operator = self._admin_auth(handler)
        if not request_id:
            raise _HttpError(400, "missing request id")
        tr = get_telemetry().get_trace(request_id)
        if tr is None:
            raise _HttpError(404, f"no recent trace for request id "
                                  f"{request_id!r} (never issued, or "
                                  "evicted from the ring buffer)")
        self._send_json(handler, 200, {"status": "ok",
                                       "operator": operator, "trace": tr})

    def _handle_stats(self, handler, tenant: str) -> None:
        st = {"service": self.service.stats(),
              "frontend": dict(self.counters), "tenant": tenant}
        sched = getattr(self.service, "scheduler", None)
        if sched is not None:
            st["scheduler"] = sched.stats()
        self._send_json(handler, 200, st)

    def _handle_metrics(self, handler) -> None:
        """Prometheus text exposition of every numeric counter: service
        stats (bank/tier/lifecycle sections included), scheduler stats
        when one is mounted, frontend counters, and the telemetry
        registry's latency histograms + monotonic counters."""
        samples = flatten_metrics(self.service.stats(), prefix="memori")
        sched = getattr(self.service, "scheduler", None)
        if sched is not None:
            samples.extend(flatten_metrics(sched.stats(),
                                           prefix="memori_scheduler"))
        with self._counter_lock:
            counters = dict(self.counters)
        samples.extend(flatten_metrics(counters, prefix="memori_frontend"))
        blob = render_prometheus(
            samples, metrics=tuple(get_telemetry().metrics())).encode()
        handler.send_response(200)
        handler.send_header("Content-Type",
                            "text/plain; version=0.0.4; charset=utf-8")
        handler.send_header("Content-Length", str(len(blob)))
        handler.end_headers()
        handler.wfile.write(blob)

    # -- streaming ----------------------------------------------------------
    @staticmethod
    def _write_chunk(handler, obj: dict) -> None:
        data = (json.dumps(obj, default=_json_default) + "\n").encode()
        handler.wfile.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")
        handler.wfile.flush()

    def _stream_results(self, handler, futs: List) -> None:
        """Chunked NDJSON: `accepted`, then one `result` per request as its
        future resolves (completion order; `index` is the submitted
        position), then `done`."""
        self._count("streams")
        handler.send_response(200)
        handler.send_header("Content-Type", "application/x-ndjson")
        handler.send_header("Transfer-Encoding", "chunked")
        handler.send_header("Cache-Control", "no-cache")
        handler.end_headers()
        self._write_chunk(handler, {"event": "accepted", "count": len(futs)})
        pending: Dict[int, object] = dict(enumerate(futs))
        deadline = time.monotonic() + self.request_timeout_s
        errors = 0
        while pending:
            # resolve-order streaming without as_completed's thread pool:
            # poll the done set, then block briefly on one future so a
            # stalled tick doesn't spin the handler
            done_now: List[Tuple[int, MemoryResponse]] = []
            for i, f in list(pending.items()):
                if f.done():
                    done_now.append((i, f.result()))
                    del pending[i]
            if not done_now:
                if time.monotonic() >= deadline:
                    for i in list(pending):
                        self._write_chunk(handler, {
                            "event": "result", "index": i,
                            "response": {"status": "error",
                                         "error": "timed out"}})
                        errors += 1
                    pending.clear()
                    break
                i, f = next(iter(pending.items()))
                try:
                    f.result(timeout=min(0.05,
                                         deadline - time.monotonic()))
                except Exception:
                    pass
                continue
            for i, resp in done_now:
                errors += 0 if resp.ok else 1
                self._write_chunk(handler, {"event": "result", "index": i,
                                            "response":
                                                response_to_json(resp)})
        self._write_chunk(handler, {"event": "done", "count": len(futs),
                                    "errors": errors})
        handler.wfile.write(b"0\r\n\r\n")
        handler.wfile.flush()


class _Resolved:
    """A future-alike for the schedulerless direct path."""

    def __init__(self, resp: MemoryResponse):
        self._resp = resp

    def result(self, timeout=None) -> MemoryResponse:
        return self._resp

    def done(self) -> bool:
        return True
