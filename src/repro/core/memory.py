"""MemoriMemory — the persistent memory facade.

record_session() feeds Advanced Augmentation; retrieve() runs hybrid search
(cosine + BM25, RRF-fused), pulls linked summaries, and assembles the
context block under the token budget, rendered in the paper's Appendix-A
format (timestamped memories + summaries).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.augmentation import AdvancedAugmentation
from repro.core.budget import TokenBudgeter
from repro.core.extraction import Extractor, Message
from repro.core.hybrid import hybrid_search
from repro.core.summaries import Summary
from repro.core.triples import Triple
from repro.data.tokenizer import HashTokenizer, default_tokenizer


@dataclasses.dataclass
class RetrievedContext:
    triples: List[Triple]
    summaries: List[Summary]
    text: str
    token_count: int
    # True when the owning shard was down at retrieval time: the result
    # is empty/partial by design, not an error (see core/shards.py)
    degraded: bool = False


ANSWER_PROMPT = """You are an intelligent memory assistant tasked with retrieving
accurate information from conversation memories.

# CONTEXT:
You have access to two types of information from a conversation:
- Memories: timestamped factual triples extracted from conversations.
- Summaries: high-level conversation summaries (also timestamped) that provide
  broader context around the memories.

# INSTRUCTIONS:
1. Carefully analyze all provided memories and summaries
2. Pay special attention to the timestamps to determine the answer
3. If the memories contain contradictory information, prioritize the most recent memory
4. Always convert relative time references to specific dates, months, or years.
5. The answer should be less than 5-6 words.

{memories}

Question: {question}
Answer:"""


class MemoriMemory:
    def __init__(self, embedder, extractor: Optional[Extractor] = None,
                 dim: int = 256, budget: int = 1300, top_k: int = 10,
                 tokenizer: HashTokenizer | None = None,
                 use_kernel: bool = True,
                 dense_weight: float = 1.0, sparse_weight: float = 0.7):
        self.embedder = embedder
        self.pipeline = AdvancedAugmentation(embedder, extractor, dim=dim,
                                             use_kernel=use_kernel)
        self.tokenizer = tokenizer or default_tokenizer()
        self.budgeter = TokenBudgeter(budget=budget, tokenizer=self.tokenizer)
        self.top_k = top_k
        self.dense_weight = dense_weight
        self.sparse_weight = sparse_weight

    # -- write path --------------------------------------------------------
    def record_session(self, conversation_id: str, session_id: str,
                       messages: Sequence[Message]):
        return self.pipeline.ingest(conversation_id, session_id, messages)

    # -- read path -----------------------------------------------------------
    def retrieve(self, query: str, top_k: Optional[int] = None) -> RetrievedContext:
        qv = self.embedder.embed_texts([query])
        fused = hybrid_search(query, qv, self.pipeline.vindex,
                              self.pipeline.bm25, top_k=top_k or self.top_k,
                              dense_weight=self.dense_weight,
                              sparse_weight=self.sparse_weight)
        scored = [(self.pipeline.triples.get(tid), score) for tid, score in fused]
        ctx = self.budgeter.select(scored, self.pipeline.summaries)
        text = self.render(ctx.triples, ctx.summaries)
        return RetrievedContext(ctx.triples, ctx.summaries, text,
                                self.tokenizer.count(text))

    def answer_prompt(self, question: str) -> tuple[str, RetrievedContext]:
        ctx = self.retrieve(question)
        return ANSWER_PROMPT.format(memories=ctx.text, question=question), ctx

    def resolve(self, query: str) -> Optional[Triple]:
        """Conflict-resolving point lookup (paper Appendix A, instruction 4):
        retrieve, group by (subject, predicate), return the most recent
        version of the best-ranked evolving attribute."""
        ctx = self.retrieve(query)
        if not ctx.triples:
            return None
        best = ctx.triples[0]
        return self.pipeline.triples.latest_for_key(best.key()) or best

    @staticmethod
    def render(triples: Sequence[Triple], summaries: Sequence[Summary]) -> str:
        lines = ["# MEMORIES:"]
        lines += [t.render() for t in triples]
        lines.append("")
        lines.append("# SUMMARIES:")
        lines += [s.render() for s in summaries]
        return "\n".join(lines)

    def stats(self) -> dict:
        return self.pipeline.stats()
