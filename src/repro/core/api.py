"""Typed request API for the memory service — the public frontend surface.

The paper's economics come from batching every tenant through one embed
call and one masked kernel launch, but a positional
`retrieve_batch([(ns, q), ...])` only delivers that when a single caller
hand-assembles the batch.  Production deployments are many independent
clients issuing one operation at a time, each with its own options — so the
public surface is *requests*, not method arguments:

* `RetrieveRequest` / `RecordRequest` / `EvictRequest` / `CompactRequest`
  are immutable, validated descriptions of one operation, carrying every
  per-request option (`top_k`, dense/sparse `weights`, plan `stages`).
* `MemoryResponse` is the uniform envelope every operation resolves to:
  payload, status, error, queue/service timing, token counts, and the size
  of the device batch the request shared.
* `RetrievalPlan` names the stage pipeline a retrieve runs —
  embed → dense → sparse → fuse → budget — with variants that drop stages
  (`dense_only`, `sparse_only`, `raw` = no token budgeting, fused ids out).

Requests are what `core/scheduler.py`'s MemoryScheduler collects from many
threads and fuses into one device launch per tick; `MemoryService.execute`
is the engine that runs a homogeneous batch of RetrieveRequests through one
embed + one masked top-k + one stacked BM25 + one fused RRF launch,
honoring per-request options by fusing at max(top_k) on device and slicing
per request.  The legacy tuple/kwargs surface remains as thin wrappers that
build requests (see docs/API.md for the migration map).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple, Union

from repro.core.extraction import Message

STAGE_DENSE = "dense"
STAGE_SPARSE = "sparse"
STAGE_GRAPH = "graph"
STAGE_FUSE = "fuse"
STAGE_BUDGET = "budget"
KNOWN_STAGES = (STAGE_DENSE, STAGE_SPARSE, STAGE_GRAPH, STAGE_FUSE,
                STAGE_BUDGET)
# what a plain RetrievalPlan() runs: graph expansion is opt-in (the
# graph_expanded variant / per-request stages), so existing flat-retrieval
# callers keep their exact rankings
DEFAULT_STAGES = (STAGE_DENSE, STAGE_SPARSE, STAGE_FUSE, STAGE_BUDGET)


def _check_stages(stages: Sequence[str]) -> Tuple[str, ...]:
    stages = tuple(dict.fromkeys(stages))
    unknown = [s for s in stages if s not in KNOWN_STAGES]
    if unknown:
        raise ValueError(f"unknown retrieval stages {unknown}; "
                         f"known: {KNOWN_STAGES}")
    if STAGE_DENSE not in stages and STAGE_SPARSE not in stages:
        raise ValueError("a retrieval plan needs at least one of "
                         "'dense' / 'sparse'"
                         + (" ('graph' expands their seed rows, it cannot "
                            "seed itself)" if STAGE_GRAPH in stages else ""))
    # fuse is how rankings become one result — it is always implied, even
    # for a single ranking (the B=1-ranking fuse is what keeps dense-only
    # ordering identical to hybrid ordering restricted to dense hits)
    if STAGE_FUSE not in stages:
        stages = stages + (STAGE_FUSE,)
    return stages


MAX_HOPS = 8          # the deepest unrolled expansion the service compiles


def _check_graph_opts(hops, edge_weights) -> None:
    if hops is not None and not (1 <= hops <= MAX_HOPS):
        raise ValueError(f"hops must be in [1, {MAX_HOPS}], got {hops}")
    if edge_weights is not None:
        if len(edge_weights) != 3:
            raise ValueError(
                "edge_weights must be (entity, temporal, causal) — "
                f"3 floats, got {len(edge_weights)}")
        if any(w < 0 for w in edge_weights):
            raise ValueError("edge_weights must be >= 0")


@dataclasses.dataclass(frozen=True)
class RetrievalPlan:
    """The stage pipeline a retrieve runs, plus its default knobs.

    `stages` ⊆ {dense, sparse, graph, fuse, budget}; at least one of
    dense/sparse; fuse is implied.  Dropping `budget` returns a
    `RawRetrieval` (fused global row ids + scores, no token budgeting, no
    rendering) instead of a `RetrievedContext`.  Every knob here is a
    *default*: a RetrieveRequest may override any of them per request, and
    mixed-option requests still share one device launch.

    The `graph` stage (docs/API.md) expands the dense/sparse seed rows
    through the store's entity graph — `hops` k-hop depth, `edge_weights`
    per edge type (entity, temporal, causal), `graph_weight` the expanded
    ranking's RRF weight column.  `graph_seed_k` (how many top rows of each
    upstream ranking seed the frontier) and `graph_decay` (per-hop score
    decay) are plan-level: they are compiled into the expansion executable,
    so they cannot vary per request within a batch."""
    stages: Tuple[str, ...] = DEFAULT_STAGES
    top_k: Optional[int] = None
    dense_weight: Optional[float] = None
    sparse_weight: Optional[float] = None
    hops: Optional[int] = None                      # default 2
    edge_weights: Optional[Tuple[float, float, float]] = None
    graph_weight: Optional[float] = None            # default 0.6
    graph_seed_k: int = 8
    graph_decay: float = 0.5

    def __post_init__(self):
        object.__setattr__(self, "stages", _check_stages(self.stages))
        if self.top_k is not None and self.top_k < 1:
            raise ValueError("top_k must be >= 1")
        _check_graph_opts(self.hops, self.edge_weights)
        if self.graph_seed_k < 1:
            raise ValueError("graph_seed_k must be >= 1")
        if not (0.0 < self.graph_decay <= 1.0):
            raise ValueError("graph_decay must be in (0, 1]")
        if self.edge_weights is not None:
            object.__setattr__(self, "edge_weights",
                               tuple(float(w) for w in self.edge_weights))

    # -- variants ----------------------------------------------------------
    @classmethod
    def hybrid(cls, **kw) -> "RetrievalPlan":
        return cls(**kw)

    @classmethod
    def dense_only(cls, budget: bool = True, **kw) -> "RetrievalPlan":
        st = (STAGE_DENSE, STAGE_FUSE) + ((STAGE_BUDGET,) if budget else ())
        return cls(stages=st, **kw)

    @classmethod
    def sparse_only(cls, budget: bool = True, **kw) -> "RetrievalPlan":
        st = (STAGE_SPARSE, STAGE_FUSE) + ((STAGE_BUDGET,) if budget else ())
        return cls(stages=st, **kw)

    @classmethod
    def raw(cls, **kw) -> "RetrievalPlan":
        """Hybrid retrieval, fused ids out: no budgeting, no rendering."""
        return cls(stages=(STAGE_DENSE, STAGE_SPARSE, STAGE_FUSE), **kw)

    @classmethod
    def graph_expanded(cls, budget: bool = True, **kw) -> "RetrievalPlan":
        """Hybrid + k-hop graph expansion of the seed rows
        (embed → dense → sparse → graph → fuse [→ budget])."""
        st = (STAGE_DENSE, STAGE_SPARSE, STAGE_GRAPH, STAGE_FUSE) + \
            ((STAGE_BUDGET,) if budget else ())
        return cls(stages=st, **kw)

    @property
    def wants_dense(self) -> bool:
        return STAGE_DENSE in self.stages

    @property
    def wants_sparse(self) -> bool:
        return STAGE_SPARSE in self.stages

    @property
    def wants_graph(self) -> bool:
        return STAGE_GRAPH in self.stages

    @property
    def wants_budget(self) -> bool:
        return STAGE_BUDGET in self.stages


@dataclasses.dataclass(frozen=True)
class RetrieveRequest:
    """One tenant's retrieval with its own options.  `None` options fall
    back to the plan's defaults, then the service's."""
    namespace: str
    query: str
    top_k: Optional[int] = None
    dense_weight: Optional[float] = None
    sparse_weight: Optional[float] = None
    stages: Optional[Tuple[str, ...]] = None
    # graph-stage options (only read when the resolved stages include
    # 'graph'); requests with different hops/edge_weights still share one
    # expansion launch — hop depth rides in as a traced per-request vector
    hops: Optional[int] = None
    edge_weights: Optional[Tuple[float, float, float]] = None
    graph_weight: Optional[float] = None

    def __post_init__(self):
        if not isinstance(self.namespace, str):
            raise TypeError(f"namespace must be str, got "
                            f"{type(self.namespace).__name__}")
        if not isinstance(self.query, str):
            raise TypeError(f"query must be str, got "
                            f"{type(self.query).__name__}")
        if self.top_k is not None and self.top_k < 1:
            raise ValueError("top_k must be >= 1")
        if self.stages is not None:
            object.__setattr__(self, "stages", _check_stages(self.stages))
        _check_graph_opts(self.hops, self.edge_weights)
        if self.edge_weights is not None:
            object.__setattr__(self, "edge_weights",
                               tuple(float(w) for w in self.edge_weights))


@dataclasses.dataclass(frozen=True)
class RecordRequest:
    """Async ingest of one session.  Resolves once the session is accepted
    into the (backpressured) write queue — and, when the scheduler flushes
    per tick, once the tick's batched flush has committed and its WAL
    record is durable."""
    namespace: str
    session_id: str
    messages: Tuple[Message, ...]
    conversation_id: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "messages", tuple(self.messages))
        if not self.messages:
            raise ValueError("RecordRequest needs at least one message")


@dataclasses.dataclass(frozen=True)
class EvictRequest:
    """Evict a whole namespace, or (superseded_only) just the triples
    superseded under conflict resolution."""
    namespace: str
    superseded_only: bool = False


@dataclasses.dataclass(frozen=True)
class CompactRequest:
    """Reclaim tombstoned rows across the whole store."""


MemoryRequest = Union[RetrieveRequest, RecordRequest, EvictRequest,
                      CompactRequest]


@dataclasses.dataclass
class RawRetrieval:
    """The no-budget payload: the fused ranking itself.  `row_ids` are
    global bank rows (valid until the next compaction remaps them),
    `triple_ids` the tenant-local triple ids behind them."""
    row_ids: List[int]
    triple_ids: List[int]
    scores: List[float]
    # True when the owning shard was down at retrieval time (empty by
    # design — the batch's surviving requests answered normally)
    degraded: bool = False


@dataclasses.dataclass
class MemoryResponse:
    """The uniform envelope every submitted request resolves to."""
    payload: Any                      # RetrievedContext | RawRetrieval |
    #                                   int (evict) | dict (record/compact)
    op: str = ""                      # retrieve | record | evict | compact
    status: str = "ok"                # "ok" | "error"
    error: Optional[str] = None
    exception: Optional[BaseException] = None   # in-process detail
    queued_s: float = 0.0             # submit -> tick pickup
    service_s: float = 0.0            # execution time inside the tick
    batch_size: int = 1               # requests sharing the device launch
    token_count: Optional[int] = None  # retrieves with a budget stage
    degraded: bool = False            # served with the owning shard down

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def result(self) -> Any:
        """Payload, or re-raise the request's failure."""
        if self.status != "ok":
            if self.exception is not None:
                raise self.exception
            raise RuntimeError(self.error or "memory request failed")
        return self.payload


# -- wire mapping (serving/frontend.py + the SDK's HTTP mode) ----------------
#
# The HTTP surface speaks exactly these types: a JSON body maps onto one
# typed request (validated by the same __post_init__ checks a direct caller
# gets), and every response is the MemoryResponse envelope rendered to
# JSON.  Keeping the codec here — next to the types — means the wire format
# can never drift from the in-process API.

def message_from_json(obj: dict) -> Message:
    if not isinstance(obj, dict) or "text" not in obj:
        raise ValueError("message must be an object with at least 'text'")
    return Message(speaker=str(obj.get("speaker", "user")),
                   text=str(obj["text"]),
                   timestamp=float(obj.get("timestamp", 0.0)))


def retrieve_request_from_json(obj: dict, namespace: str) -> RetrieveRequest:
    """One JSON query object -> RetrieveRequest.  `namespace` is the
    tenancy-scoped namespace the frontend already resolved (api key ->
    tenant -> `tenant/<client namespace>`); the body never names a raw
    service namespace."""
    stages = obj.get("stages")
    return RetrieveRequest(
        namespace=namespace, query=str(obj.get("query", "")),
        top_k=None if obj.get("top_k") is None else int(obj["top_k"]),
        dense_weight=(None if obj.get("dense_weight") is None
                      else float(obj["dense_weight"])),
        sparse_weight=(None if obj.get("sparse_weight") is None
                       else float(obj["sparse_weight"])),
        stages=None if stages is None else tuple(stages),
        hops=None if obj.get("hops") is None else int(obj["hops"]),
        edge_weights=(None if obj.get("edge_weights") is None
                      else tuple(float(w) for w in obj["edge_weights"])),
        graph_weight=(None if obj.get("graph_weight") is None
                      else float(obj["graph_weight"])))


def record_request_from_json(obj: dict, namespace: str) -> RecordRequest:
    msgs = obj.get("messages")
    if not isinstance(msgs, list):
        raise ValueError("record body needs a 'messages' list")
    return RecordRequest(
        namespace=namespace,
        session_id=str(obj.get("session_id", "s0")),
        messages=tuple(message_from_json(m) for m in msgs),
        conversation_id=obj.get("conversation_id"))


def payload_to_json(payload: Any) -> Any:
    """Render a response payload for the wire.  RetrievedContext and
    RawRetrieval become typed objects (`kind` discriminates); ints/dicts
    (evict counts, record/compact summaries) pass through."""
    if payload is None or isinstance(payload, (int, float, str, dict)):
        return payload
    if isinstance(payload, RawRetrieval):
        return {"kind": "raw_retrieval", "row_ids": list(payload.row_ids),
                "triple_ids": list(payload.triple_ids),
                "scores": list(payload.scores),
                "degraded": bool(payload.degraded)}
    # RetrievedContext (duck-typed: core.memory imports this module's
    # sibling types, so importing it here would cycle)
    if hasattr(payload, "triples") and hasattr(payload, "text"):
        return {
            "kind": "retrieved_context",
            "text": payload.text,
            "token_count": payload.token_count,
            "degraded": bool(getattr(payload, "degraded", False)),
            "triples": [dataclasses.asdict(t) for t in payload.triples],
            "summaries": [dataclasses.asdict(s) for s in payload.summaries],
        }
    return repr(payload)


def response_to_json(resp: "MemoryResponse") -> dict:
    """The uniform wire envelope: every field of MemoryResponse except the
    in-process `exception` object."""
    return {
        "status": resp.status,
        "op": resp.op,
        "error": resp.error,
        "payload": payload_to_json(resp.payload),
        "queued_s": resp.queued_s,
        "service_s": resp.service_s,
        "batch_size": resp.batch_size,
        "token_count": resp.token_count,
        "degraded": resp.degraded,
    }


def as_retrieve_request(req, top_k: Optional[int] = None) -> RetrieveRequest:
    """Coerce the legacy positional shape — an (namespace, query) tuple —
    into a RetrieveRequest.  A batch-global `top_k` kwarg becomes the
    per-request default (an explicit per-request top_k wins: that is the
    fix for the old silently-shared batch-global k)."""
    if isinstance(req, RetrieveRequest):
        if top_k is not None and req.top_k is None:
            return dataclasses.replace(req, top_k=top_k)
        return req
    ns, q = req
    return RetrieveRequest(namespace=ns, query=q, top_k=top_k)
