"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

TPU adaptation (DESIGN.md §3): training/prefill use the *chunked SSD matmul
form* — intra-chunk attention-like einsums plus an inter-chunk state scan —
which maps the recurrence onto MXU matmuls instead of a length-L sequential
scan (the CUDA kernel's approach doesn't transfer; the block-matrix algebra
does, and is exactly the paper's "duality").  Decode is the O(1) recurrent
state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.module import ParamSpec


def dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return d_in, H, s.n_groups, s.state_dim, s.head_dim, s.conv_width


def specs(cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, G, N, P, W = dims(cfg)
    conv_dim = d_in + 2 * G * N
    return {
        "in_proj": ParamSpec((d, 2 * d_in + 2 * G * N + H), ("embed", "state"),
                             init="scaled_normal", scale=1.0),
        "conv_w": ParamSpec((W, conv_dim), (None, "state"), init="scaled_normal", scale=1.0),
        "conv_b": ParamSpec((conv_dim,), ("state",), init="zeros"),
        "A_log": ParamSpec((H,), ("state",), init="ssm_alog"),
        "D": ParamSpec((H,), ("state",), init="ones"),
        "dt_bias": ParamSpec((H,), ("state",), init="ssm_dt_bias"),
        "norm_scale": ParamSpec((d_in,), ("state",), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("state", "embed"), init="scaled_normal", scale=1.0),
    }


def _split_proj(cfg, proj):
    d_in, H, G, N, P, W = dims(cfg)
    z, xs, Bc, Cc, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1)
    return z, xs, Bc, Cc, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,L,C), w: (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b


def _gated_norm(y, z, scale, eps):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = (yf * yf).mean(-1, keepdims=True)
    return ((yf / jnp.sqrt(var + eps)) * scale.astype(jnp.float32)).astype(y.dtype)


def apply(params, cfg, x, *, mode: str = "train", cache=None,
          return_cache: bool = False):
    """x: (B,L,d).  mode train/prefill: chunked SSD over the full sequence
    (optionally emitting a decode cache); mode decode: single-step with
    cache = {"conv": (B,W-1,conv_dim), "state": (B,H,P,N)}."""
    s = cfg.ssm
    d_in, H, G, N, P, W = dims(cfg)
    dt_ = x.dtype
    B_, L, d = x.shape

    proj = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(dt_))
    z, xs, Bc, Cc, dtp = _split_proj(cfg, proj)
    xBC = jnp.concatenate([xs, Bc, Cc], axis=-1)

    if mode == "decode":
        conv_cache = cache["conv"]                  # (B, W-1, conv_dim)
        window = jnp.concatenate([conv_cache.astype(dt_), xBC], axis=1)  # (B,W,·)
        conv_out = (window * params["conv_w"].astype(dt_)).sum(1, keepdims=True)
        conv_out = conv_out + params["conv_b"].astype(dt_)
        new_conv = window[:, 1:]
    else:
        conv_out = _causal_conv(xBC, params["conv_w"].astype(dt_),
                                params["conv_b"].astype(dt_))
        new_conv = xBC[:, -(W - 1):] if return_cache else None
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)

    xh = xs.reshape(B_, L, H, P)
    Bg = Bc.reshape(B_, L, G, N)
    Cg = Cc.reshape(B_, L, G, N)
    # broadcast groups over heads
    rep = H // G
    Bh = jnp.repeat(Bg, rep, axis=2)                # (B,L,H,N)
    Ch = jnp.repeat(Cg, rep, axis=2)
    dt_full = jax.nn.softplus(dtp.astype(jnp.float32)
                              + params["dt_bias"].astype(jnp.float32))  # (B,L,H)
    A = jnp.exp(params["A_log"].astype(jnp.float32))                     # (H,)
    log_a = -dt_full * A                                                  # (B,L,H)
    dtx = xh * dt_full.astype(dt_)[..., None]                             # (B,L,H,P)

    if mode == "decode":
        # h: (B,H,P,N);  h' = exp(log_a) h + dtx ⊗ B;  y = h'·C + D x
        h = cache["state"].astype(jnp.float32)
        a = jnp.exp(log_a[:, 0])[:, :, None, None]                        # (B,H,1,1)
        upd = jnp.einsum("bhp,bhn->bhpn", dtx[:, 0].astype(jnp.float32),
                         Bh[:, 0].astype(jnp.float32))
        h_new = a * h + upd
        y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch[:, 0].astype(jnp.float32))
        y = y + params["D"].astype(jnp.float32)[:, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(B_, 1, d_in).astype(dt_)
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": h_new.astype(cache["state"].dtype)}
    else:
        Q = min(s.chunk_size, L)
        if L % Q != 0:
            pad = Q - L % Q
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtx = jnp.pad(dtx, ((0, 0), (0, pad), (0, 0), (0, 0)))
            log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
            Lp = L + pad
        else:
            Lp = L
        nc = Lp // Q
        xc = dtx.reshape(B_, nc, Q, H, P)
        bc = Bh.reshape(B_, nc, Q, H, N)
        cc = Ch.reshape(B_, nc, Q, H, N)
        la = log_a.reshape(B_, nc, Q, H)
        la_cum = jnp.cumsum(la, axis=2)                                   # (B,nc,Q,H)
        la_tot = la_cum[:, :, -1]                                         # (B,nc,H)

        # Intra-chunk (the "attention" dual): scores[s,t] = C_s·B_t e^{la_s-la_t}
        cb = jnp.einsum("bcshn,bcthn->bchst", cc, bc,
                        preferred_element_type=jnp.float32)
        seg = la_cum.transpose(0, 1, 3, 2)                                # (B,nc,H,Q)
        ldiff = seg[..., :, None] - seg[..., None, :]                     # (B,nc,H,Q,Q)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        L_mat = jnp.where(causal, jnp.exp(ldiff), 0.0)
        y_intra = jnp.einsum("bchst,bcthp->bcshp", cb * L_mat,
                             xc.astype(jnp.float32))

        # Chunk summary states: S_c = Σ_t e^{la_tot - la_t} B_t ⊗ x_t
        decay_to_end = jnp.exp(la_tot[:, :, None] - la_cum)               # (B,nc,Q,H)
        S_c = jnp.einsum("bcthn,bcthp->bchnp",
                         (bc * decay_to_end[..., None]).astype(jnp.float32),
                         xc.astype(jnp.float32))                          # (B,nc,H,N,P)

        # Inter-chunk recurrence over nc chunks (tiny scan, nc = L/Q).
        a_chunk = jnp.exp(la_tot)                                         # (B,nc,H)
        init = (cache["state"].astype(jnp.float32).transpose(0, 1, 3, 2)
                if (mode == "prefill" and cache is not None)
                else jnp.zeros((B_, H, N, P), jnp.float32))

        def chunk_step(h, inp):
            ac, sc = inp                                                  # (B,H), (B,H,N,P)
            h_new = h * ac[..., None, None] + sc
            return h_new, h                                               # emit state *before* chunk

        (h_last, h_prevs) = jax.lax.scan(
            chunk_step, init,
            (a_chunk.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)))
        h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                        # (B,nc,H,N,P)

        # Inter-chunk contribution: y_inter[s] = e^{la_s} C_s · h_prev
        decay_in = jnp.exp(la_cum)                                        # (B,nc,Q,H)
        y_inter = jnp.einsum("bcshn,bchnp->bcshp", cc.astype(jnp.float32),
                             h_prevs) * decay_in[..., None]
        y = (y_intra + y_inter).reshape(B_, Lp, H, P)[:, :L]
        y = y + params["D"].astype(jnp.float32)[:, None] * xh.reshape(B_, Lp, H, P)[:, :L].astype(jnp.float32)
        y = y.reshape(B_, L, d_in).astype(dt_)
        new_cache = None
        if return_cache:
            new_cache = {"conv": new_conv.astype(dt_),
                         "state": h_last.transpose(0, 1, 3, 2).astype(dt_)}

    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(dt_))
    return out, new_cache


def init_cache(cfg, batch: int, dtype):
    d_in, H, G, N, P, W = dims(cfg)
    return {
        "conv": jnp.zeros((batch, W - 1, d_in + 2 * G * N), dtype),
        "state": jnp.zeros((batch, H, P, N), dtype),
    }


def cache_specs(cfg, batch: int, dtype):
    d_in, H, G, N, P, W = dims(cfg)
    return {
        "conv": ((batch, W - 1, d_in + 2 * G * N), ("batch", None, "state"), dtype),
        "state": ((batch, H, P, N), ("batch", "state", None, None), dtype),
    }
