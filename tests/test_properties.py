"""Hypothesis property-based tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis isn't baked into every image; the whole module skips (not
# errors) at collection when it's absent, and runs normally when present
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bm25 import BM25Index
from repro.core.budget import TokenBudgeter
from repro.core.hybrid import rrf_fuse
from repro.core.summaries import SummaryStore
from repro.core.triples import Triple
from repro.data.tokenizer import HashTokenizer
from repro.kernels import ref
from repro.models.config import plan_segments

WORDS = st.text(alphabet="abcdefghij ", min_size=1, max_size=40)


# -- tokenizer -----------------------------------------------------------------

@given(WORDS)
@settings(max_examples=60, deadline=None)
def test_tokenizer_deterministic_and_bounded(text):
    t1, t2 = HashTokenizer(1024), HashTokenizer(1024)
    a, b = t1.encode(text), t2.encode(text)
    assert a == b
    assert all(0 <= i < 1024 for i in a)
    assert t1.count(text) == len(a)


@given(WORDS)
@settings(max_examples=30, deadline=None)
def test_tokenizer_decode_roundtrip_words(text):
    tok = HashTokenizer(1 << 20)          # big vocab: no collisions expected
    ids = tok.encode(text)
    assert tok.decode(ids) == " ".join(w.lower() for w in tok.words(text))


# -- top-k exactness ------------------------------------------------------------

@given(st.integers(1, 6), st.integers(2, 40), st.integers(2, 16),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_topk_ref_is_exact(q_n, bank_n, dim, seed):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (q_n, dim))
    bank = jax.random.normal(jax.random.fold_in(key, 1), (bank_n, dim))
    kk = min(5, bank_n)
    s, i = ref.topk_mips_ref(q, bank, k=kk)
    dots = np.asarray(q) @ np.asarray(bank).T
    for r in range(q_n):
        want = set(np.argsort(-dots[r], kind="stable")[:kk].tolist())
        assert set(np.asarray(i)[r].tolist()) == want


# -- BM25 vs dict oracle ----------------------------------------------------------

def _bm25_oracle(docs, query_terms, k1=1.5, b=0.75):
    import math
    N = len(docs)
    avg = sum(max(1, len(d)) for d in docs) / N
    df = {}
    for d in docs:
        for t in set(d):
            df[t] = df.get(t, 0) + 1
    out = []
    for d in docs:
        s = 0.0
        for t in set(query_terms):
            if t not in df:
                continue
            tf = d.count(t)
            idf = math.log(1.0 + (N - df[t] + 0.5) / (df[t] + 0.5))
            s += idf * tf * (k1 + 1) / (tf + k1 * (1 - b + b * max(1, len(d)) / avg))
    # note: oracle returns scores in doc order
        out.append(s)
    return out


@given(st.lists(st.lists(st.sampled_from("abcdefg"), min_size=1, max_size=8),
                min_size=2, max_size=10),
       st.lists(st.sampled_from("abcdefg"), min_size=1, max_size=4))
@settings(max_examples=25, deadline=None)
def test_bm25_matches_dict_oracle(docs, query):
    idx = BM25Index()
    idx.add([" ".join(d) for d in docs])
    got = np.asarray(idx.scores(" ".join(query)))
    want = np.asarray(_bm25_oracle(docs, query))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# -- RRF fusion --------------------------------------------------------------------

@given(st.lists(st.integers(0, 30), min_size=1, max_size=20, unique=True),
       st.lists(st.integers(0, 30), min_size=1, max_size=20, unique=True))
@settings(max_examples=40, deadline=None)
def test_rrf_front_of_both_lists_wins(r1, r2):
    fused = rrf_fuse([r1, r2])
    ids = [d for d, _ in fused]
    assert set(ids) == set(r1) | set(r2)
    # an item first in BOTH rankings must be ranked first overall
    if r1 and r2 and r1[0] == r2[0]:
        assert ids[0] == r1[0]
    # scores descending
    scores = [s for _, s in fused]
    assert all(a >= b for a, b in zip(scores, scores[1:]))


# -- budget invariant ----------------------------------------------------------------

@given(st.integers(10, 200), st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_budget_never_exceeded(budget, n):
    tok = HashTokenizer(4096)
    budgeter = TokenBudgeter(budget=budget, tokenizer=tok)
    cands = [(Triple("subj", "pred", f"object {i} with several words",
                     conversation_id="c", session_id=f"s{i % 3}",
                     timestamp=float(i)), float(n - i)) for i in range(n)]
    ctx = budgeter.select(cands, SummaryStore())
    assert ctx.token_count <= budget


# -- layer planner -------------------------------------------------------------------

@given(st.lists(st.sampled_from([("attn", "mlp"), ("rglru", "mlp"),
                                 ("ssm", "none"), ("attn", "moe")]),
                min_size=1, max_size=80))
@settings(max_examples=50, deadline=None)
def test_plan_segments_partitions_exactly(kinds):
    kinds = tuple(kinds)
    segs = plan_segments(kinds)
    rebuilt = []
    for period, repeats in segs:
        rebuilt.extend(list(period) * repeats)
    assert tuple(rebuilt) == kinds


# -- optimizer sanity ------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_adamw_descends_on_quadratic(seed):
    from repro.training import optimizer as opt
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros((8,))}
    cfg = opt.OptimizerConfig(peak_lr=0.05, warmup_steps=1, total_steps=60,
                              weight_decay=0.0)
    state = opt.init(cfg, params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(cfg, params, g, state)
    assert float(loss(params)) < 0.5 * l0
