"""Per-tenant QoS benchmark: one abusive tenant vs a fleet of well-behaved
closed-loop clients.

Two phases over the same data and the same scheduler policy:

* **baseline** — C well-behaved clients (spread over T tenants), each
  submitting one retrieve at a time in a closed loop;
* **abuse** — the same fleet, plus one abusive tenant firing large
  `submit_many` blocks asynchronously as fast as admission lets it (never
  waiting for results — the open-loop flood shape that starved everyone
  under the PR-5 FIFO drain).

The number that matters is **protection**: the well-behaved fleet's p99
under abuse divided by its baseline p99.  Under FIFO the abuser's backlog
sat in front of every tick and the ratio exploded with flood depth; with
admission control (WRR slots per tick + per-tenant queue cap shedding the
flood) it must stay small.  `--assert-protection 2.0` enforces the PR's
acceptance bar — well-behaved p99 degrades < 2x — and CI gates on it.

    PYTHONPATH=src python benchmarks/qos_bench.py \
        [--clients 100] [--tenants 20] [--seconds 3] \
        [--abuse-block 64] [--max-batch 256] \
        [--json BENCH_qos.json] [--assert-protection 2.0]
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core import (AdmissionError, AdmissionPolicy, MemoryScheduler,
                        MemoryService, Message, RetrieveRequest, TenantPolicy)
from repro.core.embedder import HashEmbedder

CITIES = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi", "Windhoek",
          "Sapporo"]
QUERIES = ["Which city does the user live in?",
           "What pet was adopted?",
           "What is the user's job?"]
ABUSER = "abuser"


def _build_service(tenants: int) -> MemoryService:
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800)
    for u in range(tenants):
        svc.record(f"w{u}/c0", "s0", [
            Message("U", f"I live in {CITIES[u % len(CITIES)]}.",
                    1700000000.0),
            Message("U", f"I adopted a pet named P{u}.", 1700000000.0),
            Message("U", "I work as a welder.", 1700000000.0)])
    svc.record(f"{ABUSER}/c0", "s0", [
        Message("U", "I live in Flood City.", 1700000000.0)])
    return svc


def _policy(max_batch: int) -> AdmissionPolicy:
    """One uniform contract for everyone — the abuser gets no special
    treatment, which is the point: fairness must come from the mechanism,
    not from hand-tuning the attacker."""
    return AdmissionPolicy(
        default=TenantPolicy(max_queued=4 * max_batch),
        shed_retry_after_s=0.05)


def _well_behaved_phase(sched: MemoryScheduler, clients: int, tenants: int,
                        seconds: float, abuse_block: int = 0) -> dict:
    lat: list[list[float]] = [[] for _ in range(clients)]
    errors = [0]
    abuse = {"submitted": 0, "shed": 0}
    stop_at = time.perf_counter() + seconds
    parties = clients + (1 if abuse_block else 0)
    barrier = threading.Barrier(parties)

    def client(c: int) -> None:
        req = RetrieveRequest(f"w{c % tenants}/c0",
                              QUERIES[c % len(QUERIES)])
        barrier.wait()
        while time.perf_counter() < stop_at:
            t0 = time.perf_counter()
            try:
                resp = sched.submit(req).result(timeout=60)
                if not resp.ok:
                    errors[0] += 1
                    continue
            except AdmissionError:
                # well-behaved tenants should essentially never be shed;
                # count it as an error so the report surfaces it
                errors[0] += 1
                time.sleep(0.01)
                continue
            lat[c].append(time.perf_counter() - t0)

    def abuser() -> None:
        block = [RetrieveRequest(f"{ABUSER}/c0", QUERIES[0])] * abuse_block
        barrier.wait()
        while time.perf_counter() < stop_at:
            try:
                sched.submit_many(block, tenant=ABUSER)
                abuse["submitted"] += abuse_block
            except AdmissionError as e:
                abuse["shed"] += abuse_block
                # the flood ignores most of the retry hint — that is what
                # makes it abusive — but yields the GIL so the bench
                # measures scheduling policy, not lock spin
                time.sleep(min(0.001, e.retry_after_s))

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    if abuse_block:
        threads.append(threading.Thread(target=abuser))
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    # drain whatever the abuser left queued so the next phase starts clean
    while sched.admission.total_queued:
        time.sleep(0.01)
    flat = np.asarray([x for per in lat for x in per])
    out = {
        "requests": int(flat.size),
        "throughput_rps": float(flat.size / wall),
        "p50_ms": float(np.percentile(flat, 50) * 1e3),
        "p99_ms": float(np.percentile(flat, 99) * 1e3),
        "errors": errors[0],
    }
    if abuse_block:
        out["abuser"] = dict(abuse)
    return out


def run(clients: int = 100, tenants: int = 20, seconds: float = 3.0,
        abuse_block: int = 64, tick_interval: float = 0.002,
        max_batch: int = 256, json_path=None,
        assert_protection=None) -> dict:
    svc = _build_service(tenants)
    # warm every pow2 search bucket a tick can reach, so p99 measures the
    # scheduling policy and not one-off jit compiles mid-phase
    n = 1
    while n <= max_batch:
        svc.retrieve_batch([(f"w{i % tenants}/c0", QUERIES[0])
                            for i in range(n)])
        n *= 2
    print(f"# QoS bench: {clients} well-behaved clients over {tenants} "
          f"tenants + 1 abusive tenant ({abuse_block}-request async "
          f"blocks), {seconds:.1f}s per phase, max_batch={max_batch}")
    report = {"clients": clients, "tenants": tenants, "seconds": seconds,
              "abuse_block": abuse_block, "max_batch": max_batch}

    sched = MemoryScheduler(svc, tick_interval_s=tick_interval,
                            max_batch=max_batch,
                            admission=_policy(max_batch))
    try:
        baseline = _well_behaved_phase(sched, clients, tenants, seconds)
        abused = _well_behaved_phase(sched, clients, tenants, seconds,
                                     abuse_block=abuse_block)
        st = sched.stats()
    finally:
        sched.close()
    protection = abused["p99_ms"] / baseline["p99_ms"]
    report.update(baseline=baseline, under_abuse=abused,
                  p99_degradation=protection,
                  admission=st["admission"],
                  avg_batch=st.get("avg_retrieves_per_launch"))
    print(f"baseline    : {baseline['throughput_rps']:8.1f} rps  "
          f"p50 {baseline['p50_ms']:6.1f}ms  p99 {baseline['p99_ms']:6.1f}ms")
    print(f"under abuse : {abused['throughput_rps']:8.1f} rps  "
          f"p50 {abused['p50_ms']:6.1f}ms  p99 {abused['p99_ms']:6.1f}ms  "
          f"(abuser admitted {abused['abuser']['submitted']}, "
          f"shed {abused['abuser']['shed']})")
    print(f"well-behaved p99 degradation under abuse: {protection:.2f}x "
          f"(errors: {baseline['errors']}/{abused['errors']})")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    if assert_protection is not None and protection > assert_protection:
        raise AssertionError(
            f"one abusive tenant degraded well-behaved p99 by "
            f"{protection:.2f}x (bar: < {assert_protection:.2f}x) — "
            "admission control is not protecting the fleet")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=100,
                    help="well-behaved closed-loop client threads")
    ap.add_argument("--tenants", type=int, default=20,
                    help="tenants the well-behaved clients spread over")
    ap.add_argument("--seconds", type=float, default=3.0)
    ap.add_argument("--abuse-block", type=int, default=64,
                    help="requests per async abuser submit_many block")
    ap.add_argument("--tick-interval", type=float, default=0.002)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_qos.json artifact")
    ap.add_argument("--assert-protection", type=float, default=None,
                    help="fail if well-behaved p99 under abuse exceeds "
                         "this multiple of its no-abuser baseline")
    args = ap.parse_args()
    run(clients=args.clients, tenants=args.tenants, seconds=args.seconds,
        abuse_block=args.abuse_block, tick_interval=args.tick_interval,
        max_batch=args.max_batch, json_path=args.json,
        assert_protection=args.assert_protection)
