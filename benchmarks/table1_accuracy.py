"""Paper Table 1 analogue: LLM-judge accuracy by reasoning category,
Memori vs raw-chunk RAG vs full-context ceiling (+ dual-layer ablations)."""
from __future__ import annotations

import time

from benchmarks.common import evaluate
from repro.data.locomo_synth import CATEGORIES

SYSTEMS = ["memori", "memori-triples-only", "rag", "full-context"]


def run(csv_rows):
    print("\n# Table 1 — accuracy by category (synthetic LoCoMo, oracle judge)")
    header = f"{'method':22s} " + " ".join(f"{c:>11s}" for c in CATEGORIES) \
        + f" {'overall':>8s} {'tokens':>7s}"
    print(header)
    for name in SYSTEMS:
        t0 = time.time()
        r = evaluate(name)
        us = (time.time() - t0) * 1e6 / max(1, r.n_questions)
        cols = " ".join(f"{100*r.per_category[c]:10.2f}%" for c in CATEGORIES)
        print(f"{name:22s} {cols} {100*r.overall:7.2f}% {r.mean_tokens:7.0f}")
        csv_rows.append((f"table1/{name}", us, f"{100*r.overall:.2f}"))
    return csv_rows


if __name__ == "__main__":
    run([])
