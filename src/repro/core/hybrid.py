"""Hybrid retrieval: cosine similarity over triple embeddings + BM25 keyword
matching (paper §3.3), fused by weighted reciprocal-rank fusion.

Two implementations of the same contract:

* `rrf_fuse` — the scalar oracle: one query, Python lists, a dict loop.
  Accumulates in float32 so the batched device path can match it bit-for-bit.
* `rrf_fuse_batch` — the production path: a whole batch of queries' dense and
  sparse id matrices fused in ONE device op (rank-position scores, a masked
  segment-sum over an O(P²) id-equality mask, and a single lexicographic
  `jax.lax.sort` on (-score, id)).  No per-request Python loop; the (B, k)
  result crosses to the host once.  Ordering (including duplicate-id
  suppression, -1 padding, and score ties broken by lower doc id) matches
  `rrf_fuse` exactly — property-tested in tests/test_retrieval_engine.py.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rrf_fuse(rankings: Sequence[Sequence[int]], weights: Sequence[float] = None,
             c: float = 60.0) -> List[Tuple[int, float]]:
    """Weighted reciprocal-rank fusion.  rankings: lists of doc ids, best
    first (ids < 0 are padding and ignored).  Returns (doc_id, fused_score)
    sorted descending, ties broken by lower doc id.  Within one ranking only
    a doc's best (first) rank counts — a duplicated id must not accumulate
    score, or any upstream bug that emits duplicates silently inflates that
    doc's fused rank.  Scores accumulate in float32: this function is the
    oracle for the on-device `rrf_fuse_batch`, which must match it exactly."""
    weights = weights or [1.0] * len(rankings)
    scores: Dict[int, np.float32] = {}
    zero = np.float32(0.0)
    for ranking, w in zip(rankings, weights):
        w32 = np.float32(w)
        seen = set()
        for rank, doc in enumerate(ranking):
            doc = int(doc)
            if doc < 0 or doc in seen:
                continue
            seen.add(doc)
            scores[doc] = np.float32(
                scores.get(doc, zero) + w32 / np.float32(c + rank + 1.0))
    return sorted(((d, float(s)) for d, s in scores.items()),
                  key=lambda kv: (-kv[1], kv[0]))


@functools.partial(jax.jit, static_argnames=("k", "c"))
def _rrf_fuse_device(ids, pos, ranking_id, weights, *, k: int, c: float):
    """ids (B, P) i32 concatenated rankings (-1 padding); pos (P,) i32 rank
    within the owning ranking; ranking_id (P,) i32 column -> ranking;
    weights (B, R) f32 per-row ranking weights (every request in the batch
    may weight dense vs sparse differently — the typed-request API's
    per-request `weights` option rides in here).  Returns
    (fused_ids (B, k) i32, scores (B, k) f32)."""
    B, P = ids.shape
    valid = ids >= 0                                            # (B, P)
    eq = ids[:, :, None] == ids[:, None, :]                     # (B, P, P)
    earlier = jnp.tril(jnp.ones((P, P), bool), k=-1)            # l < j
    same_ranking = ranking_id[:, None] == ranking_id[None, :]
    # within one ranking only the first occurrence of an id scores:
    # dup[b, j] <=> some column l < j in the same ranking holds the same id
    dup = jnp.any(eq & (earlier & same_ranking)[None, :, :], axis=2)
    contrib = jnp.where(
        valid & ~dup,
        weights[:, ranking_id] /
        (jnp.float32(c) + pos.astype(jnp.float32)[None, :] + 1.0),
        0.0)                                                    # (B, P)
    # fused[b, j] = sum of contribs at every column holding the same id,
    # accumulated as a left-fold over the rankings in ranking order.  Each
    # per-ranking term has at most ONE nonzero per (b, j) (duplicates are
    # zeroed above) and adding exact zeros is the identity, so the float32
    # rounding sequence is bit-identical to the scalar oracle's dict
    # accumulation — for any number of rankings, not just two.
    fused = jnp.zeros((B, P), jnp.float32)
    for r in range(weights.shape[1]):
        in_r = (ranking_id == r).astype(jnp.float32)            # (P,)
        fused = fused + jnp.sum(
            (contrib * in_r[None, :])[:, None, :] * eq, axis=2)
    # first concatenated occurrence of each id represents it in the output
    keep = valid & ~jnp.any(eq & earlier[None, :, :], axis=2)
    neg = jnp.where(keep, -fused, jnp.inf)
    sort_ids = jnp.where(keep, ids, jnp.iinfo(jnp.int32).max)
    out_ids = jnp.where(keep, ids, -1)
    # lexicographic (-score, id): descending score, ties to the lower doc id
    neg_s, _, ids_s = jax.lax.sort((neg, sort_ids, out_ids), dimension=1,
                                   num_keys=2, is_stable=True)
    kk = min(k, P)
    live = neg_s[:, :kk] < jnp.inf
    return (jnp.where(live, ids_s[:, :kk], -1),
            jnp.where(live, -neg_s[:, :kk], 0.0))


def rrf_fuse_batch(rankings, weights=None, c: float = 60.0, k: int = 10):
    """Batched on-device RRF: `rankings` is a sequence of (B, P_i) id
    matrices, best-first along axis 1 with -1 padding (the stacked dense and
    sparse retrieval outputs).  `weights` is either one weight per ranking
    (shared by the whole batch, the legacy shape) or a (B, R) array giving
    every batch row its own per-ranking weights — the typed-request API uses
    the latter so mixed-weight clients still fuse in ONE launch.  Returns
    device arrays (fused_ids (B, k) i32, fused_scores (B, k) f32), -1/0
    beyond each row's fused pool.  Row b equals
    `rrf_fuse([rankings[0][b], rankings[1][b], ...], weights_b, c)[:k]`
    exactly (same ids, same order, same float32 scores)."""
    rankings = [jnp.asarray(r, jnp.int32) for r in rankings]
    if not rankings or rankings[0].shape[0] == 0:
        B = rankings[0].shape[0] if rankings else 0
        return (jnp.full((B, k), -1, jnp.int32),
                jnp.zeros((B, k), jnp.float32))
    R = len(rankings)
    B = rankings[0].shape[0]
    w = np.asarray([1.0] * R if weights is None else weights, np.float32)
    if w.ndim == 1:
        if w.shape != (R,):
            raise ValueError(f"{w.shape[0]} weights for {R} rankings")
        w = np.broadcast_to(w, (B, R))
    elif w.shape != (B, R):
        raise ValueError(f"weights shape {w.shape} != ({B}, {R})")
    P_sizes = [int(r.shape[1]) for r in rankings]
    pos = np.concatenate([np.arange(p, dtype=np.int32) for p in P_sizes]) \
        if sum(P_sizes) else np.zeros((0,), np.int32)
    ranking_id = np.concatenate(
        [np.full((p,), i, np.int32) for i, p in enumerate(P_sizes)]) \
        if sum(P_sizes) else np.zeros((0,), np.int32)
    if sum(P_sizes) == 0:
        return (jnp.full((B, k), -1, jnp.int32),
                jnp.zeros((B, k), jnp.float32))
    ids = jnp.concatenate(rankings, axis=1)
    fused_ids, fused_scores = _rrf_fuse_device(
        ids, jnp.asarray(pos), jnp.asarray(ranking_id),
        jnp.asarray(w), k=k, c=float(c))
    P = sum(P_sizes)
    if P < k:
        fused_ids = jnp.pad(fused_ids, ((0, 0), (0, k - P)),
                            constant_values=-1)
        fused_scores = jnp.pad(fused_scores, ((0, 0), (0, k - P)))
    return fused_ids, fused_scores


def hybrid_search(query_text: str, query_vec, vindex, bm25, top_k: int = 24,
                  dense_weight: float = 1.0, sparse_weight: float = 0.7,
                  pool: int = 64) -> List[Tuple[int, float]]:
    """Returns [(triple_id, fused_score)] best-first, length <= top_k."""
    if vindex.n == 0:
        return []
    pool = min(pool, vindex.n)
    _, dense_ids = vindex.search(query_vec, k=pool)
    dense_rank = [int(i) for i in dense_ids[0] if i >= 0]
    _, sparse_ids = bm25.topk(query_text, k=pool)
    sparse_rank = [int(i) for i in sparse_ids]
    fused = rrf_fuse([dense_rank, sparse_rank],
                     weights=[dense_weight, sparse_weight])
    return fused[:top_k]
