"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b family: LayerNorm, partial
rotary (25%), full MHA.]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        arch_type="dense",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=6912,
        vocab_size=50304,
        source="[hf:stabilityai/stablelm-2-1_6b]",
        norm="layernorm",
        rope_pct=0.25,
        rope_theta=10000.0,
        act="silu",
        mlp_gated=True,
        long_context_window=8192,   # sliding-window variant for long_500k
    )
