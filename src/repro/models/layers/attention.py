"""Multi-head attention with GQA, partial RoPE, qk-norm, sliding-window,
prefix-LM and cross-attention — the single attention module used by every
attention-bearing architecture in the zoo.

Two numerics paths:
  * direct SDPA for small S*T (smoke tests, decode single-token queries);
  * chunked online-softmax SDPA (pure-JAX flash attention via lax.scan) for
    long sequences, so prefill_32k / train_4k never materialise (S, T) score
    or mask tensors.  The Pallas kernels in `repro.kernels` implement the
    same contract for real TPU hardware and are checked against these.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.module import ParamSpec
from repro.models.layers import rope as rope_lib
from repro.models.layers.norms import rms_norm

NEG_INF = -2.0e38
_DIRECT_LIMIT = 4 * 1024 * 1024   # max S*T for the direct path


def specs(cfg, *, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"),
                        init="scaled_normal", scale=1.0),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"),
                        init="scaled_normal", scale=1.0),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"),
                        init="scaled_normal", scale=1.0),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"),
                        init="scaled_normal", scale=1.0),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        s["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return s


# ---------------------------------------------------------------------------
# Masking (built from position arrays so chunked blocks can mask locally).
# ---------------------------------------------------------------------------

def _allowed(q_pos, kv_pos, *, kind: str, window: int, prefix_len,
             kv_len_valid):
    """Boolean allowed-mask (B, Sq, Tk) from (B,Sq) and (B,Tk) positions.
    kv positions < 0 are padding and always masked."""
    q = q_pos[:, :, None]
    t = kv_pos[:, None, :]
    B, S = q_pos.shape
    T = kv_pos.shape[1]
    if kind == "bidir":
        allowed = jnp.broadcast_to(t >= 0, (B, S, T))
    else:
        allowed = t <= q
        if kind == "prefix" and prefix_len is not None:
            pl = prefix_len if jnp.ndim(prefix_len) else jnp.full((q_pos.shape[0],), prefix_len)
            allowed = allowed | (t < pl[:, None, None])
    if window and window > 0:
        allowed = allowed & (t > q - window)
    if kv_len_valid is not None:
        kl = kv_len_valid if jnp.ndim(kv_len_valid) else jnp.full((q_pos.shape[0],), kv_len_valid)
        allowed = allowed & (t < kl[:, None, None])
    allowed = allowed & (t >= 0)
    return allowed


# ---------------------------------------------------------------------------
# SDPA: direct and chunked.
# ---------------------------------------------------------------------------

def _group(q, k, v):
    B, S, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, Dh).transpose(0, 2, 3, 1, 4)   # (B,K,G,S,D)
    kk = k.transpose(0, 2, 1, 3)                               # (B,K,T,D)
    vv = v.transpose(0, 2, 1, 3)
    return qg, kk, vv, (B, S, H, K, G, Dh)


def _ungroup(out, B, S, H, Dh):
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)


def _sdpa_direct(q, k, v, allowed, scale):
    qg, kk, vv, (B, S, H, K, G, Dh) = _group(q, k, v)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, kk,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(allowed[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs.astype(v.dtype), vv,
                     preferred_element_type=jnp.float32)
    return _ungroup(out.astype(q.dtype), B, S, H, v.shape[-1])


def _sdpa_chunked(q, k, v, q_pos, kv_pos, *, kind, window, prefix_len,
                  kv_len_valid, scale, q_block, kv_block, unroll=False):
    """Online-softmax blocked attention: O(q_block*kv_block) live scores."""
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K

    qb = min(q_block, S)
    kb = min(kv_block, T)
    Sp = -(-S // qb) * qb
    Tp = -(-T // kb) * kb
    q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_pos, ((0, 0), (0, Sp - S)))
    kv_pos = jnp.pad(kv_pos, ((0, 0), (0, Tp - T)), constant_values=-1)

    nq, nk = Sp // qb, Tp // kb
    # (nq, B, K, G, qb, D) and (nk, B, K, kb, D)
    qs = q.reshape(B, nq, qb, K, G, Dh).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(B, nk, kb, K, Dh).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, nk, kb, K, Dv).transpose(1, 0, 3, 2, 4)
    qps = q_pos.reshape(B, nq, qb).transpose(1, 0, 2)
    kps = kv_pos.reshape(B, nk, kb).transpose(1, 0, 2)

    def q_step(q_blk_in):
        qblk, qp = q_blk_in                      # (B,K,G,qb,D), (B,qb)

        def kv_step(carry, kv_blk_in):
            m, l, acc = carry
            kblk, vblk, kp = kv_blk_in
            s = jnp.einsum("bkgsd,bktd->bkgst", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            ok = _allowed(qp, kp, kind=kind, window=window,
                          prefix_len=prefix_len, kv_len_valid=kv_len_valid)
            s = jnp.where(ok[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgst,bktd->bkgsd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qb), jnp.float32)
        a0 = jnp.zeros((B, K, G, qb, Dv), jnp.float32)
        if unroll:      # probe mode: XLA cost analysis counts scan bodies once
            carry = (m0, l0, a0)
            for t in range(ks.shape[0]):
                carry, _ = kv_step(carry, (ks[t], vs[t], kps[t]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return out.astype(q.dtype)               # (B,K,G,qb,D)

    if unroll:
        outs = jnp.stack([q_step((qs[i], qps[i])) for i in range(nq)])
    else:
        outs = jax.lax.map(q_step, (qs, qps))     # (nq,B,K,G,qb,Dv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, H, Dv)
    return out[:, :S]


def attend(q, k, v, *, q_pos, kv_pos, kind: str = "causal", window: int = 0,
           prefix_len=None, kv_len_valid=None, scale: Optional[float] = None,
           q_block: int = 512, kv_block: int = 1024, unroll: bool = False):
    """Dispatching SDPA.  q: (B,S,H,Dh), k/v: (B,T,K,Dh)."""
    S, T = q.shape[1], k.shape[1]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if S * T <= _DIRECT_LIMIT or S == 1:
        allowed = _allowed(q_pos, kv_pos, kind=kind, window=window,
                           prefix_len=prefix_len, kv_len_valid=kv_len_valid)
        return _sdpa_direct(q, k, v, allowed, scale)
    if unroll:
        # probe mode: unrolled blocks must stay few or XLA CPU compile time
        # explodes; FLOP totals are block-size independent, so count with
        # coarse blocks (these never execute on real VMEM)
        q_block = max(q_block, -(-S // 16))
        kv_block = max(kv_block, -(-T // 8))
    return _sdpa_chunked(q, k, v, q_pos, kv_pos, kind=kind, window=window,
                         prefix_len=prefix_len, kv_len_valid=kv_len_valid,
                         scale=scale, q_block=q_block, kv_block=kv_block,
                         unroll=unroll)


# ---------------------------------------------------------------------------
# Module apply.
# ---------------------------------------------------------------------------

def _project_qkv(params, cfg, x, kv_x=None, *, use_rope=True, positions=None,
                 kv_positions=None, theta=None):
    kv_x = x if kv_x is None else kv_x
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", kv_x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", kv_x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if use_rope:
        th = theta if theta is not None else cfg.rope_theta
        q = rope_lib.apply_rope(q, positions, theta=th, pct=cfg.rope_pct)
        k = rope_lib.apply_rope(k, kv_positions, theta=th, pct=cfg.rope_pct)
    return q, k, v


def apply(params, cfg, x, *, positions, mode: str = "train",
          cache=None, cache_pos=None, mask_kind: str = "causal",
          window: int = 0, prefix_len=None, kv_x=None, kv_positions=None,
          use_rope: bool = True, theta=None, return_cache: bool = False):
    """Unified attention entry point; returns (out (B,S,D), new_cache|None)."""
    B = x.shape[0]
    dt = x.dtype
    new_cache = None

    if mode in ("train", "prefill"):
        kv_pos = kv_positions if kv_positions is not None else positions
        q, k, v = _project_qkv(params, cfg, x, kv_x, use_rope=use_rope,
                               positions=positions, kv_positions=kv_pos,
                               theta=theta)
        out = attend(q, k, v, q_pos=positions, kv_pos=kv_pos,
                     kind=("bidir" if kv_x is not None else mask_kind),
                     window=window, prefix_len=prefix_len,
                     unroll=cfg.force_unroll)
        if return_cache:
            new_cache = {"k": k, "v": v}
    elif mode == "decode":
        T = cache["k"].shape[1]
        q, k_new, v_new = _project_qkv(
            params, cfg, x, None, use_rope=use_rope,
            positions=positions, kv_positions=positions, theta=theta)
        # per-row cache positions (continuous batching: each slot has its own
        # sequence length); scalar cache_pos broadcasts.
        pos = jnp.asarray(cache_pos)
        if pos.ndim == 0:
            pos = jnp.broadcast_to(pos, (B,))
        rows = jnp.arange(B)
        ring = "pos" in cache                  # ring-buffer sliding window
        quant = "k_scale" in cache             # int8-quantised cache (§Perf)
        idx = pos % T if ring else pos
        new_cache = {}

        if quant:
            kq, ksc = quantize_kv(k_new[:, 0])
            vq, vsc = quantize_kv(v_new[:, 0])
            k_store = cache["k"].at[rows, idx].set(kq)
            v_store = cache["v"].at[rows, idx].set(vq)
            k_sc = cache["k_scale"].at[rows, idx].set(ksc)
            v_sc = cache["v_scale"].at[rows, idx].set(vsc)
            k_use = dequantize_kv(k_store, k_sc, dt)
            v_use = dequantize_kv(v_store, v_sc, dt)
            new_cache.update({"k_scale": k_sc, "v_scale": v_sc})
        else:
            k_store = cache["k"].at[rows, idx].set(
                k_new[:, 0].astype(cache["k"].dtype))
            v_store = cache["v"].at[rows, idx].set(
                v_new[:, 0].astype(cache["v"].dtype))
            k_use = k_store.astype(dt)
            v_use = v_store.astype(dt)
        new_cache.update({"k": k_store, "v": v_store})

        if ring:
            # Fixed window-sized cache, write slot = pos % W, true positions
            # tracked per slot so masking stays exact — this is what makes
            # dense-arch long_500k feasible (a 500k cache is never allocated).
            pos_arr = cache["pos"].at[rows, idx].set(pos.astype(jnp.int32))
            out = attend(q, k_use, v_use, q_pos=positions, kv_pos=pos_arr,
                         kind="causal", window=window)
            new_cache["pos"] = pos_arr
        else:
            kv_pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
            out = attend(q, k_use, v_use, q_pos=positions, kv_pos=kv_pos,
                         kind="causal", window=window, kv_len_valid=pos + 1)
    elif mode == "cross_decode":
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
        if "q_norm" in params:
            q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k, v = cache["k"].astype(dt), cache["v"].astype(dt)
        T = k.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        out = attend(q, k, v, q_pos=positions, kv_pos=kv_pos, kind="bidir")
        new_cache = cache
    else:
        raise ValueError(mode)

    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return proj, new_cache


def quantize_kv(x):
    """Symmetric per-(token, head) int8 quantisation.  x: (..., D)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_specs(cfg, batch: int, max_len: int, dtype, *, window: int = 0):
    """(shape, logical_axes, dtype) per cache entry.  window>0 and < max_len
    selects the ring-buffer layout (fixed window-sized cache + slot
    positions); cfg.kv_cache_quant == "int8" stores int8 values + per-token
    scales (halves the decode cache footprint — §Perf)."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ring = window and 0 < window < max_len
    quant = cfg.kv_cache_quant == "int8"
    T = window if ring else max_len
    shape = (batch, T, kv, hd)
    axes = ("batch", "seq", "kv_heads", "head_dim")
    kv_dtype = jnp.int8 if quant else dtype
    out = {"k": (shape, axes, kv_dtype), "v": (shape, axes, kv_dtype)}
    if quant:
        out["k_scale"] = ((batch, T, kv), ("batch", "seq", "kv_heads"), jnp.float32)
        out["v_scale"] = ((batch, T, kv), ("batch", "seq", "kv_heads"), jnp.float32)
    if ring:
        out["pos"] = ((batch, T), ("batch", "seq"), jnp.int32)
    return out


def init_cache(cfg, batch: int, max_len: int, dtype, *, window: int = 0):
    out = {}
    for name, (shape, _axes, dt) in cache_specs(cfg, batch, max_len, dtype,
                                                window=window).items():
        fill = -1 if name == "pos" else 0
        out[name] = jnp.full(shape, fill, dt)
    return out
