"""Client- and control-plane robustness: HttpMemory's bounded retry
(exponential backoff + jitter, Retry-After honored, transient-only) against
a deliberately flaky HTTP server, and dynamic AdmissionPolicy reload — the
authenticated admin endpoint swapping the mounted policy under live
traffic without a restart."""
import json
import random
import threading
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import pytest

from repro.core import (AdmissionPolicy, MemoryScheduler, MemoryService,
                        TenantPolicy)
from repro.core.admission import AdmissionError
from repro.core.embedder import HashEmbedder
from repro.core.sdk import HttpMemory, RetryPolicy
from repro.serving.frontend import MemoryFrontend

EMB = HashEmbedder()
KEYS = {"key-acme": "acme", "key-beta": "beta"}

_OK_ENV = {"status": "ok", "payload": {
    "kind": "retrieved_context", "triples": [], "summaries": [],
    "text": "remembered", "token_count": 3}}


# -- a scriptable flaky server -------------------------------------------------

class _FlakyServer:
    """Answers each request with the next scripted step: an int HTTP
    status, or "drop" (close the socket before responding — a connection
    reset from the client's point of view).  Steps past the end of the
    script answer 200."""

    def __init__(self, script):
        self.script = list(script)
        self.requests = []
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_POST(self):
                n = int(self.headers.get("Content-Length") or 0)
                outer.requests.append(
                    (self.path, json.loads(self.rfile.read(n) or b"{}")))
                step = outer.script.pop(0) if outer.script else 200
                if step == "drop":
                    self.connection.close()
                    return
                if step == 200:
                    body = _OK_ENV
                elif step == 429:
                    body = {"error": "rate limited", "reason": "rate_limited",
                            "retry_after_s": 0.25}
                else:
                    body = {"error": f"scripted {step}"}
                blob = json.dumps(body).encode()
                self.send_response(step)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(blob)))
                self.end_headers()
                self.wfile.write(blob)

            def log_message(self, *a):
                pass

        self.httpd = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
        self.thread = threading.Thread(target=self.httpd.serve_forever,
                                       daemon=True)
        self.thread.start()

    @property
    def url(self):
        return f"http://127.0.0.1:{self.httpd.server_port}"

    def close(self):
        self.httpd.shutdown()
        self.httpd.server_close()


def _client(url, **policy_kw):
    policy_kw.setdefault("base_backoff_s", 0.001)
    policy_kw.setdefault("max_backoff_s", 0.05)
    mem = HttpMemory(url, "key", retry=RetryPolicy(**policy_kw))
    sleeps = []
    mem._sleep = sleeps.append           # no real sleeping in tests
    mem._rng = random.Random(7)          # deterministic jitter
    return mem, sleeps


# -- HttpMemory retry ----------------------------------------------------------

def test_retries_5xx_then_succeeds():
    srv = _FlakyServer([500, 503, 200])
    try:
        mem, sleeps = _client(srv.url)
        ctx = mem.retrieve("anything")
        assert ctx.text == "remembered"
        assert mem.counters == {"requests": 1, "retries": 2}
        assert len(srv.requests) == 3
        assert len(sleeps) == 2 and all(0 < s <= 0.05 for s in sleeps)
        assert sleeps[1] > sleeps[0] / 2      # roughly exponential (jitter)
    finally:
        srv.close()


def test_retries_connection_drop():
    srv = _FlakyServer(["drop", 200])
    try:
        mem, _ = _client(srv.url)
        assert mem.retrieve("q").text == "remembered"
        assert mem.counters["retries"] == 1
        assert len(srv.requests) == 2
    finally:
        srv.close()


def test_429_backs_off_by_the_servers_retry_after_hint():
    srv = _FlakyServer([429, 200])
    try:
        mem, sleeps = _client(srv.url, max_backoff_s=2.0)
        assert mem.retrieve("q").text == "remembered"
        assert sleeps == [0.25]               # the hint, not the exponential
    finally:
        srv.close()


def test_max_attempts_exhaustion_reraises_the_last_failure():
    srv = _FlakyServer([500] * 8)
    try:
        mem, sleeps = _client(srv.url, max_attempts=3)
        with pytest.raises(RuntimeError, match="HTTP 500") as ei:
            mem.retrieve("q")
        assert ei.value.http_status == 500
        assert len(srv.requests) == 3         # tries == max_attempts, no more
        assert mem.counters["retries"] == 2 and len(sleeps) == 2
    finally:
        srv.close()


def test_non_retryable_4xx_fails_immediately():
    srv = _FlakyServer([404, 200])
    try:
        mem, sleeps = _client(srv.url)
        with pytest.raises(RuntimeError, match="HTTP 404"):
            mem.retrieve("q")
        assert len(srv.requests) == 1 and sleeps == []
        assert mem.counters["retries"] == 0
    finally:
        srv.close()


def test_retry_rate_limited_false_surfaces_429_immediately():
    srv = _FlakyServer([429, 200])
    try:
        mem, _ = _client(srv.url, retry_rate_limited=False)
        with pytest.raises(AdmissionError) as ei:
            mem.retrieve("q")
        assert ei.value.reason == "rate_limited"
        assert ei.value.retry_after_s == 0.25
        assert len(srv.requests) == 1
    finally:
        srv.close()


def test_retry_policy_backoff_shape_and_validation():
    pol = RetryPolicy(base_backoff_s=0.1, max_backoff_s=1.0, jitter=0.0)
    rng = random.Random(0)
    assert [pol.backoff_s(a, rng) for a in range(5)] == \
        [0.1, 0.2, 0.4, 0.8, 1.0]             # capped at max_backoff_s
    assert pol.backoff_s(0, rng, retry_after_s=9.0) == 1.0   # hint capped
    assert pol.backoff_s(3, rng, retry_after_s=0.3) == 0.3   # hint replaces
    jittered = RetryPolicy(base_backoff_s=0.1, max_backoff_s=10.0,
                           jitter=0.5)
    for a in range(4):
        raw = 0.1 * 2 ** a
        assert raw / 2 <= jittered.backoff_s(a, rng) <= raw
    for bad in (dict(max_attempts=0), dict(base_backoff_s=-1),
                dict(jitter=1.5)):
        with pytest.raises(ValueError):
            RetryPolicy(**bad)


# -- dynamic admission policy reload -------------------------------------------

def _call(fe, path, body=None, key="key-acme", method=None):
    req = urllib.request.Request(
        fe.address + path,
        data=None if body is None else json.dumps(body).encode(),
        headers={"Authorization": f"Bearer {key}"},
        method=method or ("GET" if body is None else "POST"))
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, json.loads(r.read().decode())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode())


def test_set_policy_swaps_limits_without_restart():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(
        svc, tick_interval_s=0.002,
        admission=AdmissionPolicy(
            tenants={"acme": TenantPolicy(rate=0.001, burst=2)}))
    try:
        for _ in range(2):
            svc.retrieve("acme/c0", "q")
        with pytest.raises(AdmissionError):   # bucket drained, 0.001/s refill
            svc.retrieve("acme/c0", "q")
        sched.set_admission_policy(AdmissionPolicy(
            tenants={"acme": TenantPolicy(rate=1000.0, burst=100)}))
        # a reload never refills spent tokens (that would make reloads an
        # abuse lever) — but at the new 1000/s rate the drained bucket is
        # usable again within milliseconds
        threading.Event().wait(0.02)
        svc.retrieve("acme/c0", "q")
        assert sched.admission.counters["policy_reloads"] == 1
    finally:
        sched.close()


def test_policy_reload_under_concurrent_traffic():
    """Swap policies while worker threads hammer the scheduler: no request
    may hang or fail with anything but a clean admission rejection, and
    the final (restrictive) policy must actually bite."""
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = svc.start_scheduler(tick_interval_s=0.002, max_batch=16)
    stop = threading.Event()
    outcomes, errors = [], []

    def worker(i):
        while not stop.is_set():
            try:
                svc.retrieve(f"t{i}/c0", "anything at all")
                outcomes.append("ok")
            except AdmissionError:
                outcomes.append("rejected")
            except Exception as e:            # anything else is a bug
                errors.append(repr(e))
                return

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    try:
        for t in threads:
            t.start()
        liberal = AdmissionPolicy(default=TenantPolicy(burst=64))
        strict = AdmissionPolicy(default=TenantPolicy(rate=50.0, burst=2))
        for i in range(10):                   # 10 live swaps under load
            sched.set_admission_policy(strict if i % 2 else liberal)
            threading.Event().wait(0.01)
        stop.set()
        for t in threads:
            t.join(timeout=30)
        assert not any(t.is_alive() for t in threads), "worker hung"
        assert errors == [], errors
        assert outcomes.count("ok") > 0
        assert sched.admission.counters["policy_reloads"] == 10
        # the last-installed strict policy is live for fresh tenants
        svc.retrieve("fresh/c0", "q")
        svc.retrieve("fresh/c0", "q")
        with pytest.raises(AdmissionError):   # burst=2 exhausted
            svc.retrieve("fresh/c0", "q")
    finally:
        stop.set()
        sched.close()


def test_admin_endpoint_reloads_policy_over_http():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(
        svc, tick_interval_s=0.002,
        admission=AdmissionPolicy(
            tenants={"acme": TenantPolicy(rate=0.001, burst=2)}))
    fe = MemoryFrontend(svc, KEYS,
                        admin_keys={"admin-key": "oncall"}).start()
    try:
        for _ in range(2):
            st, _ = _call(fe, "/v1/retrieve", {"namespace": "c", "query": "q"})
            assert st == 200
        st, env = _call(fe, "/v1/retrieve", {"namespace": "c", "query": "q"})
        assert st == 429
        st, env = _call(fe, "/v1/admin/policy",
                        {"tenants": {"acme": {"rate": 1000, "burst": 100}}},
                        key="admin-key")
        assert st == 200
        assert env["op"] == "policy_reload" and env["operator"] == "oncall"
        assert env["tenants"] == ["acme"]
        threading.Event().wait(0.02)          # drained bucket refills at
        st, _ = _call(fe, "/v1/retrieve",     # the new 1000/s rate
                      {"namespace": "c", "query": "q"})
        assert st == 200                      # un-throttled without restart
        assert fe.counters["policy_reloads"] == 1
        # a typo'd knob fails loudly instead of silently no-opping
        st, env = _call(fe, "/v1/admin/policy",
                        {"tenants": {"acme": {"rrate": 1}}}, key="admin-key")
        assert st == 400 and "unknown tenant policy keys" in env["error"]
    finally:
        fe.close()
        sched.close()


def test_admin_surface_auth_contract():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    body = {"tenants": {}}
    # no admin keyring mounted: the surface does not exist (404, so probing
    # cannot distinguish "wrong key" from "not enabled")
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        st, env = _call(fe, "/v1/admin/policy", body, key="whatever")
        assert st == 404 and "not enabled" in env["error"]
    finally:
        fe.close()
    fe = MemoryFrontend(svc, KEYS, admin_keys={"admin-key": "ops"}).start()
    try:
        st, _ = _call(fe, "/v1/admin/policy", body, key="wrong-key")
        assert st == 401
        # a TENANT key is not an admin key
        st, _ = _call(fe, "/v1/admin/policy", body, key="key-acme")
        assert st == 401
        # authenticated but no scheduler mounted: nothing to reload into
        st, env = _call(fe, "/v1/admin/policy", body, key="admin-key")
        assert st == 409 and "no scheduler" in env["error"]
    finally:
        fe.close()
    with pytest.raises(ValueError, match="disjoint"):
        MemoryFrontend(svc, KEYS, admin_keys={"key-acme": "ops"})
