"""Step builders: pjit'd train / prefill / decode steps with full sharding
specifications for any (arch, input shape, mesh).

The same builders serve the real launchers (train.py / serve.py) and the
AOT dry-run (dryrun.py): the dry-run lowers them against ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import partitioning as pt
from repro.common.module import abstract, shardings_of
from repro.models.config import InputShape, ModelConfig
from repro.models.model_api import Model
from repro.training import optimizer as opt

PyTree = Any

# FSDP threshold: above this many params, fp32 optimizer state at pure
# model-parallel sharding cannot fit 256 × 16 GiB; shard params over data too.
FSDP_PARAM_THRESHOLD = 5e9
# Above this, even fp32 moments are untenable — bf16 optimizer state.
BF16_OPT_THRESHOLD = 100e9


@dataclasses.dataclass
class StepBundle:
    """Everything dryrun/launchers need for one (arch, shape, mesh)."""
    fn: Any                       # the jit'd function
    args: tuple                   # ShapeDtypeStruct (or concrete) args
    rules: pt.MeshRules
    meta: Dict[str, Any]


def _batch_sharding(rules: pt.MeshRules, spec_dict: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in spec_dict.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = rules.sharding_for(axes, v.shape)
    return out


def opt_config_for(cfg: ModelConfig) -> opt.OptimizerConfig:
    n = cfg.param_count()
    return opt.OptimizerConfig(
        state_dtype="bfloat16" if n > BF16_OPT_THRESHOLD else "float32")


def use_fsdp(cfg: ModelConfig) -> bool:
    return cfg.param_count() > FSDP_PARAM_THRESHOLD


def build_train_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                     *, fsdp: Optional[bool] = None) -> StepBundle:
    model = Model(cfg)
    fsdp = use_fsdp(cfg) if fsdp is None else fsdp
    rules = pt.standard_rules(mesh, fsdp=fsdp)
    ocfg = opt_config_for(cfg)

    param_sh = model.param_shardings(rules)
    opt_sh = opt.OptState(
        step=NamedSharding(mesh, P()), mu=param_sh, nu=param_sh)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch, rules=rules)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params2, opt_state2, om = opt.update(ocfg, params, grads, opt_state)
        metrics.update(om)
        return params2, opt_state2, metrics

    aparams = abstract(model.param_specs(), cfg.pdtype)
    sdt = jnp.dtype(ocfg.state_dtype)
    aopt = opt.OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, sdt), aparams),
        nu=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, sdt), aparams))
    abatch = model.input_specs(shape)
    batch_sh = _batch_sharding(rules, abatch)

    fn = jax.jit(train_step,
                 in_shardings=(param_sh, opt_sh, batch_sh),
                 out_shardings=(param_sh, opt_sh, None),
                 donate_argnums=(0, 1))
    return StepBundle(fn=fn, args=(aparams, aopt, abatch), rules=rules,
                      meta={"kind": "train", "fsdp": fsdp,
                            "opt_dtype": ocfg.state_dtype})


def build_prefill_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> StepBundle:
    model = Model(cfg)
    rules = pt.standard_rules(mesh)
    param_sh = model.param_shardings(rules)

    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch, rules=rules)
        return logits, caches

    aparams = abstract(model.param_specs(), cfg.pdtype)
    abatch = model.input_specs(shape)
    batch_sh = _batch_sharding(rules, abatch)
    fn = jax.jit(prefill_step, in_shardings=(param_sh, batch_sh))
    return StepBundle(fn=fn, args=(aparams, abatch), rules=rules,
                      meta={"kind": "prefill"})


def build_decode_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                      *, kv_replicated: bool = False) -> StepBundle:
    """serve_step: ONE new token against a cache of shape.seq_len.

    kv_replicated (§Perf pair 3): disable the head_dim fallback so
    non-divisible kv heads replicate over `model` instead of being
    head_dim-sharded — avoids XLA all-gathering the whole cache per layer."""
    import dataclasses as _dc
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    long_ctx = shape.name == "long_500k"
    window_override = (cfg.long_context_window or None) if long_ctx else None
    # batch=1 long-context decode: context-parallel over the cache sequence
    rules = pt.long_context_rules(mesh) if (long_ctx and B < mesh.shape["data"]) \
        else pt.standard_rules(mesh)
    if kv_replicated:
        rules = _dc.replace(rules, head_dim_fallback=False)

    param_sh = model.param_shardings(rules)
    acaches = model.abstract_caches(B, S, window_override=window_override)
    cache_sh = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        model.cache_pspecs(B, S, rules, window_override=window_override))

    def decode_step(params, tokens, caches, pos):
        logits, new_caches = model.decode_step(
            params, tokens, caches, pos, rules=rules,
            window_override=window_override)
        return logits, new_caches

    atokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    apos = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = rules.sharding_for(("batch", None), (B, 1))
    pos_sh = rules.sharding_for(("batch",), (B,))
    fn = jax.jit(decode_step,
                 in_shardings=(param_sh, tok_sh, cache_sh, pos_sh),
                 out_shardings=(None, cache_sh),
                 donate_argnums=(2,))
    return StepBundle(fn=fn, args=(abstract(model.param_specs(), cfg.pdtype),
                                   atokens, acaches, apos),
                      rules=rules,
                      meta={"kind": "decode", "long_ctx": long_ctx,
                            "window_override": window_override})


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               variant: str = "") -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh)
    return build_decode_step(cfg, shape, mesh,
                             kv_replicated="kv_replicated" in variant)


def supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Skip policy (documented in DESIGN.md §9)."""
    if shape.name == "long_500k":
        if not cfg.supports_long_context:
            return False, ("full-attention enc-dec (whisper): no faithful "
                           "sliding-window variant; skipped per DESIGN.md §9")
    return True, ""
