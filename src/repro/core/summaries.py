"""Conversation summaries — the narrative layer of the dual memory asset."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Summary:
    conversation_id: str
    session_id: str
    timestamp: float
    text: str

    def render(self) -> str:
        ts = time.strftime("%Y-%m-%d", time.gmtime(self.timestamp)) if self.timestamp else "?"
        return f"[{ts}] (session {self.session_id}) {self.text}"


class SummaryStore:
    def __init__(self):
        self._by_session: Dict[str, Summary] = {}

    @staticmethod
    def skey(conversation_id: str, session_id: str) -> str:
        return f"{conversation_id}/{session_id}"

    def add(self, summary: Summary) -> str:
        key = self.skey(summary.conversation_id, summary.session_id)
        self._by_session[key] = summary
        return key

    def get(self, conversation_id: str, session_id: str) -> Optional[Summary]:
        return self._by_session.get(self.skey(conversation_id, session_id))

    def all(self) -> List[Summary]:
        return list(self._by_session.values())

    def __len__(self):
        return len(self._by_session)
