"""Fused top-k maximum-inner-product search over the Memori triple bank.

This is the TPU-native replacement for the paper's FAISS index (DESIGN.md
§3): the embedding bank is streamed HBM→VMEM in (block_n, D) tiles, scored
against the resident query tile on the MXU, and a running top-k (scores +
global indices) is maintained in the revisited output block across the
sequential bank-block grid dimension.

Exact search is deliberate: Advanced Augmentation compresses dialogue to
~10⁶-scale triples, small enough that exact MIPS beats pointer-chasing ANN
structures on TPU.

Grid: (num_q_blocks, num_bank_blocks)   — bank dim innermost/sequential.
Per-step top-k merge is an unrolled k-iteration argmax sweep (Pallas-TPU
friendly: no sort, no scatter).

Multi-tenant extension: when per-query and per-bank-row namespace ids are
supplied, cross-namespace hits are masked to NEG_INF *before* the top-k
merge, so one kernel launch serves a whole batch of tenants against one
packed bank (the MemoryService batched-retrieval path).  Rows with
namespace -1 are tombstones and match no query.  Without namespaces the
original kernel runs unchanged.

Stable-shape contract (the device-resident retrieval engine): the number of
valid bank rows rides along as a *traced* SMEM scalar, never a trace-time
constant.  Callers may hand in a capacity-padded bank (rows >= n_valid are
garbage) and grow `n_valid` append after append without triggering a single
recompile — the executable is keyed only on the padded shapes, which the
VectorIndex changes exclusively at power-of-two capacity boundaries.

Quantized extension (`scales=`): the bank may arrive as int8 with one f32
scale per row (symmetric per-row quantization: row_f32 ≈ scale * row_i8).
Dequantization is FUSED into the block loop — the kernel contracts the
int8 tile against the f32 query tile with f32 accumulation and multiplies
the score columns by the row scales afterwards, which is exactly
q · (scale * row_i8) without ever materializing an f32 bank tile.  The
bank read drops from 4 bytes/element to 1 (+4 bytes/row for the scale), so
the memory-bound scan moves ~4x less data and the same HBM holds ~4x more
resident rows.  Same grid, same masked/`n_valid`-traced contract, same
launch count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _merge_topk(scores_ref, idx_ref, s, col, k: int):
    """Merge block scores s (Qb, Nb) with the running (Qb, k) top-k refs."""
    all_s = jnp.concatenate([scores_ref[...], s], axis=1)
    all_i = jnp.concatenate([idx_ref[...], col], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, all_s.shape, 1)
    for j in range(k):
        m = jnp.max(all_s, axis=1)
        am = jnp.argmax(all_s, axis=1)
        hit = cols == am[:, None]
        sel_i = jnp.sum(jnp.where(hit, all_i, 0), axis=1)
        scores_ref[:, j] = m
        # once a query's candidates are exhausted, every remaining max is the
        # NEG_INF sentinel and argmax degenerates to column 0 — whose all_i
        # entry is a previously-selected index at grid steps nb > 0.  Emit -1
        # instead (matching the oracle); real dot products never reach the
        # sentinel, so live slots are unaffected.
        idx_ref[:, j] = jnp.where(m > NEG_INF / 2, sel_i, -1)
        all_s = jnp.where(hit, NEG_INF, all_s)


def _kernel(nvalid_ref, q_ref, bank_ref, scores_ref, idx_ref, *, block_n: int,
            k: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        scores_ref[...] = jnp.full_like(scores_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...]
    b = bank_ref[...]
    s = jax.lax.dot_general(q, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Qb, Nb)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + nb * block_n
    s = jnp.where(col < nvalid_ref[0], s, NEG_INF)  # mask padded bank rows
    _merge_topk(scores_ref, idx_ref, s, col, k)


def _kernel_masked(nvalid_ref, q_ref, bank_ref, qns_ref, bns_ref, scores_ref,
                   idx_ref, *, block_n: int, k: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        scores_ref[...] = jnp.full_like(scores_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...]
    b = bank_ref[...]
    s = jax.lax.dot_general(q, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Qb, Nb)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + nb * block_n
    # (Qb, 1) == (1, Nb) broadcast: a hit survives only within its namespace
    ok = (col < nvalid_ref[0]) & (qns_ref[...] == bns_ref[...])
    s = jnp.where(ok, s, NEG_INF)
    _merge_topk(scores_ref, idx_ref, s, col, k)


def _kernel_quant(nvalid_ref, q_ref, bank_ref, scale_ref, scores_ref,
                  idx_ref, *, block_n: int, k: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        scores_ref[...] = jnp.full_like(scores_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...]
    b = bank_ref[...]                                # (Nb, D) int8
    # fused dequant: q · (scale * b_i8) == scale * (q · b_i8) — contract the
    # int8 tile directly (f32 accumulate on the MXU), then scale the score
    # columns; the f32 bank tile is never materialized
    s = jax.lax.dot_general(q, b.astype(jnp.float32), (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Qb, Nb)
    s = s * scale_ref[...]                           # (1, Nb) broadcast
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + nb * block_n
    s = jnp.where(col < nvalid_ref[0], s, NEG_INF)
    _merge_topk(scores_ref, idx_ref, s, col, k)


def _kernel_quant_masked(nvalid_ref, q_ref, bank_ref, scale_ref, qns_ref,
                         bns_ref, scores_ref, idx_ref, *, block_n: int,
                         k: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        scores_ref[...] = jnp.full_like(scores_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...]
    b = bank_ref[...]                                # (Nb, D) int8
    s = jax.lax.dot_general(q, b.astype(jnp.float32), (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Qb, Nb)
    s = s * scale_ref[...]                           # fused dequant
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + nb * block_n
    ok = (col < nvalid_ref[0]) & (qns_ref[...] == bns_ref[...])
    s = jnp.where(ok, s, NEG_INF)
    _merge_topk(scores_ref, idx_ref, s, col, k)


def topk_mips(queries, bank, k: int = 32, *, n_valid=None, q_ns=None,
              bank_ns=None, scales=None, block_q: int = 128,
              block_n: int = 512, interpret: bool = False):
    """queries (Q, D) · bank (N, D) -> (scores (Q, k) f32, indices (Q, k) i32).

    `n_valid` (traced i32 scalar, default N) bounds the live bank prefix:
    rows >= n_valid never appear (NEG_INF score, index -1 if nothing live
    fills the slot).  Passing a capacity-padded bank plus a traced n_valid
    keeps the compiled executable stable while the bank grows.

    Optional namespace mask: q_ns (Q,) i32 and bank_ns (N,) i32 (both or
    neither).  Bank rows whose namespace differs from the query's score
    NEG_INF and keep index -1 if nothing in-namespace fills the slot; q_ns
    must be >= 0, bank_ns == -1 marks tombstoned rows.

    Quantized bank (`scales`): pass an int8 bank plus per-row f32 scales
    (N,) — scores are computed against `scale * row_i8` with dequant fused
    into the block loop (f32 accumulation, see module docstring).  All other
    contracts (n_valid, namespace mask, -1 sentinels) are unchanged."""
    Q, D = queries.shape
    N = bank.shape[0]
    if n_valid is None:
        n_valid = N
    if scales is not None and bank.dtype != jnp.int8:
        raise TypeError(f"scales given but bank dtype is {bank.dtype}, "
                        "expected int8")
    nv = jnp.asarray(n_valid, jnp.int32).reshape(1)
    bq = min(block_q, max(8, Q))
    bn = min(block_n, max(8, N))
    Qp = -(-Q // bq) * bq
    Np = -(-N // bn) * bn
    qp = jnp.pad(queries, ((0, Qp - Q), (0, 0)))
    bp = jnp.pad(bank, ((0, Np - N), (0, 0)))

    grid = (Qp // bq, Np // bn)
    nv_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    out_specs = [
        pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((Qp, k), jnp.float32),
        jax.ShapeDtypeStruct((Qp, k), jnp.int32),
    ]
    q_spec = pl.BlockSpec((bq, D), lambda i, j: (i, 0))
    bank_spec = pl.BlockSpec((bn, D), lambda i, j: (j, 0))
    # per-row scales ride as a (1, Np) row, tiled with the bank blocks
    scale_args, scale_specs = (), ()
    if scales is not None:
        sp = jnp.pad(jnp.asarray(scales, jnp.float32),
                     (0, Np - N)).reshape(1, Np)
        scale_args = (sp,)
        scale_specs = (pl.BlockSpec((1, bn), lambda i, j: (0, j)),)
    if q_ns is None and bank_ns is None:
        body = _kernel_quant if scales is not None else _kernel
        scores, idx = pl.pallas_call(
            functools.partial(body, block_n=bn, k=k),
            grid=grid,
            in_specs=[nv_spec, q_spec, bank_spec, *scale_specs],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(nv, qp, bp, *scale_args)
        return scores[:Q], idx[:Q]
    assert q_ns is not None and bank_ns is not None, \
        "q_ns and bank_ns must be given together"
    # namespace ids ride along as 2-D blocks: (Qp, 1) column / (1, Np) row
    qns = jnp.pad(jnp.asarray(q_ns, jnp.int32), (0, Qp - Q),
                  constant_values=-1).reshape(Qp, 1)
    bns = jnp.pad(jnp.asarray(bank_ns, jnp.int32), (0, Np - N),
                  constant_values=-2).reshape(1, Np)
    body = _kernel_quant_masked if scales is not None else _kernel_masked
    scores, idx = pl.pallas_call(
        functools.partial(body, block_n=bn, k=k),
        grid=grid,
        in_specs=[
            nv_spec, q_spec, bank_spec, *scale_specs,
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(nv, qp, bp, *scale_args, qns, bns)
    return scores[:Q], idx[:Q]
