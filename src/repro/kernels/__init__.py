# Pallas TPU kernels for the perf-critical hot-spots (retrieval MIPS +
# attention), each with a jit'd wrapper in ops.py and a pure-jnp oracle in
# ref.py.  Validated in interpret mode on CPU; BlockSpecs target v5e VMEM.
