"""Sharded exact-MIPS vector index — the FAISS replacement (DESIGN.md §3).

Single-device search runs the fused Pallas topk_mips kernel.  On a mesh, the
bank rows shard across every device (logical axis "bank"); search is the
classic distributed-ANN reduction expressed in shard_map:

    local top-k per shard  →  all_gather(k·shards candidates)  →  re-rank

Exact search is the right call *because of the paper*: Advanced Augmentation
compresses raw dialogue into triples, keeping the bank orders of magnitude
smaller than chunk-RAG banks — small enough that exact MIPS at full HBM
bandwidth beats approximate pointer-chasing structures on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import ops as kops
from repro.kernels import ref as kref


class VectorIndex:
    def __init__(self, dim: int, capacity: int = 1024, use_kernel: bool = True):
        self.dim = dim
        self.n = 0
        self.use_kernel = use_kernel
        self._bank = np.zeros((capacity, dim), np.float32)
        self._alive = np.ones((capacity,), bool)

    def add(self, vecs) -> np.ndarray:
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        m = vecs.shape[0]
        while self.n + m > self._bank.shape[0]:
            self._bank = np.concatenate(
                [self._bank, np.zeros_like(self._bank)], axis=0)
            self._alive = np.concatenate(
                [self._alive, np.ones_like(self._alive)])
        ids = np.arange(self.n, self.n + m)
        self._bank[self.n: self.n + m] = vecs
        self._alive[self.n: self.n + m] = True
        self.n += m
        return ids

    @property
    def bank(self) -> np.ndarray:
        return self._bank[: self.n]

    @property
    def n_alive(self) -> int:
        return int(self._alive[: self.n].sum())

    @property
    def n_dead(self) -> int:
        return self.n - self.n_alive

    def alive(self, ids=None):
        """Liveness of `ids` (or the full (n,) mask when ids is None)."""
        if ids is None:
            return self._alive[: self.n].copy()
        return self._alive[np.asarray(ids, np.int64)]

    def delete(self, ids) -> int:
        """Tombstone rows: ids keep their slots (the tid==row alignment with
        TripleStore/BM25 survives) but the vectors are physically zeroed and
        the rows never surface from search again.  Returns #newly deleted."""
        ids = np.asarray(ids, np.int64).ravel()
        ids = ids[(ids >= 0) & (ids < self.n)]
        ids = ids[self._alive[ids]]
        self._alive[ids] = False
        self._bank[ids] = 0.0
        return int(ids.size)

    def compact(self) -> np.ndarray:
        """Physically drop tombstoned rows, repacking the bank (and shrinking
        its capacity to the next power of two).  Returns the old→new row id
        mapping as an (n_old,) int64 array (-1 for dropped rows); kept rows
        keep their relative order.  Callers owning row-aligned side tables
        (see core/store.py) must remap them with the returned array."""
        n_old = self.n
        alive = self._alive[:n_old]
        old_to_new = np.full((n_old,), -1, np.int64)
        keep = np.where(alive)[0]
        old_to_new[keep] = np.arange(keep.size)
        n_new = int(keep.size)
        cap = max(64, 1 << max(0, int(n_new - 1).bit_length()))
        bank = np.zeros((cap, self.dim), np.float32)
        bank[:n_new] = self._bank[keep]
        self._bank = bank
        self._alive = np.ones((cap,), bool)
        self.n = n_new
        return old_to_new

    def load_rows(self, bank, alive) -> None:
        """Bulk-load a snapshot's rows (replaces any current content)."""
        bank = np.asarray(bank, np.float32)
        n = bank.shape[0]
        if bank.ndim != 2 or bank.shape[1] != self.dim:
            raise ValueError(f"bank shape {bank.shape} != (*, {self.dim})")
        cap = max(64, 1 << max(0, int(n - 1).bit_length()))
        self._bank = np.zeros((cap, self.dim), np.float32)
        self._bank[:n] = bank
        self._alive = np.ones((cap,), bool)
        self._alive[:n] = np.asarray(alive, bool)
        self.n = n

    def search(self, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """queries (Q, D) -> (scores (Q, k), ids (Q, k)); ids == -1 beyond n.
        Tombstoned rows never appear: with any dead rows the search routes
        through the masked kernel (uniform namespace, dead rows -> -1),
        which keeps k static across delete()s — no per-delete retrace and
        no over-fetch."""
        queries = jnp.asarray(queries, jnp.float32)
        if queries.ndim == 1:
            queries = queries[None]
        Q = queries.shape[0]
        if self.n == 0 or self.n_alive == 0:
            return (np.full((Q, k), -np.inf, np.float32),
                    np.full((Q, k), -1, np.int64))
        if self.n_dead:
            return self.search_masked(queries, np.zeros((Q,), np.int32),
                                      np.zeros((self.n,), np.int32), k)
        bank = jnp.asarray(self.bank)
        kk = min(k, self.n)
        if self.use_kernel:
            s, i = kops.topk_mips(queries, bank, k=kk)
        else:
            s, i = kref.topk_mips_ref(queries, bank, k=kk)
        s = np.asarray(s)
        i = np.asarray(i, np.int64)
        if kk < k:
            s = np.pad(s, ((0, 0), (0, k - kk)), constant_values=-np.inf)
            i = np.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
        return s, i

    def search_masked(self, queries, q_ns, row_ns, k: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched multi-tenant search: one kernel launch over the packed
        bank.  q_ns (Q,) >= 0 is each query's namespace, row_ns (n,) labels
        every bank row; tombstoned rows are masked regardless of their label.
        Rows outside the query's namespace never appear (ids -1 / -inf)."""
        queries = jnp.asarray(queries, jnp.float32)
        if queries.ndim == 1:
            queries = queries[None]
        Q = queries.shape[0]
        if self.n == 0 or self.n_alive == 0:
            return (np.full((Q, k), -np.inf, np.float32),
                    np.full((Q, k), -1, np.int64))
        row_ns = np.asarray(row_ns, np.int32)
        assert row_ns.shape == (self.n,), (row_ns.shape, self.n)
        eff_ns = jnp.asarray(np.where(self._alive[: self.n], row_ns, -1))
        q_ns = jnp.asarray(q_ns, jnp.int32)
        kk = min(k, self.n)
        if self.use_kernel:
            s, i = kops.topk_mips_masked(queries, jnp.asarray(self.bank),
                                         q_ns, eff_ns, k=kk)
        else:
            s, i = kref.topk_mips_masked_ref(queries, jnp.asarray(self.bank),
                                             q_ns, eff_ns, k=kk)
        s = np.asarray(s)
        i = np.asarray(i, np.int64)
        if kk < k:
            s = np.pad(s, ((0, 0), (0, k - kk)), constant_values=-np.inf)
            i = np.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
        return s, i


# ---------------------------------------------------------------------------
# Distributed search (shard_map): used by launch/dryrun and on real meshes.
# ---------------------------------------------------------------------------

# jax moved shard_map out of experimental (and renamed check_rep->check_vma);
# support both so the CPU-mesh parity tests run on older pinned jax too
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:                                    # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_unchecked(fn, mesh, in_specs, out_specs):
    import inspect
    flag = "check_vma" if "check_vma" in \
        inspect.signature(_shard_map).parameters else "check_rep"
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{flag: False})


def sharded_topk(queries, bank, k: int, mesh: Mesh, axis_names=("data", "model")):
    """bank rows sharded over `axis_names` (flattened); returns global
    (scores (Q,k), ids (Q,k)).  Local top-k → all_gather → re-rank."""
    flat_axes = tuple(a for a in axis_names if a in mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in flat_axes]))
    N = bank.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    shard_rows = N // n_shards

    def local(q, b):
        # positional index of this shard along the flattened bank axes
        idx = jax.lax.axis_index(flat_axes)
        s, i = kref.topk_mips_ref(q, b, k=min(k, shard_rows))
        i = i + idx * shard_rows
        # gather candidates from every shard, then re-rank globally
        s_all = jax.lax.all_gather(s, flat_axes, axis=1, tiled=True)
        i_all = jax.lax.all_gather(i, flat_axes, axis=1, tiled=True)
        top_s, pos = jax.lax.top_k(s_all, k)
        top_i = jnp.take_along_axis(i_all, pos, axis=1)
        return top_s, top_i

    spec_bank = P(flat_axes)
    # outputs are replicated by construction (all_gather + local re-rank);
    # the replication checker can't prove it, so we assert it ourselves
    fn = _shard_map_unchecked(local, mesh=mesh,
                              in_specs=(P(), spec_bank),
                              out_specs=(P(), P()))
    return fn(queries, bank)
