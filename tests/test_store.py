"""MemoryStore storage engine: async batched ingestion (flush == one embed
call), bank compaction (row-id remapping, retrieval unchanged), and
snapshot/restore persistence (bit-identical retrieval), plus the BM25
batched-scoring and capacity-growth paths underneath."""
import numpy as np
import pytest

from repro.core import (MemoryService, MemoryStore, Message,
                        StoreInvariantError)
from repro.core.bm25 import BM25Index
from repro.core.embedder import HashEmbedder
from repro.core.vector_index import VectorIndex


class CountingEmbedder(HashEmbedder):
    """HashEmbedder that counts embed_texts calls (the flush invariant)."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.calls = 0

    def embed_texts(self, texts):
        self.calls += 1
        return super().embed_texts(texts)


def _svc(emb=None, **kw):
    kw.setdefault("use_kernel", False)
    return MemoryService(emb or HashEmbedder(), **kw)


def _session(texts, speaker="Caroline", ts=1700000000.0):
    return [Message(speaker, t, ts) for t in texts]


def _fill(svc):
    svc.record("alice/c0", "s0", _session(
        ["I work as a botanist and I live in Tallinn.",
         "I adopted a hedgehog named Biscuit."], speaker="Alice"))
    svc.record("bob/c0", "s0", _session(
        ["I work as a welder and I live in Porto.",
         "I adopted a parrot named Olive."], speaker="Bob"))
    svc.record("carol/c0", "s0", _session(
        ["I work as a pilot and I live in Cusco."], speaker="Carol"))
    return svc


QUERIES = [("alice/c0", "Which city does the user live in?"),
           ("bob/c0", "Which city does the user live in?"),
           ("carol/c0", "What is the user's job?"),
           ("alice/c0", "What pet was adopted?"),
           ("mallory/c0", "anything at all?")]


def _contexts_equal(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert [t.text() for t in g.triples] == [t.text() for t in w.triples]
        assert [s.render() for s in g.summaries] == \
            [s.render() for s in w.summaries]
        assert g.text == w.text
        assert g.token_count == w.token_count


# -- async batched ingestion ---------------------------------------------------

def test_flush_of_pending_sessions_is_one_embed_call():
    emb = CountingEmbedder()
    svc = _svc(emb)
    for u in range(5):
        svc.enqueue(f"u{u}/c0", "s0", _session(
            [f"I live in Tallinn.", "I adopted a gecko named Pixel."],
            speaker=f"U{u}"))
    assert emb.calls == 0, "enqueue must not embed"
    assert svc.stats()["pending"] == 5
    assert svc.flush() == 5
    assert emb.calls == 1, "flush must batch all pending into ONE embed call"
    assert svc.stats()["pending"] == 0
    ctx = svc.retrieve("u3/c0", "Which city does the user live in?")
    assert any(t.object == "tallinn" for t in ctx.triples)


def test_flush_empty_is_noop():
    emb = CountingEmbedder()
    svc = _svc(emb)
    assert svc.flush() == 0
    assert emb.calls == 0


def test_enqueue_then_retrieve_is_read_your_writes():
    svc = _svc()
    svc.enqueue("u0/c0", "s0", _session(["I live in Lisbon."]))
    ctx = svc.retrieve("u0/c0", "Which city does the user live in?")
    assert any(t.object == "lisbon" for t in ctx.triples)
    assert svc.stats()["pending"] == 0


def test_record_equals_enqueue_flush():
    a, b = _svc(), _svc()
    msgs = _session(["I work as a chef.", "I adopted a ferret named Maple."])
    ta, _ = a.record("u/c0", "s0", msgs)
    b.enqueue("u/c0", "s0", msgs)
    b.flush()
    q = [("u/c0", "What is the user's job?")]
    _contexts_equal(a.retrieve_batch(q), b.retrieve_batch(q))
    assert [t.text() for t in ta]


def test_flush_every_auto_flushes():
    emb = CountingEmbedder()
    svc = _svc(emb, flush_every=3)
    for s in range(3):
        svc.enqueue("u/c0", f"s{s}", _session([f"I bought a lamp."], ts=s))
    assert emb.calls == 1 and svc.stats()["pending"] == 0


def test_flush_failure_restores_queue_and_commits_nothing():
    emb = CountingEmbedder()
    svc = _svc(emb)

    class PoisonError(RuntimeError):
        pass

    orig = svc.extractor.extract

    def poisoned(conv, sess, msgs):
        if sess == "poison":
            raise PoisonError(sess)
        return orig(conv, sess, msgs)

    svc.extractor.extract = poisoned
    svc.enqueue("a/c0", "s0", _session(["I live in Tallinn."]))
    svc.enqueue("b/c0", "poison", _session(["I live in Porto."]))
    svc.enqueue("a/c0", "s1", _session(["I adopted a gecko named Pixel."]))
    with pytest.raises(PoisonError):
        svc.flush()
    # nothing committed: no orphaned summaries, no bank rows, queue intact
    st = svc.stats()
    assert st["pending"] == 3 and st["bank_rows"] == 0
    assert st["namespaces"] == 0
    # dropping the poison namespace unblocks the batch
    svc.evict("b/c0")
    assert svc.flush() == 2
    ctx = svc.retrieve("a/c0", "Which city does the user live in?")
    assert any(t.object == "tallinn" for t in ctx.triples)


def test_namespace_view_uses_async_path_when_flush_every_set():
    emb = CountingEmbedder()
    svc = _svc(emb, flush_every=2)
    view = svc.namespace("u/c0")
    view.record_session("u/c0", "s0", _session(["I live in Tallinn."]))
    assert emb.calls == 0 and svc.stats()["pending"] == 1
    view.record_session("u/c0", "s1", _session(["I work as a chef."]))
    assert emb.calls == 1 and svc.stats()["pending"] == 0
    # reads see buffered sessions regardless (read-your-writes)
    view.record_session("u/c0", "s2", _session(["I adopted a magpie."]))
    ctx = view.retrieve("What pet was adopted?")
    assert any(t.object == "magpie" for t in ctx.triples)


def test_evict_drops_pending_sessions_of_that_namespace():
    svc = _fill(_svc())
    svc.enqueue("bob/c0", "s9", _session(["I live in Sapporo."]))
    svc.evict("bob/c0")
    assert svc.retrieve("bob/c0", "Which city?").triples == []


def test_flush_interleaves_tenants_consistently():
    """Sessions from several tenants flushed in one batch keep namespace
    isolation and match a per-session synchronous service."""
    sync, batched = _svc(), _svc()
    sessions = [("alice/c0", "s0", _session(["I live in Tallinn."], speaker="Alice")),
                ("bob/c0", "s0", _session(["I live in Porto."], speaker="Bob")),
                ("alice/c0", "s1", _session(["I adopted a hedgehog named Biscuit."],
                                            speaker="Alice")),
                ("bob/c0", "s1", _session(["I work as a welder."], speaker="Bob"))]
    for ns, sid, msgs in sessions:
        sync.record(ns, sid, msgs)
        batched.enqueue(ns, sid, msgs)
    batched.flush()
    q = [("alice/c0", "Which city does the user live in?"),
         ("bob/c0", "Which city does the user live in?"),
         ("alice/c0", "What pet was adopted?"),
         ("bob/c0", "What is the user's job?")]
    _contexts_equal(batched.retrieve_batch(q), sync.retrieve_batch(q))


# -- compaction ----------------------------------------------------------------

def _evict_some(svc):
    svc.record("alice/c0", "s1", _session(["I work as a luthier."],
                                          speaker="Alice", ts=1700000100.0))
    assert svc.evict_superseded("alice/c0") == 1
    assert svc.evict("carol/c0") > 0
    return svc


def test_compact_shrinks_bank_to_alive_rows_and_preserves_retrieval():
    svc = _evict_some(_fill(_svc()))
    before = svc.retrieve_batch(QUERIES)
    st0 = svc.stats()
    assert st0["tombstones"] > 0
    info = svc.compact()
    assert info["dropped"] == st0["tombstones"]
    st1 = svc.stats()
    assert st1["bank_rows"] == st1["alive_rows"] == st0["alive_rows"]
    assert st1["tombstones"] == 0
    assert len(svc.bm25) == st1["bank_rows"]
    _contexts_equal(svc.retrieve_batch(QUERIES), before)


def test_compact_is_idempotent_and_ingest_after_compact_works():
    svc = _evict_some(_fill(_svc()))
    svc.compact()
    assert svc.compact()["dropped"] == 0
    svc.record("dave/c0", "s0", _session(["I live in Windhoek."],
                                         speaker="Dave"))
    ctx = svc.retrieve("dave/c0", "Which city does the user live in?")
    assert any(t.object == "windhoek" for t in ctx.triples)
    # pre-compaction tenants still answer correctly through remapped rows
    ctx = svc.retrieve("alice/c0", "What is the user's job?")
    objs = [t.object for t in ctx.triples]
    assert "luthier" in objs and "botanist" not in objs


def test_compact_flushes_pending_first():
    svc = _fill(_svc())
    svc.enqueue("erin/c0", "s0", _session(["I live in Oslo."], speaker="Erin"))
    svc.compact()
    assert svc.stats()["pending"] == 0
    ctx = svc.retrieve("erin/c0", "Which city does the user live in?")
    assert any(t.object == "oslo" for t in ctx.triples)


def test_compact_empty_store_safe():
    svc = _svc()
    assert svc.compact() == {"rows_before": 0, "rows_after": 0, "dropped": 0}


def test_vector_index_compact_mapping():
    rng = np.random.default_rng(0)
    vi = VectorIndex(dim=8, use_kernel=False)
    vecs = rng.standard_normal((10, 8)).astype(np.float32)
    vi.add(vecs)
    vi.delete([1, 4, 5])
    m = vi.compact()
    keep = [0, 2, 3, 6, 7, 8, 9]
    assert m.shape == (10,)
    assert [int(x) for x in m[keep]] == list(range(7))
    assert all(int(m[i]) == -1 for i in (1, 4, 5))
    assert vi.n == vi.n_alive == 7
    np.testing.assert_array_equal(vi.bank, vecs[keep])


# -- snapshot / restore --------------------------------------------------------

def test_snapshot_restore_retrieval_bit_identical(tmp_path):
    svc = _evict_some(_fill(_svc()))
    want = svc.retrieve_batch(QUERIES)
    path = str(tmp_path / "store.msgpack")
    assert svc.snapshot(path) > 0
    restored = MemoryService.restore(path, HashEmbedder(), use_kernel=False)
    _contexts_equal(restored.retrieve_batch(QUERIES), want)
    # the restored packed bank is byte-identical, tombstones included
    np.testing.assert_array_equal(restored.vindex.bank, svc.vindex.bank)
    np.testing.assert_array_equal(restored.vindex.alive(), svc.vindex.alive())
    assert restored.stats() == svc.stats()


def test_snapshot_flushes_pending_writes(tmp_path):
    svc = _svc()
    svc.enqueue("u0/c0", "s0", _session(["I live in Lisbon."]))
    path = str(tmp_path / "store.msgpack")
    svc.snapshot(path)
    restored = MemoryService.restore(path, HashEmbedder(), use_kernel=False)
    ctx = restored.retrieve("u0/c0", "Which city does the user live in?")
    assert any(t.object == "lisbon" for t in ctx.triples)


def test_snapshot_restore_then_compact_then_more_writes(tmp_path):
    svc = _evict_some(_fill(_svc()))
    path = str(tmp_path / "store.msgpack")
    svc.snapshot(path)
    restored = MemoryService.restore(path, HashEmbedder(), use_kernel=False)
    before = restored.retrieve_batch(QUERIES)
    restored.compact()
    _contexts_equal(restored.retrieve_batch(QUERIES), before)
    restored.record("bob/c0", "s9", _session(["I moved to Sapporo."],
                                             speaker="Bob",
                                             ts=1700000200.0))
    ctx = restored.retrieve("bob/c0", "Which city does the user live in?")
    assert any(t.object == "sapporo" for t in ctx.triples)


def test_restore_rejects_wrong_version(tmp_path):
    import msgpack
    from repro.checkpoint import io as ckpt_io
    svc = _fill(_svc())
    path = str(tmp_path / "store.msgpack")
    svc.snapshot(path)
    arrays = ckpt_io.load_raw(path)
    meta = msgpack.unpackb(arrays["meta"].tobytes(), raw=False)
    meta["version"] = 999
    arrays["meta"] = np.frombuffer(
        msgpack.packb(meta, use_bin_type=True), np.uint8)
    ckpt_io.save(path, arrays)
    with pytest.raises(StoreInvariantError, match="version"):
        MemoryService.restore(path, HashEmbedder(), use_kernel=False)


# -- invariants are real exceptions --------------------------------------------

def test_write_path_alignment_raises_store_invariant_error():
    store = MemoryStore(HashEmbedder(), use_kernel=False)
    orig = store.bm25.add
    store.bm25.add = lambda texts, namespace=None: \
        [i + 1 for i in orig(texts, namespace=namespace)]
    with pytest.raises(StoreInvariantError, match="alignment"):
        store.ingest("u/c0", "s0", _session(["I live in Porto."]))


def test_compaction_drift_raises_store_invariant_error():
    store = MemoryStore(HashEmbedder(), use_kernel=False)
    store.ingest("u/c0", "s0", _session(["I live in Porto.",
                                         "I work as a chef."]))
    store.vindex.delete([0])          # tombstone the bank only, not BM25
    with pytest.raises(StoreInvariantError, match="drift"):
        store.compact()


def test_namespace_stats_is_public_api():
    svc = _fill(_svc())
    st = svc.namespace_stats("alice/c0")
    assert st["triples"] > 0 and st["summaries"] == 1
    assert svc.namespace("alice/c0").stats() == st
    assert svc.namespace_stats("nobody/c0") == \
        {"triples": 0, "summaries": 0, "evicted": 0}


# -- BM25 storage + batched scoring --------------------------------------------

def test_bm25_topk_batch_matches_sequential_topk():
    idx = BM25Index()
    idx.add(["alpha beta gamma", "beta beta delta", "gamma epsilon"],
            namespace=0)
    idx.add(["alpha alpha alpha", "zeta eta", "beta gamma zeta"],
            namespace=1)
    idx.remove([1])
    queries = ["alpha beta", "gamma", "zeta eta", "nothing matches here"]
    namespaces = [0, 1, None, 0]
    s_b, i_b = idx.topk_batch(queries, k=4, namespaces=namespaces)
    for b, (q, ns) in enumerate(zip(queries, namespaces)):
        s_s, i_s = idx.topk(q, k=4, namespace=ns)
        m = i_b[b] >= 0
        np.testing.assert_array_equal(i_b[b][m], i_s)
        np.testing.assert_allclose(s_b[b][m], s_s, rtol=1e-6)


def test_bm25_per_doc_namespace_tags():
    idx = BM25Index()
    ids = idx.add(["alpha beta", "gamma delta", "alpha gamma"],
                  namespace=[0, 1, 0])
    _, i0 = idx.topk("alpha gamma", k=3, namespace=0)
    assert set(i0.tolist()) == {ids[0], ids[2]}
    with pytest.raises(ValueError, match="tags"):
        idx.add(["x"], namespace=[0, 1])


def test_bm25_growth_preserves_scores_across_capacity_doublings():
    grown = BM25Index(capacity=2)
    fresh = BM25Index()
    docs = [f"term{i} alpha shared" for i in range(40)]
    for d in docs:                    # one-by-one: forces several doublings
        grown.add([d])
        grown.topk("alpha", k=3)      # interleaved queries (post-add reads)
    fresh.add(docs)
    for q in ["alpha shared", "term7", "term39 alpha"]:
        np.testing.assert_allclose(np.asarray(grown.scores(q)),
                                   np.asarray(fresh.scores(q)), rtol=1e-6)


def test_bm25_compact_mapping_and_scoped_scores():
    idx = BM25Index()
    idx.add(["alpha beta", "gamma", "alpha gamma", "delta"],
            namespace=[0, 0, 1, 1])
    idx.remove([1, 3])
    want_s, want_i = idx.topk("alpha", k=4, namespace=0)
    m = idx.compact()
    assert [int(x) for x in m] == [0, -1, 1, -1]
    assert len(idx) == idx.alive_count == 2
    got_s, got_i = idx.topk("alpha", k=4, namespace=0)
    np.testing.assert_array_equal(got_i, [int(m[i]) for i in want_i])
    np.testing.assert_allclose(got_s, want_s, rtol=1e-6)
