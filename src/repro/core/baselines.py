"""Comparison memory systems the paper benchmarks against (§3.6), rebuilt
in-framework so Table 1/2 analogues are self-contained:

* FullContextMemory — the ceiling: injects every stored message verbatim.
* RagChunkMemory    — "traditional RAG": raw transcripts chunked (~chunk_tokens
  per chunk), embedded, top-k chunks retrieved without any structuring —
  the architecture whose noise/token-bloat the paper attributes to Mem0/Zep-
  style raw storage.

Both expose the same retrieve(query) -> RetrievedContext surface as
MemoriMemory so the benchmark treats them interchangeably.
"""
from __future__ import annotations

import time
from typing import List, Sequence

from repro.core.bm25 import BM25Index
from repro.core.extraction import Message
from repro.core.hybrid import hybrid_search
from repro.core.memory import RetrievedContext
from repro.core.vector_index import VectorIndex
from repro.data.tokenizer import default_tokenizer


def _fmt(msg: Message) -> str:
    ts = time.strftime("%Y-%m-%d", time.gmtime(msg.timestamp)) if msg.timestamp else "?"
    return f"[{ts}] {msg.speaker}: {msg.text}"


class FullContextMemory:
    def __init__(self, tokenizer=None):
        self.tokenizer = tokenizer or default_tokenizer()
        self._messages: List[Message] = []

    def record_session(self, conversation_id: str, session_id: str,
                       messages: Sequence[Message]):
        self._messages.extend(messages)

    def retrieve(self, query: str) -> RetrievedContext:
        text = "\n".join(_fmt(m) for m in self._messages)
        return RetrievedContext([], [], text, self.tokenizer.count(text))


class RagChunkMemory:
    def __init__(self, embedder, chunk_tokens: int = 120, top_k: int = 8,
                 dim: int = 256, tokenizer=None, use_kernel: bool = True):
        self.embedder = embedder
        self.chunk_tokens = chunk_tokens
        self.top_k = top_k
        self.tokenizer = tokenizer or default_tokenizer()
        self.vindex = VectorIndex(dim=dim, use_kernel=use_kernel)
        self.bm25 = BM25Index(max_doc_len=chunk_tokens + 16)
        self._chunks: List[str] = []

    def record_session(self, conversation_id: str, session_id: str,
                       messages: Sequence[Message]):
        cur: List[str] = []
        count = 0
        chunks: List[str] = []
        for m in messages:
            line = _fmt(m)
            n = self.tokenizer.count(line)
            if cur and count + n > self.chunk_tokens:
                chunks.append("\n".join(cur))
                cur, count = [], 0
            cur.append(line)
            count += n
        if cur:
            chunks.append("\n".join(cur))
        if chunks:
            vecs = self.embedder.embed_texts(chunks)
            self.vindex.add(vecs)
            self.bm25.add(chunks)
            self._chunks.extend(chunks)

    def retrieve(self, query: str) -> RetrievedContext:
        qv = self.embedder.embed_texts([query])
        fused = hybrid_search(query, qv, self.vindex, self.bm25,
                              top_k=self.top_k)
        text = "\n---\n".join(self._chunks[cid] for cid, _ in fused)
        return RetrievedContext([], [], text, self.tokenizer.count(text))
