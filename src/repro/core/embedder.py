"""Embedding backends for triple/summary/query text.

* HashEmbedder — deterministic random-projection bag-of-words embedding
  (per-word Gaussian vectors keyed by the word's stable hash, idf-free mean,
  L2-normalised).  Zero-training, reproducible across processes: used by the
  benchmark so Table-1/2 analogues are exactly repeatable.
* LMEmbedder — the in-framework replacement for the paper's Gemma-300: a
  small bidirectional transformer (configs/memori_embedder.py), mean-pooled
  and L2-normalised.  Same interface; used in the end-to-end examples.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import stable_hash
from repro.data.tokenizer import HashTokenizer, default_tokenizer


# Small synonym lexicon: canonicalising through it is what gives the dense
# path *semantics* that the lexical BM25 path lacks (a stand-in for what a
# learned embedding model provides) — paraphrased queries match via dense
# retrieval while exact rare terms (names, objects) match via BM25, which is
# exactly the complementarity the paper's hybrid search exploits.
SYNONYMS = {
    "job": ["work", "works", "working", "profession", "living", "occupation",
            "career", "trade", "employed"],
    "food": ["dish", "meal", "cuisine", "eat", "eats", "eating"],
    "like": ["likes", "love", "loves", "adore", "adores", "enjoy", "enjoys",
             "favorite", "favourite", "prefer", "prefers", "into"],
    "city": ["town", "live", "lives", "living", "based", "reside", "resides",
             "moved"],
    "buy": ["bought", "buys", "purchase", "purchased", "acquired", "got"],
    "travel": ["travelled", "traveled", "went", "trip", "visit", "visited",
               "journey", "vacation"],
    "learn": ["learning", "learns", "study", "studying", "studies",
              "practicing", "picking"],
    "pet": ["animal", "adopt", "adopted", "companion"],
    "name": ["named", "called", "call"],
    "color": ["colour", "shade"],
    "hobby": ["hobbies", "pastime", "interests", "interest"],
    "when": ["month", "year", "date", "time"],
}
_CANON = {w: k for k, ws in SYNONYMS.items() for w in ws}


def canonicalize(word: str) -> str:
    w = word.lower()
    return _CANON.get(w, w)


class HashEmbedder:
    def __init__(self, dim: int = 256, seed: int = 0,
                 tokenizer: HashTokenizer | None = None):
        self.dim = dim
        self.seed = seed
        self.tokenizer = tokenizer or default_tokenizer()
        self._cache: dict[str, np.ndarray] = {}

    def _word_vec(self, word: str) -> np.ndarray:
        w = canonicalize(word)
        v = self._cache.get(w)
        if v is None:
            rng = np.random.default_rng(stable_hash(w, 2**31) + self.seed)
            v = rng.standard_normal(self.dim).astype(np.float32)
            self._cache[w] = v
        return v

    def embed_texts(self, texts: Sequence[str]) -> jnp.ndarray:
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            words = self.tokenizer.words(t)
            if not words:
                continue
            v = np.mean([self._word_vec(w) for w in words], axis=0)
            n = np.linalg.norm(v)
            out[i] = v / n if n > 0 else v
        return jnp.asarray(out)

    def embed_text(self, text: str) -> jnp.ndarray:
        return self.embed_texts([text])[0]


class LMEmbedder:
    """Mean-pooled bidirectional transformer encoder."""

    def __init__(self, model, params, out_dim: int = 256,
                 tokenizer: HashTokenizer | None = None, max_len: int = 64):
        from repro.models import transformer as _tf  # local import: avoid cycle
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.out_dim = out_dim
        self.max_len = max_len
        self.tokenizer = tokenizer or HashTokenizer(self.cfg.vocab_size)
        self._tf = _tf

        def _fwd(params, tokens, mask):
            from repro.models.layers import embedding as emb
            x = emb.embed(params["embed"], self.cfg, tokens)
            B, S = tokens.shape
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            h, _, _ = self._tf.decoder_apply(
                params, self.cfg, x, mode="train", positions=pos,
                mask_kind="bidir", remat=False)
            m = mask[..., None].astype(h.dtype)
            pooled = (h * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
            pooled = pooled[:, : self.out_dim]
            return pooled / jnp.maximum(
                jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-6)

        self._fwd = jax.jit(_fwd)

    def embed_texts(self, texts: Sequence[str]) -> jnp.ndarray:
        L = self.max_len
        toks = np.zeros((len(texts), L), np.int32)
        mask = np.zeros((len(texts), L), np.float32)
        for i, t in enumerate(texts):
            ids = self.tokenizer.encode(t)[:L]
            toks[i, : len(ids)] = ids
            mask[i, : len(ids)] = 1.0
        return self._fwd(self.params, jnp.asarray(toks), jnp.asarray(mask))

    def embed_text(self, text: str) -> jnp.ndarray:
        return self.embed_texts([text])[0]
