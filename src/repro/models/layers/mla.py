"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Train/prefill use the decompressed form (standard MHA over reconstructed
K/V, chunked online-softmax attention so 32k prefill never materialises
(S,T) scores).  Decode uses the *absorbed* form: scores are computed directly
against the compressed latent cache

    score[h,t] = (W_UK[h]^T q_nope[h]) . c_kv[t]  +  q_rope[h] . k_rope[t]

so the per-token cache is only (kv_lora_rank + qk_rope_head_dim) floats —
the whole point of MLA — and the 500k/32k decode caches stay tiny.  The
latent cache is shared across all heads (it cannot shard over `heads`; it
shards over batch, or over `seq` for long_500k context parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.module import ParamSpec
from repro.models.layers import rope as rope_lib
from repro.models.layers.attention import attend
from repro.models.layers.norms import rms_norm

NEG_INF = -2.0e38


def specs(cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": ParamSpec((d, m.q_lora_rank), ("embed", None), init="scaled_normal", scale=1.0),
        "q_norm": ParamSpec((m.q_lora_rank,), (None,), init="ones"),
        "wuq": ParamSpec((m.q_lora_rank, h, qk_hd), (None, "heads", "head_dim"),
                         init="scaled_normal", scale=1.0),
        "wdkv": ParamSpec((d, m.kv_lora_rank), ("embed", None), init="scaled_normal", scale=1.0),
        "kv_norm": ParamSpec((m.kv_lora_rank,), (None,), init="ones"),
        "wkr": ParamSpec((d, m.qk_rope_head_dim), ("embed", "head_dim"),
                         init="scaled_normal", scale=1.0),
        "wuk": ParamSpec((m.kv_lora_rank, h, m.qk_nope_head_dim), (None, "heads", "head_dim"),
                         init="scaled_normal", scale=1.0),
        "wuv": ParamSpec((m.kv_lora_rank, h, m.v_head_dim), (None, "heads", "head_dim"),
                         init="scaled_normal", scale=1.0),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", "head_dim", "embed"),
                        init="scaled_normal", scale=1.0),
    }


def _q_proj(params, cfg, x, positions):
    m = cfg.mla
    dt = x.dtype
    cq = jnp.einsum("bsd,dr->bsr", x, params["wdq"].astype(dt))
    cq = rms_norm(cq, params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"].astype(dt))
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = rope_lib.apply_rope(q[..., m.qk_nope_head_dim:], positions,
                                 theta=cfg.rope_theta, pct=1.0)
    return q_nope, q_rope


def _latent_proj(params, cfg, x, positions):
    dt = x.dtype
    ckv = jnp.einsum("bsd,dr->bsr", x, params["wdkv"].astype(dt))
    ckv = rms_norm(ckv, params["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, params["wkr"].astype(dt))
    k_rope = rope_lib.apply_rope(k_rope, positions, theta=cfg.rope_theta, pct=1.0)
    return ckv, k_rope


def apply(params, cfg, x, *, positions, mode: str = "train", cache=None,
          cache_pos=None, window: int = 0, return_cache: bool = False,
          mask_kind: str = "causal", prefix_len=None):
    m = cfg.mla
    dt = x.dtype
    B = x.shape[0]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    new_cache = None

    if mode in ("train", "prefill"):
        q_nope, q_rope = _q_proj(params, cfg, x, positions)
        ckv, k_rope = _latent_proj(params, cfg, x, positions)
        if cfg.mla_absorbed_train:
            # §Perf variant: absorbed form in train/prefill too — W_UK folds
            # into q, attention runs against the latent (one shared kv head,
            # Dq = r + rope, Dv = r); the decompressed (B,S,H,192/128) K/V
            # never materialise.  Trades ~(r+rope)/Dqk x more score FLOPs for
            # a large activation-bytes reduction (see EXPERIMENTS.md §Perf).
            q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, params["wuk"].astype(dt))
            q2 = jnp.concatenate([q_eff, q_rope], axis=-1)    # (B,S,H,r+rope)
            k2 = jnp.concatenate([ckv, k_rope], axis=-1)[:, :, None]  # (B,T,1,·)
            v2 = ckv[:, :, None]                               # (B,T,1,r)
            o_lat = attend(q2, k2, v2, q_pos=positions, kv_pos=positions,
                           kind=mask_kind, window=window,
                           prefix_len=prefix_len, scale=scale,
                           unroll=cfg.force_unroll)            # (B,S,H,r)
            out = jnp.einsum("bshr,rhk->bshk", o_lat, params["wuv"].astype(dt))
        else:
            # Decompressed K/V: (B,S,H,*)
            k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["wuk"].astype(dt))
            v = jnp.einsum("bsr,rhk->bshk", ckv, params["wuv"].astype(dt))
            H = k_nope.shape[2]
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope[:, :, None], (*k_rope.shape[:2], H, k_rope.shape[-1]))],
                axis=-1)
            q = jnp.concatenate([q_nope, q_rope], axis=-1)
            out = attend(q, k, v, q_pos=positions, kv_pos=positions,
                         kind=mask_kind, window=window, prefix_len=prefix_len,
                         scale=scale, unroll=cfg.force_unroll)
        if return_cache:
            new_cache = {"ckv": ckv, "k_rope": k_rope}
    elif mode == "decode":
        # Absorbed decode against the latent cache.
        q_nope, q_rope = _q_proj(params, cfg, x, positions)        # (B,1,H,*)
        ckv_new, kr_new = _latent_proj(params, cfg, x, positions)  # (B,1,r)
        pos = jnp.asarray(cache_pos)
        if pos.ndim == 0:
            pos = jnp.broadcast_to(pos, (B,))
        rows = jnp.arange(B)
        ckv = cache["ckv"].at[rows, pos].set(ckv_new[:, 0].astype(cache["ckv"].dtype))
        k_rope = cache["k_rope"].at[rows, pos].set(
            kr_new[:, 0].astype(cache["k_rope"].dtype))
        T = ckv.shape[1]
        # Absorb W_UK into q: q_eff (B,1,H,r)
        q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, params["wuk"].astype(dt))
        s_nope = jnp.einsum("bshr,btr->bhst", q_eff, ckv.astype(dt))
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope.astype(dt))
        scores = (s_nope + s_rope).astype(jnp.float32) * scale     # (B,H,1,T)
        t_idx = jnp.arange(T)[None, None, None, :]
        posb = pos[:, None, None, None]
        ok = t_idx <= posb
        if window and window > 0:
            ok = ok & (t_idx > posb - window)
        scores = jnp.where(ok, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, ckv.astype(dt))  # (B,1,H,r)
        out = jnp.einsum("bshr,rhk->bshk", o_lat, params["wuv"].astype(dt))
        new_cache = {"ckv": ckv, "k_rope": k_rope}
    else:
        raise ValueError(mode)

    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return proj, new_cache


def init_cache(cfg, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def cache_specs(cfg, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "ckv": ((batch, max_len, m.kv_lora_rank), ("batch", "seq", None), dtype),
        "k_rope": ((batch, max_len, m.qk_rope_head_dim), ("batch", "seq", None), dtype),
    }
