"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in Pallas interpret mode — the
kernel bodies run exactly as written, validated against ref.py oracles; on a
real TPU backend interpret is off and the same BlockSpecs drive VMEM tiling.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import topk_mips as _tm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n", "interpret"))
def topk_mips(queries, bank, k: int = 32, *, n_valid=None, block_q: int = 128,
              block_n: int = 512, interpret: bool | None = None):
    """`n_valid` is a *traced* operand (SMEM scalar inside the kernel): a
    capacity-padded bank can grow its live prefix call after call without a
    recompile — the executable is keyed on the padded shapes only."""
    interpret = _interpret_default() if interpret is None else interpret
    return _tm.topk_mips(queries, bank, k, n_valid=n_valid, block_q=block_q,
                         block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n", "interpret"))
def topk_mips_masked(queries, bank, q_ns, bank_ns, k: int = 32, *,
                     n_valid=None, block_q: int = 128, block_n: int = 512,
                     interpret: bool | None = None):
    """Namespace-masked batched MIPS: one launch scores many tenants' queries
    against one packed multi-tenant bank (cross-namespace hits -> NEG_INF/-1).
    `n_valid` is traced, as in topk_mips."""
    interpret = _interpret_default() if interpret is None else interpret
    return _tm.topk_mips(queries, bank, k, n_valid=n_valid, q_ns=q_ns,
                         bank_ns=bank_ns, block_q=block_q, block_n=block_n,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n", "interpret"))
def topk_mips_quant(queries, bank_i8, scales, k: int = 32, *, n_valid=None,
                    block_q: int = 128, block_n: int = 512,
                    interpret: bool | None = None):
    """Fused dequant+MIPS over an int8 bank with per-row f32 scales: the
    bank is scanned at 1 byte/element and dequantization happens inside the
    block loop (scores accumulate in f32).  Same traced-`n_valid`
    stable-shape contract as topk_mips."""
    interpret = _interpret_default() if interpret is None else interpret
    return _tm.topk_mips(queries, bank_i8, k, n_valid=n_valid, scales=scales,
                         block_q=block_q, block_n=block_n,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n", "interpret"))
def topk_mips_quant_masked(queries, bank_i8, scales, q_ns, bank_ns,
                           k: int = 32, *, n_valid=None, block_q: int = 128,
                           block_n: int = 512, interpret: bool | None = None):
    """Namespace-masked fused dequant+MIPS (see topk_mips_quant /
    topk_mips_masked)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _tm.topk_mips(queries, bank_i8, k, n_valid=n_valid, q_ns=q_ns,
                         bank_ns=bank_ns, scales=scales, block_q=block_q,
                         block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, block_q: int = 256, block_k: int = 512,
                    interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "window", "block_t",
                                             "interpret"))
def decode_attention(q, k, v, kv_len, *, scale=None, window: int = 0,
                     block_t: int = 512, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _da.decode_attention(q, k, v, kv_len, scale=scale, window=window,
                                block_t=block_t, interpret=interpret)
