"""Retrieval hot-spot microbenchmark: the topk_mips Pallas kernel vs the
pure-jnp oracle on growing bank sizes (wall-clock here is CPU/interpret —
the roofline numbers in EXPERIMENTS.md §Roofline are the TPU-relevant ones)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out[0].block_until_ready()
    return (time.time() - t0) / iters


def run(csv_rows):
    print("\n# Retrieval microbench — fused topk_mips vs jnp oracle")
    key = jax.random.PRNGKey(0)
    D, K = 256, 32
    for N in (1024, 8192, 32768):
        q = jax.random.normal(key, (64, D))
        bank = jax.random.normal(jax.random.fold_in(key, 1), (N, D))
        t_ref = _time(lambda a, b: ref.topk_mips_ref(a, b, k=K), q, bank)
        flops = 2 * 64 * N * D
        bytes_ = (64 * D + N * D) * 4
        # v5e roofline for this op (exact MIPS is bandwidth-bound at Q=64)
        t_compute = flops / PEAK_FLOPS_BF16
        t_mem = bytes_ / HBM_BW
        print(f"N={N:6d}: jnp_ref {t_ref*1e6:9.0f}us/call | v5e roofline "
              f"compute {t_compute*1e6:6.2f}us, memory {t_mem*1e6:6.2f}us "
              f"(bound: {'memory' if t_mem > t_compute else 'compute'})")
        csv_rows.append((f"retrieval/topk_N{N}", t_ref * 1e6,
                         f"{t_mem*1e6:.2f}"))
    return csv_rows


if __name__ == "__main__":
    run([])
