"""Logical-axis partitioning.

Params and activations are annotated with *logical* axis names
("vocab", "heads", "ff", "experts", "batch", ...).  A `MeshRules` object maps
logical names to physical mesh axes for a concrete mesh, with divisibility
guards: a logical axis only shards if its dimension size divides the mesh axis
size (otherwise it is replicated — e.g. whisper's vocab=51865 on model=16).

This mirrors the MaxText "logical axis rules" design but stays dependency-free.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# Logical axis vocabulary used across the model zoo.
LOGICAL_AXES = (
    "layers",      # stacked scanned layers — never sharded
    "vocab",       # embedding/logits vocab dim
    "embed",       # d_model dim (FSDP shards this over the data axis)
    "heads",       # attention query heads
    "kv_heads",    # attention kv heads
    "head_dim",
    "ff",          # mlp hidden
    "experts",     # moe experts (expert parallel)
    "expert_cap",  # moe capacity dim
    "batch",       # global batch
    "seq",         # sequence dim (context parallel for long_500k)
    "state",       # ssm / rglru state channels
    "bank",        # memory-bank rows (retrieval)
    "topk",
    None,
)


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Maps logical axis names -> physical mesh axis (or None)."""

    mesh: Mesh
    rules: dict  # logical name -> physical axis name | tuple | None
    # heads that don't divide the model axis fall back to sharding head_dim
    # (contraction parallelism).  Right for training; WRONG for decode caches:
    # head_dim-sharded K/V makes XLA all-gather the whole cache every layer
    # (EXPERIMENTS.md §Perf pair 3) — decode rules disable it and replicate.
    head_dim_fallback: bool = True

    def axis_size(self, phys) -> int:
        if phys is None:
            return 1
        if isinstance(phys, (tuple, list)):
            s = 1
            for a in phys:
                s *= self.mesh.shape[a]
            return s
        return self.mesh.shape[phys]

    def spec_for(self, logical_axes: Sequence[Optional[str]],
                 dim_sizes: Optional[Sequence[int]] = None) -> P:
        parts = []
        fallbacks = []   # (phys, from_index) for indivisible head shardings
        for i, name in enumerate(logical_axes):
            phys = self.rules.get(name) if name is not None else None
            if phys is not None and dim_sizes is not None:
                size = self.axis_size(phys)
                if dim_sizes[i] % size != 0:
                    # replicate instead of uneven shard (pjit arguments must
                    # shard evenly); heads fall back to head_dim below
                    if name in ("heads", "kv_heads"):
                        fallbacks.append(phys)
                    phys = None
            parts.append(phys)
        # Split-within-head fallback: when the head count doesn't divide the
        # model axis (qwen2.5: 40 heads on model=16; whisper: 12), shard the
        # head_dim instead — contraction-dim parallelism that SPMD lowers to
        # partial sums + all-reduce (Megatron-style alternative).
        if fallbacks and not self.head_dim_fallback:
            fallbacks = []
        if fallbacks and dim_sizes is not None:
            for j, name in enumerate(logical_axes):
                if name == "head_dim" and parts[j] is None:
                    phys = fallbacks[0]
                    if dim_sizes[j] % self.axis_size(phys) == 0:
                        parts[j] = phys
                        break
        # PartitionSpec must not repeat a physical axis; later dims lose.
        seen: set = set()
        cleaned = []
        for phys in parts:
            flat = phys if isinstance(phys, (tuple, list)) else (phys,)
            if phys is not None and any(a in seen for a in flat):
                cleaned.append(None)
            else:
                cleaned.append(phys)
                if phys is not None:
                    seen.update(flat)
        return P(*cleaned)

    def sharding_for(self, logical_axes, dim_sizes=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, dim_sizes))


def standard_rules(mesh: Mesh, *, fsdp: bool = False) -> MeshRules:
    """The production mapping.

    data axis (+ pod, if present) carries batch; model axis carries tensor
    parallelism (heads / ff / experts / vocab).  With ``fsdp=True`` the
    ``embed`` axis of params additionally shards over data (ZeRO-3 style; XLA
    inserts the per-scan-step all-gathers).
    """
    axes = mesh.axis_names
    has_pod = "pod" in axes
    batch_axes = ("pod", "data") if has_pod else ("data",)
    rules = {
        "layers": None,
        "vocab": "model",
        "embed": (("pod", "data") if has_pod else "data") if fsdp else None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        # capacity dim shards over the batch axes: each data shard owns its
        # slice of every expert's buffer (GShard layout) — without this the
        # (E, C, d) buffers replicate across data and expert FLOPs blow up 16x
        "expert_cap": ("pod", "data") if has_pod else "data",
        "batch": batch_axes if len(batch_axes) > 1 else batch_axes[0],
        "seq": None,
        "state": "model",
        "bank": (("pod", "data", "model") if has_pod else ("data", "model")),
        "topk": None,
    }
    return MeshRules(mesh=mesh, rules=rules)


def long_context_rules(mesh: Mesh) -> MeshRules:
    """Rules for decode at batch=1 over a 500k cache: the cache *sequence*
    shards over the data axis (context parallel); the softmax reduction over
    the sharded axis lowers to LSE-combining collectives under SPMD."""
    r = standard_rules(mesh)
    rules = dict(r.rules)
    rules["seq"] = "data"
    rules["batch"] = None
    return MeshRules(mesh=mesh, rules=rules)


# ---------------------------------------------------------------------------
# Path-pattern -> logical axes assignment for param pytrees.
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def spec_tree_from_axes(axes_tree: PyTree, shapes_tree: PyTree, rules: MeshRules) -> PyTree:
    """axes_tree mirrors the param tree, with tuples of logical names at the
    leaves; returns a tree of PartitionSpec."""
    return jax.tree.map(
        lambda ax, shp: rules.spec_for(ax, shp.shape),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and (len(x) == 0 or x[0] is None or isinstance(x[0], str)),
    )


def shard_constraint(x, rules: MeshRules, *logical_axes):
    """with_sharding_constraint by logical names (divisibility-guarded)."""
    return jax.lax.with_sharding_constraint(
        x, rules.sharding_for(logical_axes, x.shape)
    )


PATTERN_RULES: list = [
    # (regex on param path, logical axes per dim) — used by generic matchers.
    (re.compile(r"embed/table$"), ("vocab", "embed")),
]
