"""Synthetic LoCoMo generator invariants + oracle self-consistency."""
import pytest

from repro.core import Message, MemoriMemory
from repro.core.embedder import HashEmbedder
from repro.data.locomo_synth import (CATEGORIES, generate_conversation, judge,
                                     oracle_read)


@pytest.fixture(scope="module")
def conv():
    return generate_conversation(seed=7, n_sessions=8, noise_turns=30)


def test_generation_is_deterministic(conv):
    other = generate_conversation(seed=7, n_sessions=8, noise_turns=30)
    assert [m.text for m in conv.all_messages()] == \
        [m.text for m in other.all_messages()]
    assert [q.question for q in conv.questions] == \
        [q.question for q in other.questions]


def test_all_categories_generated(conv):
    assert {q.category for q in conv.questions} == set(CATEGORIES)


def test_supports_exist_in_raw_transcript(conv):
    """Oracle self-consistency: with the full transcript and rot disabled,
    every question must be answerable — the planted facts really are there."""
    import time as _t
    lines = []
    for _, msgs in conv.sessions:
        for m in msgs:
            ts = _t.strftime("%Y-%m-%d", _t.gmtime(m.timestamp))
            lines.append(f"[{ts}] {m.speaker}: {m.text}")
    full_text = "\n".join(lines)
    for q in conv.questions:
        ans = oracle_read(q, full_text, rot_coef=0.0)
        assert judge(q, ans), (q.question, ans)


def test_memori_resolves_job_change_to_latest(conv):
    """End-to-end recency: after a job change, resolve() returns the NEW job."""
    mem = MemoriMemory(HashEmbedder(), use_kernel=False)
    for sid, msgs in conv.sessions:
        mem.record_session(conv.conversation_id, sid, msgs)
    sp = conv.speakers[0]
    jobs = [q for q in conv.questions
            if q.category == "single_hop" and "work as now" in q.question
            and sp in q.question]
    if not jobs:
        pytest.skip("paraphrased variant generated for this seed")
    t = mem.resolve(f"{sp} works as")
    assert t is not None
    assert t.object == jobs[0].answer.lower()


def test_conversation_token_scale():
    conv = generate_conversation(seed=3)     # defaults
    from repro.data.tokenizer import default_tokenizer
    tok = default_tokenizer()
    total = sum(tok.count(m.text) + 4 for m in conv.all_messages())
    assert 20_000 < total < 34_000           # paper's 26k full-context regime
