"""Memory-augmented agent serving: the full Memori stack end-to-end.

    PYTHONPATH=src python examples/agent_serve.py

A small LM is served with continuous batching behind the MemoriClient SDK,
fronted by the multi-tenant MemoryService: every user gets an isolated
namespace in one shared packed bank, chat turns retrieve structured memory
and record the exchange back through Advanced Augmentation, and the pending
queries of *all* tenants are answered in one batched retrieval (one embed
call + one namespace-masked topk_mips launch).  The LM is random-init (this
box trains ~minutes, not the hours a useful chat model needs) — the demo
shows the *system*: interception, retrieval, isolation, token accounting,
batched decode.
"""
import time

import jax

from repro.configs import get_config
from repro.core import MemoriClient, MemoryService
from repro.core.embedder import HashEmbedder
from repro.data.tokenizer import HashTokenizer
from repro.models.model_api import Model
from repro.serving.engine import Engine
from repro.serving.sampler import SamplerConfig


def main():
    cfg = get_config("memori-agent").reduced(layers=2, d_model=128)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashTokenizer(cfg.vocab_size)
    engine = Engine(model, params, max_len=192, slots=2,
                    sampler=SamplerConfig(temperature=0.9, top_k=50),
                    tokenizer=tok)

    def llm(prompt: str) -> str:
        return engine.generate([prompt[-600:]], max_new_tokens=16)[0]

    service = MemoryService(HashEmbedder(), budget=800, use_kernel=False)
    users = {
        "priya/c0": ("Priya", [
            "Hi there! I am Priya.",
            "I work as a botanist and I live in Tallinn.",
            "My favorite color is indigo.",
            "I adopted a hedgehog named Biscuit.",
        ]),
        "marco/c0": ("Marco", [
            "Hello, Marco here.",
            "I work as a glassblower and I live in Porto.",
            "I adopted a parrot named Olive.",
        ]),
    }
    for ns, (name, turns) in users.items():
        client = MemoriClient(llm, service.namespace(ns), user_name=name)
        for t in turns:
            reply = client.chat(t, timestamp=time.time())
            print(f"{name}: {t}\n  agent: {reply[:60]}")
        client.end_session()

    print("\nservice after sessions:", service.stats())
    # the cross-tenant hot path: both tenants' queries in ONE batched call
    batch = [("priya/c0", "What is the name of Priya's pet?"),
             ("marco/c0", "What is the name of Marco's pet?")]
    for (ns, q), ctx in zip(batch, service.retrieve_batch(batch)):
        print(f"\n[{ns}] Q: {q}  ({ctx.token_count} tokens injected)")
        for t in ctx.triples[:3]:
            print(f"   {t.render()}")
    print(f"\nengine stats: {engine.stats}")


if __name__ == "__main__":
    main()
