"""Fused top-k maximum-inner-product search over the Memori triple bank.

This is the TPU-native replacement for the paper's FAISS index (DESIGN.md
§3): the embedding bank is streamed HBM→VMEM in (block_n, D) tiles, scored
against the resident query tile on the MXU, and a running top-k (scores +
global indices) is maintained in the revisited output block across the
sequential bank-block grid dimension.

Exact search is deliberate: Advanced Augmentation compresses dialogue to
~10⁶-scale triples, small enough that exact MIPS beats pointer-chasing ANN
structures on TPU.

Grid: (num_q_blocks, num_bank_blocks)   — bank dim innermost/sequential.
Per-step top-k merge is an unrolled k-iteration argmax sweep (Pallas-TPU
friendly: no sort, no scatter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _merge_topk(scores_ref, idx_ref, s, col, k: int):
    """Merge block scores s (Qb, Nb) with the running (Qb, k) top-k refs."""
    all_s = jnp.concatenate([scores_ref[...], s], axis=1)
    all_i = jnp.concatenate([idx_ref[...], col], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, all_s.shape, 1)
    for j in range(k):
        m = jnp.max(all_s, axis=1)
        am = jnp.argmax(all_s, axis=1)
        hit = cols == am[:, None]
        sel_i = jnp.sum(jnp.where(hit, all_i, 0), axis=1)
        scores_ref[:, j] = m
        idx_ref[:, j] = sel_i
        all_s = jnp.where(hit, NEG_INF, all_s)


def _kernel(q_ref, bank_ref, scores_ref, idx_ref, *, block_n: int, k: int,
            n_valid: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        scores_ref[...] = jnp.full_like(scores_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    q = q_ref[...]
    b = bank_ref[...]
    s = jax.lax.dot_general(q, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Qb, Nb)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + nb * block_n
    s = jnp.where(col < n_valid, s, NEG_INF)   # mask padded bank rows
    _merge_topk(scores_ref, idx_ref, s, col, k)


def topk_mips(queries, bank, k: int = 32, *, block_q: int = 128,
              block_n: int = 512, interpret: bool = False):
    """queries (Q, D) · bank (N, D) -> (scores (Q, k) f32, indices (Q, k) i32).
    Rows beyond N (padding) never appear: padded bank rows score NEG_INF."""
    Q, D = queries.shape
    N = bank.shape[0]
    bq = min(block_q, max(8, Q))
    bn = min(block_n, max(8, N))
    Qp = -(-Q // bq) * bq
    Np = -(-N // bn) * bn
    qp = jnp.pad(queries, ((0, Qp - Q), (0, 0)))
    bp = jnp.pad(bank, ((0, Np - N), (0, 0)))

    grid = (Qp // bq, Np // bn)
    scores, idx = pl.pallas_call(
        functools.partial(_kernel, block_n=bn, k=k, n_valid=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, bp)
    return scores[:Q], idx[:Q]
