#!/usr/bin/env bash
# CI entry point: fast signal first, then the tier-1 gate.
#
#   scripts/ci.sh            # fast pass (-m "not slow") + full tier-1 suite
#   FAST_ONLY=1 scripts/ci.sh  # just the fast pass (pre-push hook friendly)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== fast pass: pytest -m 'not slow' =="
python -m pytest -q -m "not slow"

if [[ "${FAST_ONLY:-0}" != "1" ]]; then
    echo "== tier-1: pytest -x -q (full suite) =="
    python -m pytest -x -q

    echo "== bench smoke: service throughput (retrieval + ingestion + compaction) =="
    JAX_PLATFORMS=cpu python benchmarks/service_throughput.py \
        --tenants 4 --sessions 2 --batches 1,8 --mode all \
        --json BENCH_service.json
    echo "== BENCH_service.json =="
    cat BENCH_service.json

    echo "== bench: steady-state retrieval (device-resident engine, 65k-row bank) =="
    # asserts zero recompiles while the bank grows within a capacity bucket
    JAX_PLATFORMS=cpu python benchmarks/retrieval_microbench.py \
        --steady --json BENCH_retrieval.json
    echo "== BENCH_retrieval.json =="
    cat BENCH_retrieval.json

    echo "== bench: quantized bank (int8 + exact rescore, 65k-row bank) =="
    # asserts >= 2x lower bank-bytes-read and recall@10 >= 0.95 vs the
    # f32 oracle (the acceptance gate for the quantized residency mode)
    JAX_PLATFORMS=cpu python benchmarks/retrieval_microbench.py \
        --quantized --assert-recall 0.95 --json BENCH_quantized.json
    echo "== BENCH_quantized.json =="
    cat BENCH_quantized.json

    echo "== bench: lifecycle soak (flusher + auto-compaction + rotation live) =="
    # asserts the recovered service answers identically to the live one
    JAX_PLATFORMS=cpu python benchmarks/lifecycle_bench.py \
        --seconds 5 --json BENCH_lifecycle.json
    echo "== BENCH_lifecycle.json =="
    cat BENCH_lifecycle.json

    echo "== bench: kill-a-shard recovery (sharded WAL + follower restore) =="
    # asserts the recovered service answers bit-identically to the live one
    # after a shard's disk is lost and rebuilt from the follower's segments
    JAX_PLATFORMS=cpu python benchmarks/shard_recovery_bench.py \
        --seconds 3 --shards 2 --tenants 8 --json BENCH_shard_recovery.json
    echo "== BENCH_shard_recovery.json =="
    cat BENCH_shard_recovery.json

    echo "== bench: cross-client scheduler (closed-loop multi-client) =="
    # asserts the scheduled path >= 2x the per-call path at 8 clients
    JAX_PLATFORMS=cpu python benchmarks/scheduler_bench.py \
        --clients 1,8 --seconds 2 --assert-speedup 2.0 \
        --json BENCH_scheduler.json
    echo "== BENCH_scheduler.json =="
    cat BENCH_scheduler.json

    echo "== bench: telemetry overhead (instrumented vs disabled closed loop) =="
    # asserts tracing + metrics add < 5% to closed-loop p50 (median of
    # interleaved within-pair ratios — robust to shared-runner drift)
    JAX_PLATFORMS=cpu python benchmarks/telemetry_overhead_bench.py \
        --assert-overhead 1.05 --json BENCH_telemetry.json
    echo "== BENCH_telemetry.json =="
    cat BENCH_telemetry.json

    echo "== bench: graph expansion (k-hop recall uplift vs flat hybrid) =="
    # asserts the graph-expanded plan's triple-level support recall beats
    # flat hybrid by >= 0.1 on graph-answerable chains, within a 5x batch
    # latency budget, with zero recompiles in steady state
    JAX_PLATFORMS=cpu python benchmarks/graph_bench.py \
        --assert-uplift 0.1 --assert-latency-factor 5.0 \
        --json BENCH_graph.json
    echo "== BENCH_graph.json =="
    cat BENCH_graph.json

    echo "== bench: per-tenant QoS (1 abusive + N well-behaved tenants) =="
    # asserts one flooding tenant degrades well-behaved p99 by < 2x vs the
    # no-abuser baseline (admission control protects the fleet)
    JAX_PLATFORMS=cpu python benchmarks/qos_bench.py \
        --clients 40 --tenants 10 --seconds 2 --assert-protection 2.0 \
        --json BENCH_qos.json
    echo "== BENCH_qos.json =="
    cat BENCH_qos.json
fi
