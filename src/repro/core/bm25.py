"""BM25 keyword index, TPU-adapted (DESIGN.md §3).

Classic BM25 walks inverted lists — pointer-chasing the TPU hates.  Here
terms hash into a fixed id space and documents are fixed-width padded id
rows, so scoring a query against the whole bank is a dense vectorised
comparison:  tf(t, d) = sum_j [doc_ids[d, j] == t].  Ranking semantics match
textbook BM25 up to hash collisions (property-tested against a dict-based
oracle in tests/).
"""
from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import HashTokenizer, default_tokenizer


class BM25Index:
    def __init__(self, k1: float = 1.5, b: float = 0.75, max_doc_len: int = 32,
                 tokenizer: HashTokenizer | None = None):
        self.k1 = k1
        self.b = b
        self.max_doc_len = max_doc_len
        self.tokenizer = tokenizer or default_tokenizer()
        self._doc_rows: List[np.ndarray] = []
        self._doc_lens: List[int] = []
        self._df: dict[int, int] = {}
        self._dirty = True
        self._docs_arr = None
        self._lens_arr = None

    def add(self, texts: Sequence[str]) -> List[int]:
        ids = []
        for t in texts:
            tok = self.tokenizer.encode(t)[: self.max_doc_len]
            row = np.full((self.max_doc_len,), -1, np.int32)
            row[: len(tok)] = tok
            self._doc_rows.append(row)
            self._doc_lens.append(max(1, len(tok)))
            for term in set(tok):
                self._df[term] = self._df.get(term, 0) + 1
            ids.append(len(self._doc_rows) - 1)
        self._dirty = True
        return ids

    def __len__(self):
        return len(self._doc_rows)

    def _arrays(self):
        if self._dirty:
            self._docs_arr = jnp.asarray(np.stack(self._doc_rows)) \
                if self._doc_rows else jnp.zeros((0, self.max_doc_len), jnp.int32)
            self._lens_arr = jnp.asarray(np.asarray(self._doc_lens, np.float32)) \
                if self._doc_lens else jnp.zeros((0,), jnp.float32)
            self._dirty = False
        return self._docs_arr, self._lens_arr

    def scores(self, query: str) -> jnp.ndarray:
        """BM25 scores over all docs -> (N,) f32 (empty -> (0,))."""
        docs, lens = self._arrays()
        N = docs.shape[0]
        if N == 0:
            return jnp.zeros((0,), jnp.float32)
        terms = list(dict.fromkeys(self.tokenizer.encode(query)))
        if not terms:
            return jnp.zeros((N,), jnp.float32)
        avg_len = float(np.mean(self._doc_lens))
        out = jnp.zeros((N,), jnp.float32)
        norm = self.k1 * (1.0 - self.b + self.b * lens / avg_len)
        for t in terms:
            df = self._df.get(t, 0)
            if df == 0:
                continue
            idf = float(np.log(1.0 + (N - df + 0.5) / (df + 0.5)))
            tf = (docs == t).sum(axis=1).astype(jnp.float32)
            out = out + idf * tf * (self.k1 + 1.0) / (tf + norm)
        return out

    def topk(self, query: str, k: int):
        s = self.scores(query)
        if s.shape[0] == 0:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        k = min(k, s.shape[0])
        idx = np.argsort(-np.asarray(s), kind="stable")[:k]
        return np.asarray(s)[idx], idx
