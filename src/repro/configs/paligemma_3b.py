"""paligemma-3b [vlm] — 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216.  SigLIP vision tower is a STUB per the assignment —
input_specs provides (B, 256, 1152) patch embeddings consumed through a
learned projector; the Gemma decoder uses prefix-LM masking over the image
tokens.  [arXiv:2407.07726]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        arch_type="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,               # MQA (gemma-2b)
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        source="[arXiv:2407.07726]",
        num_image_tokens=256,
        act="gelu",
        mlp_gated=True,
        tie_embeddings=True,
        attention="prefix_lm",
        long_context_window=8192,     # sliding-window variant for long_500k
    )
