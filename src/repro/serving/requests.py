"""Serving request/response types."""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt_tokens: List[int]
    max_new_tokens: int = 32
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Response:
    request_id: int
    tokens: List[int]
    prompt_len: int
    finished: bool = True
