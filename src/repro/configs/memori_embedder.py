"""memori-embedder — the in-framework replacement for the paper's Gemma-300
embedding model: a small bidirectional transformer encoder, mean-pooled to a
256-d embedding, used by the Advanced Augmentation pipeline to embed semantic
triples (DESIGN.md §3 adaptation note 2)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="memori-embedder",
        arch_type="dense",
        num_layers=4,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=1024,
        vocab_size=32768,
        source="[this paper: Gemma-300 replacement]",
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )
