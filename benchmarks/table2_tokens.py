"""Paper Table 2 analogue: token usage + cost per query + context footprint
(gpt-4.1-mini pricing $0.8/1M tokens, as in the paper)."""
from __future__ import annotations

import time

from benchmarks.common import evaluate

PRICE_PER_TOKEN = 0.8 / 1e6
SYSTEMS = ["memori", "rag", "full-context"]


def run(csv_rows):
    print("\n# Table 2 — token usage and cost efficiency")
    results = {}
    for name in SYSTEMS:
        t0 = time.time()
        r = evaluate(name)
        us = (time.time() - t0) * 1e6 / max(1, r.n_questions)
        results[name] = r
        csv_rows.append((f"table2/{name}", us, f"{r.mean_tokens:.0f}"))
    full = results["full-context"].mean_tokens
    print(f"{'method':14s} {'added tokens':>12s} {'cost($)':>10s} {'footprint':>9s}")
    for name, r in results.items():
        print(f"{name:14s} {r.mean_tokens:12.0f} "
              f"{r.mean_tokens * PRICE_PER_TOKEN:10.6f} "
              f"{100 * r.mean_tokens / full:8.2f}%")
    saving = full / results["memori"].mean_tokens
    print(f"memori vs full-context: {saving:.1f}x cheaper per query")
    return csv_rows


if __name__ == "__main__":
    run([])
