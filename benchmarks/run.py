# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations


def main() -> None:
    from benchmarks import (fig2_variance, retrieval_microbench,
                            roofline_report, service_throughput,
                            table1_accuracy, table2_tokens, table3_categories)
    rows = []
    for mod in (table1_accuracy, table2_tokens, table3_categories,
                fig2_variance, retrieval_microbench, service_throughput,
                roofline_report):
        rows = mod.run(rows)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
