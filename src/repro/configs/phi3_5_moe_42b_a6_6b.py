"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.config import MoEConfig, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        arch_type="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        source="[hf:microsoft/Phi-3.5-MoE-instruct]",
        use_moe=True,
        moe=MoEConfig(num_experts=16, experts_per_token=2,
                      num_shared_experts=0, d_ff_expert=6400,
                      capacity_factor=1.25),
        long_context_window=8192,
    )
