"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) d_ff=0 vocab=50280,
ssm_state=128.  SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        arch_type="ssm",
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,                       # the SSD block is the whole layer
        vocab_size=50280,
        source="[arXiv:2405.21060]",
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      chunk_size=128, n_groups=1),
        tie_embeddings=True,
        long_context_window=0,        # natively sub-quadratic
    )
