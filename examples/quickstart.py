"""Quickstart: the Memori persistent memory layer in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Ingest two chat sessions through Advanced Augmentation, then answer
questions from the structured memory — and compare the token bill against
stuffing the full history into the prompt.
"""
import time

from repro.core import MemoriMemory, Message
from repro.core.baselines import FullContextMemory
from repro.core.embedder import HashEmbedder


def main():
    memory = MemoriMemory(HashEmbedder(), budget=1300, use_kernel=False)
    full = FullContextMemory()

    t0 = time.time() - 14 * 86400
    sessions = {
        "s0": [
            Message("Ana", "Hey! Long time no see.", t0),
            Message("Ana", "I work as a data analyst these days.", t0),
            Message("Ana", "My favorite food is pad thai.", t0),
            Message("Ana", "I adopted a parrot named Mochi.", t0),
            Message("Ben", "Nice! I went to Iceland. The glaciers were unreal.", t0),
        ],
        "s1": [
            Message("Ana", "Big news since last time we talked!", t0 + 7 * 86400),
            Message("Ana", "I used to work as a data analyst, but now I am a chef.",
                    t0 + 7 * 86400),
            Message("Ben", "I bought a telescope last week.", t0 + 7 * 86400),
        ],
    }
    for sid, msgs in sessions.items():
        memory.record_session("demo", sid, msgs)
        full.record_session("demo", sid, msgs)

    print("memory stats:", memory.stats(), "\n")
    for q in ["What does Ana work as now?",
              "What is the name of Ana's parrot?",
              "Where did Ben travel to?"]:
        ctx = memory.retrieve(q)
        print(f"Q: {q}")
        print(f"  retrieved {len(ctx.triples)} triples, "
              f"{len(ctx.summaries)} summaries, {ctx.token_count} tokens "
              f"(full-context would be {full.retrieve(q).token_count})")
        for t in ctx.triples[:3]:
            print(f"    {t.render()}")
        print()

    prompt, ctx = memory.answer_prompt("What does Ana work as now?")
    print("--- assembled LLM prompt (truncated) ---")
    print(prompt[:600])


if __name__ == "__main__":
    main()
