from repro.common import partitioning, utils  # noqa: F401
