"""Unit + integration tests for the Memori core (the paper's contribution)."""
import time

import pytest

from repro.core import (AdvancedAugmentation, MemoriClient, MemoriMemory,
                        Message, RuleExtractor, Triple, TripleStore)
from repro.core.baselines import FullContextMemory, RagChunkMemory
from repro.core.budget import TokenBudgeter
from repro.core.embedder import HashEmbedder
from repro.core.summaries import SummaryStore
from repro.data.tokenizer import default_tokenizer

EMB = HashEmbedder()


def _mem(**kw):
    kw.setdefault("use_kernel", False)   # pure-jnp search: fast on CPU
    return MemoriMemory(EMB, **kw)


def _session(texts, speaker="Caroline", ts=1700000000.0):
    return [Message(speaker, t, ts) for t in texts]


# -- extraction --------------------------------------------------------------

def test_rule_extractor_finds_planted_facts():
    ex = RuleExtractor()
    msgs = _session([
        "My favorite food is sushi.",
        "I work as a teacher.",
        "I adopted a puppy named Max.",
        "I used to work as a nurse, but now I am a chef.",
        "The weather is nice today.",
    ])
    triples, summary = ex.extract("c", "s0", msgs)
    texts = {t.text() for t in triples}
    assert "Caroline favorite food sushi" in texts
    assert "Caroline works as teacher" in texts
    assert "Caroline adopted puppy" in texts
    assert "puppy is named max" in texts
    assert "Caroline used to work as nurse" in texts
    assert "Caroline works as chef" in texts
    assert "summary" not in summary.text.lower() or summary.text
    assert "Caroline" in summary.text


def test_extractor_skips_pure_noise():
    ex = RuleExtractor()
    triples, _ = ex.extract("c", "s0", _session([
        "How have you been lately?",
        "The weather here has been so strange.",
        "Anyway, enough about that.",
    ]))
    assert triples == []


def test_triple_store_latest_for_key():
    store = TripleStore()
    store.add(Triple("a", "works as", "nurse", timestamp=1.0))
    store.add(Triple("a", "works as", "chef", timestamp=2.0))
    latest = store.latest_for_key("a|works as")
    assert latest.object == "chef"


# -- pipeline / retrieval ------------------------------------------------------

def test_augmentation_aligns_indices():
    aug = AdvancedAugmentation(EMB, use_kernel=False)
    aug.ingest("c", "s0", _session(["I love chess.", "I live in Lisbon."]))
    aug.ingest("c", "s1", _session(["My favorite color is teal."]))
    st = aug.stats()
    assert st["triples"] == st["bank_rows"] == len(aug.bm25)
    assert st["summaries"] == 2


def test_retrieval_surfaces_relevant_triple_with_summary():
    mem = _mem()
    mem.record_session("c", "s0", _session(["I love chess.",
                                            "I live in Lisbon."]))
    mem.record_session("c", "s1", _session(["I adopted a kitten named Luna."]))
    ctx = mem.retrieve("Which city does Caroline live in?")
    assert any(t.object == "lisbon" for t in ctx.triples)
    assert ctx.summaries, "linked session summary must ride along"
    assert ctx.token_count <= mem.budgeter.budget


def test_retrieval_empty_memory_is_safe():
    mem = _mem()
    ctx = mem.retrieve("anything at all?")
    assert ctx.triples == [] and ctx.token_count >= 0


# -- budget ---------------------------------------------------------------------

def test_budgeter_never_exceeds_budget():
    tok = default_tokenizer()
    summaries = SummaryStore()
    budgeter = TokenBudgeter(budget=40, tokenizer=tok)
    cands = [(Triple("s", f"pred{i}", f"object number {i}",
                     conversation_id="c", session_id=f"s{i}",
                     timestamp=float(i)), 1.0 / (i + 1)) for i in range(50)]
    ctx = budgeter.select(cands, summaries)
    assert ctx.token_count <= 40
    assert len(ctx.triples) >= 1


# -- SDK -------------------------------------------------------------------------

def test_sdk_round_trip_injects_memory():
    mem = _mem()
    seen_prompts = []

    def llm(prompt):
        seen_prompts.append(prompt)
        return "ok"

    client = MemoriClient(llm, mem)
    client.chat("My favorite food is ramen.", timestamp=time.time())
    client.end_session()
    client.chat("Do you remember my favorite food?")
    assert "ramen" in seen_prompts[-1].lower(), \
        "retrieved triple must be injected into the LLM prompt"
    assert client.context_tokens("favorite food?") < 200


# -- baselines --------------------------------------------------------------------

def test_full_context_grows_but_memori_stays_bounded():
    mem = _mem(budget=300)
    full = FullContextMemory()
    for s in range(6):
        msgs = _session([f"I bought a telescope number {s}.",
                         "Nothing else happened today."] * 10, ts=1e9 + s)
        mem.record_session("c", f"s{s}", msgs)
        full.record_session("c", f"s{s}", msgs)
    q = "What did Caroline buy?"
    assert full.retrieve(q).token_count > 4 * mem.retrieve(q).token_count


def test_rag_chunker_chunks_by_token_budget():
    rag = RagChunkMemory(EMB, chunk_tokens=30, top_k=2, use_kernel=False)
    rag.record_session("c", "s0", _session([f"sentence number {i} is here."
                                            for i in range(40)]))
    ctx = rag.retrieve("sentence number 7")
    assert ctx.token_count > 0
    assert len(rag._chunks) > 5
