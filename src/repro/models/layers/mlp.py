"""Dense MLP: gated (SwiGLU/GeGLU) or plain 2-layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.module import ParamSpec


def _act(cfg, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def specs(cfg, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "wi": ParamSpec((d, ff), ("embed", "ff"), init="scaled_normal", scale=1.0),
        "wo": ParamSpec((ff, d), ("ff", "embed"), init="scaled_normal", scale=1.0),
    }
    if cfg.mlp_gated:
        s["wg"] = ParamSpec((d, ff), ("embed", "ff"), init="scaled_normal", scale=1.0)
    return s


def apply(params, cfg, x):
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype))
    if cfg.mlp_gated:
        g = jnp.einsum("...d,df->...f", x, params["wg"].astype(x.dtype))
        h = _act(cfg, g) * h
    else:
        h = _act(cfg, h)
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(x.dtype))
