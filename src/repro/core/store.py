"""MemoryStore — the unified storage engine under the memory layer.

Before this module existed, the packed vector bank, the BM25 corpus, the
per-tenant triple/summary stores and the row↔namespace↔triple mapping were
aligned parallel structures scattered across `core/service.py` and
`core/augmentation.py`, held together by raw asserts.  MemoryStore owns all
of them as ONE consistent unit with three subsystems the scattered version
could not support:

* **async batched ingestion** — `enqueue()` is cheap (no extraction, no
  embedding); `flush()` drains every pending session across *all* tenants
  through ONE `embed_texts` call and ONE bank append, mirroring how
  `MemoryService.retrieve_batch` amortizes reads.  `ingest()` is the
  synchronous path (enqueue + flush).
* **bank compaction** — `compact()` rebuilds the packed bank dropping
  tombstoned rows and remaps global row ids in the row tables, the BM25
  corpus and every tenant's `rows` list, so long-lived services stop
  leaking memory after `evict` / `evict_superseded`.
* **snapshot/restore persistence** — `snapshot(path)` serializes the bank,
  BM25 arrays, triples, summaries and namespace tables through
  `checkpoint/io.py`; `MemoryStore.restore(path, embedder)` reconstructs a
  store whose retrieval results are bit-identical to the writer's.
* **incremental persistence hooks** — when `wal_sink` is attached (by
  `core/lifecycle.py`'s LifecycleRuntime), every durable mutation emits a
  self-describing record *before* it is applied: `flush` logs the extracted
  sessions plus the raw embedding vectors (the only input a replay could
  not recompute bit-exactly), `evict`/`evict_superseded`/`compact` log
  their operation (they are deterministic functions of store state).
  `apply_wal(record)` replays a record through the exact same commit code
  the original mutation used, so snapshot + ordered replay reconstructs a
  store that answers retrieval bit-identically up to the last durable
  record.

Layout invariant (checked, raising StoreInvariantError — not asserted):
global row id == BM25 doc id == position in the row tables; tenant-local
`rows[tid]` maps a triple id back to its global row (-1 once compacted
away).  See docs/STORAGE.md for the full layout and remapping rules.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import msgpack
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.obs.telemetry import FLUSH_LATENCY, get_telemetry
from repro.core.bm25 import BM25Index
from repro.core.extraction import Extractor, Message, RuleExtractor
from repro.core.graph import (EDGE_TYPE_IDS, GraphInvariantError,
                              MemoryGraph)
from repro.core.summaries import Summary, SummaryStore
from repro.core.triples import Triple, TripleStore
from repro.core.vector_index import VectorIndex
from repro.data.tokenizer import HashTokenizer, default_tokenizer

# v2 added the memory-graph extents (graph_* arrays + meta["graph"]); v1
# snapshots predate the graph subsystem and are refused rather than half-read
SNAPSHOT_VERSION = 2


class StoreInvariantError(RuntimeError):
    """A storage-layer alignment invariant was violated (row id / doc id /
    row-table drift).  A real exception — unlike the asserts it replaces,
    it does not vanish under ``python -O``."""


@dataclasses.dataclass
class TenantState:
    """Per-namespace state.  Bank rows and BM25 doc ids share one global id
    space (row == doc id); `rows[local_tid] -> global row` maps back
    (-1 after the row was tombstoned and compacted away)."""
    ns_id: int
    triples: TripleStore = dataclasses.field(default_factory=TripleStore)
    summaries: SummaryStore = dataclasses.field(default_factory=SummaryStore)
    rows: List[int] = dataclasses.field(default_factory=list)
    evicted: Set[int] = dataclasses.field(default_factory=set)  # local tids


@dataclasses.dataclass
class PendingSession:
    namespace: str
    conversation_id: str
    session_id: str
    messages: List[Message]


class MemoryStore:
    def __init__(self, embedder, extractor: Optional[Extractor] = None,
                 dim: int = 256, use_kernel: bool = True,
                 tokenizer: HashTokenizer | None = None,
                 quantize: str = "none", rescore: int = 4,
                 shards: int = 1, mesh=None):
        self.embedder = embedder
        self.extractor = extractor or RuleExtractor()
        self.tokenizer = tokenizer or default_tokenizer()
        self.dim = dim
        self.use_kernel = use_kernel
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if shards > 1 and quantize != "none":
            raise ValueError(
                "sharded placement and the quantized device bank are "
                "mutually exclusive (the shard slabs hold f32 rows)")
        self.shards = int(shards)
        self.mesh = mesh
        # quantize="int8" keeps the f32 host mirror as ground truth
        # (snapshots/WAL bit-identical) but holds the DEVICE bank as int8
        # codes + per-row scales searched by the fused dequant kernel with
        # exact f32 rescore of the top rescore*k candidates
        self.vindex = VectorIndex(dim=dim, use_kernel=use_kernel,
                                  quantize=quantize, rescore=rescore)
        # shards > 1 mounts a shard-major device bank (core/shards.py):
        # namespace-affine placement over a device mesh, searched by the
        # namespace-masked sharded_topk.  The VectorIndex host mirror stays
        # the ground truth for WAL/snapshot/compaction either way.
        if self.shards > 1:
            from repro.core.shards import ShardedBank
            self.sharded: Optional[object] = ShardedBank(
                dim, self.shards, mesh=mesh, use_kernel=use_kernel)
        else:
            self.sharded = None
        self.bm25 = BM25Index(tokenizer=self.tokenizer)
        # device-resident entity graph (core/graph.py): interned entity
        # nodes, typed edges (entity/temporal/causal) and row-incidence
        # lanes, grown at flush time and remapped through compaction like
        # every other row table.  The retrieval graph stage expands over it.
        self.graph = MemoryGraph()
        # hot/warm tier manager (core/tiering.py) — attach_tiers() mounts
        # one; when None every row stays device-resident
        self.tiers = None
        self._tenants: Dict[str, TenantState] = {}
        self._ns_ids: Dict[str, int] = {}      # survives evict(): tombstoned
        #                                        rows keep a retired ns id
        # global row -> namespace id lives in the vector index (single
        # source of truth, mirrored into its device label buffer)
        self._row_tid: List[int] = []          # global row -> local tid
        self._pending: List[PendingSession] = []
        # incremental-persistence hook: called with a self-describing record
        # BEFORE each durable mutation is applied (WAL-before-apply); a sink
        # that raises aborts the mutation.  Attached by LifecycleRuntime;
        # must be None while apply_wal() replays (replay must not re-log).
        self.wal_sink: Optional[Callable[[dict], object]] = None
        # called with the session count AFTER each non-empty flush commits,
        # whoever triggered it (runtime, service read path, direct caller);
        # the runtime uses it to track flush times and wake blocked
        # enqueuers waiting on queue space
        self.on_flush_commit: Optional[Callable[[int], None]] = None

    # -- tenancy -----------------------------------------------------------
    def tenant(self, namespace: str) -> TenantState:
        """Create-or-get a tenant (the write path)."""
        t = self._tenants.get(namespace)
        if t is None:
            ns_id = self._ns_ids.setdefault(namespace, len(self._ns_ids))
            t = self._tenants[namespace] = TenantState(ns_id=ns_id)
        return t

    def get(self, namespace: str) -> Optional[TenantState]:
        """Get without creating (the read path: unknown stays unknown)."""
        return self._tenants.get(namespace)

    def namespaces(self) -> List[str]:
        return list(self._tenants)

    def namespace_id_count(self) -> int:
        """Number of namespace ids ever assigned (a fresh id >= this count
        can never collide with any bank row's label)."""
        return len(self._ns_ids)

    def row_namespaces(self) -> np.ndarray:
        """(n,) int32: every bank row's namespace id (host array; the
        vector index is the single owner of the row->namespace mapping)."""
        return self.vindex.row_namespaces()

    def row_namespaces_device(self):
        """(capacity,) i32 DEVICE array of effective row labels (live row ->
        ns id, tombstone/unfilled -> -1).  Cached inside the vector index
        and updated in place on flush/evict; rebuilt after compact/restore —
        the retrieval hot path never reconstructs it per call."""
        return self.vindex.row_labels_device()

    def row_tid(self, row: int) -> int:
        return self._row_tid[row]

    # -- tiering -----------------------------------------------------------
    def attach_tiers(self, policy=None, clock=None) -> "object":
        """Mount a hot/warm TierManager (core/tiering.py) on the vector
        index.  Activity notes flow from the write path (`_apply_flush`)
        and the service's read path; demotion/promotion run from lifecycle
        maintenance.  Returns the manager (also at `self.tiers`)."""
        from repro.core.tiering import TierManager
        if self.tiers is not None:
            raise ValueError("a TierManager is already attached")
        if self.sharded is not None:
            raise ValueError(
                "hot/warm tiering is not supported on a sharded bank")
        kwargs = {} if clock is None else {"clock": clock}
        self.tiers = TierManager(self.vindex, policy=policy, **kwargs)
        return self.tiers

    # -- write path: async batched ingestion -------------------------------
    def enqueue(self, namespace: str, session_id: str,
                messages: Sequence[Message],
                conversation_id: Optional[str] = None) -> None:
        """Cheap: no extraction, no embedding — just queue the session.
        `conversation_id` defaults to the namespace (the service's shape);
        a single-tenant wrapper may scope several conversations under one
        namespace by passing it explicitly."""
        self._pending.append(PendingSession(
            namespace=namespace,
            conversation_id=conversation_id if conversation_id is not None
            else namespace,
            session_id=session_id, messages=list(messages)))

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    def flush(self) -> List[Tuple[str, List[Triple], Summary]]:
        """Drain every pending session across all tenants: extraction runs
        per session, but all new triples go through ONE `embed_texts` call,
        ONE bank append and ONE BM25 append.  Returns per-session
        (namespace, triples, summary) in enqueue order.

        All-or-nothing: extraction, embedding (the phases running
        caller-supplied code) and the WAL append touch no store state — if
        any of them raises, the queue is restored intact and nothing is
        committed (no orphaned summaries, no partial batch, no WAL record
        for an unapplied flush... and no applied flush without its WAL
        record, since the sink runs first).  The commit phase only mutates
        the store's own structures."""
        if not self._pending:
            return []
        tel = get_telemetry()
        t_flush = time.perf_counter()
        pending, self._pending = self._pending, []
        with tel.span("store.flush", sessions=len(pending)):
            try:
                batch = []                   # (session, triples, summary)
                for p in pending:
                    triples, summary = self.extractor.extract(
                        p.conversation_id, p.session_id, p.messages)
                    batch.append((p, triples, summary))
                if self.sharded is not None:
                    # pin namespace ids in ENQUEUE order before grouping —
                    # replay sees sessions grouped by shard, so the record
                    # must carry the live assignment or recovered ids would
                    # drift
                    for p, _, _ in batch:
                        self._ns_ids.setdefault(p.namespace,
                                                len(self._ns_ids))
                    # stable sort: shard-contiguous parts, enqueue order
                    # within
                    batch = sorted(
                        batch, key=lambda b:
                        self._ns_ids[b[0].namespace] % self.shards)
                flat = [tr for _, triples, _ in batch for tr in triples]
                vecs = self.embedder.embed_texts(            # ONE embed call
                    [tr.text() for tr in flat]) if flat else None
                sessions = [(p.namespace, summary, triples)
                            for p, triples, summary in batch]
                if self.wal_sink is not None:  # durability point: WAL first
                    self.wal_sink(self._sharded_flush_record(sessions, vecs)
                                  if self.sharded is not None
                                  else self._flush_record(sessions, vecs))
            except BaseException:
                # restore the queue (ahead of anything enqueued
                # concurrently)
                self._pending = pending + self._pending
                raise
            self._apply_flush(sessions, vecs)
            if self.on_flush_commit is not None:
                self.on_flush_commit(len(batch))
        tel.observe(FLUSH_LATENCY, time.perf_counter() - t_flush,
                    help="flush latency (extract + embed + WAL + commit)")
        return [(p.namespace, triples, summary)
                for p, triples, summary in batch]

    def _apply_flush(self, sessions, vecs) -> None:
        """Commit one flush batch: `sessions` is [(namespace, Summary,
        [Triple, ...]), ...] and `vecs` the (M, dim) f32 embeddings of the
        flattened triples in order.  The ONLY code path that writes rows —
        live flushes and WAL replay both land here, which is what makes
        replayed state bit-identical to the original commit."""
        for ns, summary, _ in sessions:
            t = self.tenant(ns)
            t.summaries.add(summary)
            if self.tiers is not None:
                self.tiers.note_record(t.ns_id)
        flat = [(ns, tr) for ns, _, triples in sessions for tr in triples]
        if not flat:
            return
        tenants = [self.tenant(ns) for ns, _ in flat]
        rows = self.vindex.add(                              # ONE bank append
            vecs, ns=[t.ns_id for t in tenants])
        bids = self.bm25.add([tr.text() for _, tr in flat],
                             namespace=[t.ns_id for t in tenants])
        for t, (_, tr), row, bid in zip(tenants, flat, rows, bids):
            if not (int(row) == int(bid) == len(self._row_tid)):
                raise StoreInvariantError(
                    f"write-path alignment drift: bank row {int(row)}, "
                    f"BM25 doc {int(bid)}, row table size "
                    f"{len(self._row_tid)} must all be equal")
            tid = t.triples.add(tr)
            t.rows.append(int(row))
            self._row_tid.append(tid)
        # grow the entity graph in step: one ingest per session (temporal
        # edges follow each session's extraction order), one device sync for
        # the whole batch.  Replay lands here too — graph state is a
        # deterministic function of the flush records.
        cursor = 0
        try:
            for ns, _, triples in sessions:
                if triples:
                    self.graph.ingest_session(
                        self.tenant(ns).ns_id, triples,
                        [int(r) for r in rows[cursor: cursor + len(triples)]])
                cursor += len(triples)
        except GraphInvariantError as e:
            raise StoreInvariantError(str(e)) from e
        self.graph.sync_device()
        if self.graph.n_rows != len(self._row_tid):
            raise StoreInvariantError(
                f"graph row-incidence lanes ({self.graph.n_rows}) out of "
                f"sync with the row tables ({len(self._row_tid)})")
        if self.sharded is not None:     # mirror into the shard layout
            self.sharded.append(rows, np.asarray(vecs, np.float32),
                                [t.ns_id for t in tenants])

    # -- incremental persistence (WAL records) ------------------------------
    def _flush_record(self, sessions, vecs) -> dict:
        """Self-describing WAL record of one flush batch.  Everything a
        replay cannot recompute rides along: the extracted sessions (the
        extractor may be an LLM) and the raw embedding vectors (the
        embedder may be one too).  BM25 doc rows are NOT logged — they are
        a deterministic function of triple text and the tokenizer."""
        n_rows = sum(len(triples) for _, _, triples in sessions)
        return {
            "op": "flush",
            "sessions": [{
                "namespace": ns,
                "summary": dataclasses.asdict(summary),
                "triples": [dataclasses.asdict(tr) for tr in triples],
            } for ns, summary, triples in sessions],
            "n_rows": n_rows,
            "dim": self.dim,
            "vecs": (np.asarray(vecs, "<f4").tobytes()
                     if n_rows else b""),
        }

    def _sharded_flush_record(self, sessions, vecs) -> dict:
        """Sharded flush record: the (shard-grouped) sessions split into
        per-shard parts — each part a plain flush record of that shard's
        contiguous session run — plus the namespace-id table.  The WAL
        layer (`checkpoint/replication.ShardedWal`) lands each part in its
        shard's own log and journals one cross-shard commit record; the
        ns_ids table rides along because ids were assigned in enqueue
        order, which the grouped parts alone cannot reconstruct."""
        parts = []
        cursor = 0
        by_shard: Dict[int, list] = {}
        for ns, summary, triples in sessions:
            s = self._ns_ids[ns] % self.shards
            by_shard.setdefault(s, []).append((ns, summary, triples))
        for s in sorted(by_shard):       # ascending shard == grouped order
            group = by_shard[s]
            cnt = sum(len(triples) for _, _, triples in group)
            part_vecs = (np.asarray(vecs, np.float32)[cursor: cursor + cnt]
                         if cnt else None)
            cursor += cnt
            parts.append([s, self._flush_record(group, part_vecs)])
        return {"op": "sharded_flush",
                "ns_ids": {ns: int(i) for ns, i in self._ns_ids.items()},
                "parts": parts}

    def _apply_flush_record(self, record: dict) -> None:
        sessions = [
            (s["namespace"], Summary(**s["summary"]),
             [Triple(**td) for td in s["triples"]])
            for s in record["sessions"]]
        n, dim = int(record["n_rows"]), int(record["dim"])
        if dim != self.dim:
            raise StoreInvariantError(
                f"WAL flush record dim {dim} != store dim {self.dim}")
        vecs = (np.frombuffer(record["vecs"], "<f4").reshape(n, dim)
                if n else None)
        self._apply_flush(sessions, vecs)

    def apply_wal(self, record: dict) -> None:
        """Replay one WAL record through the same commit code the live
        mutation used.  Only valid on a store whose `wal_sink` is detached
        (replay must not append to the log it is reading)."""
        if self.wal_sink is not None:
            raise StoreInvariantError(
                "apply_wal with an attached wal_sink would re-log the "
                "records being replayed")
        op = record["op"]
        if op == "flush":
            self._apply_flush_record(record)
        elif op == "sharded_flush":
            # pin the live run's namespace-id assignment first: ids were
            # handed out in enqueue order, the parts arrive shard-grouped
            for ns, nid in record.get("ns_ids", {}).items():
                got = self._ns_ids.setdefault(str(ns), int(nid))
                if got != int(nid):
                    raise StoreInvariantError(
                        f"replayed namespace id for {ns!r} is {nid}, "
                        f"store already assigned {got}")
            for _shard, part in record["parts"]:
                self._apply_flush_record(part)
        elif op == "graph_edge":
            self._apply_link(record["namespace"], record["subject"],
                             record["object"], record["etype"],
                             float(record["weight"]))
        elif op == "evict_ns":
            self.evict_namespace(record["namespace"])
        elif op == "evict_superseded":
            self.evict_superseded(record["namespace"])
        elif op == "compact":
            self.compact()
        else:
            raise StoreInvariantError(f"unknown WAL record op {op!r}")

    def ingest(self, namespace: str, session_id: str,
               messages: Sequence[Message],
               conversation_id: Optional[str] = None
               ) -> Tuple[List[Triple], Summary]:
        """Synchronous write: enqueue + flush (drains anything else pending
        too — there is exactly one write path).  Returns this session's
        extraction result."""
        self.enqueue(namespace, session_id, messages,
                     conversation_id=conversation_id)
        _, triples, summary = self.flush()[-1]
        return triples, summary

    # -- explicit graph edges ----------------------------------------------
    def link(self, namespace: str, subject: str, obj: str,
             etype: str = "entity", weight: float = 1.0) -> None:
        """Upsert one explicit graph edge between two entities of a tenant
        (both directions; entities intern through the same normalization as
        extraction, so linking "Caroline" reaches the node her triples
        built).  Durable: a `graph_edge` WAL record lands before the apply,
        and replay goes through the same `_apply_link`."""
        if etype not in EDGE_TYPE_IDS:
            raise ValueError(
                f"unknown edge type {etype!r}; expected one of "
                f"{sorted(EDGE_TYPE_IDS)}")
        if self.wal_sink is not None:    # durability point: WAL first
            self.wal_sink({"op": "graph_edge", "namespace": namespace,
                           "subject": subject, "object": obj,
                           "etype": etype, "weight": float(weight)})
        self._apply_link(namespace, subject, obj, etype, float(weight))

    def _apply_link(self, namespace: str, subject: str, obj: str,
                    etype: str, weight: float) -> None:
        ns_id = self.tenant(namespace).ns_id
        src = self.graph.intern(ns_id, subject)
        dst = self.graph.intern(ns_id, obj)
        self.graph.link_nodes(src, dst, EDGE_TYPE_IDS[etype], weight)
        self.graph.sync_device()

    # -- eviction ----------------------------------------------------------
    def evict_namespace(self, namespace: str) -> int:
        """Drop a whole tenant: tombstone its bank rows + BM25 docs, free
        its stores.  Returns the number of rows evicted."""
        self._pending = [p for p in self._pending
                         if p.namespace != namespace]
        if namespace not in self._tenants:
            return 0
        if self.wal_sink is not None:    # deterministic given store state
            self.wal_sink({"op": "evict_ns", "namespace": namespace})
        t = self._tenants.pop(namespace)
        live = [row for tid, row in enumerate(t.rows)
                if tid not in t.evicted and row >= 0]
        self.vindex.delete(live)
        self.bm25.remove(live)
        if self.sharded is not None:
            self.sharded.delete(live)
        return len(live)

    def evict_superseded(self, namespace: str) -> int:
        """Physically evict triples superseded under conflict resolution
        (triples.latest_for_key keeps the newest version of every
        (subject, predicate) key; the older versions leave the indices)."""
        t = self._tenants.get(namespace)
        if t is None:
            return 0
        fresh = [tid for tid in t.triples.superseded_ids()
                 if tid not in t.evicted]
        if fresh and self.wal_sink is not None:
            self.wal_sink({"op": "evict_superseded", "namespace": namespace})
        rows = [t.rows[tid] for tid in fresh]
        self.vindex.delete([r for r in rows if r >= 0])
        self.bm25.remove([r for r in rows if r >= 0])
        if self.sharded is not None:
            self.sharded.delete([r for r in rows if r >= 0])
        t.evicted.update(fresh)
        return len(fresh)

    # -- compaction --------------------------------------------------------
    def compact(self) -> dict:
        """Rebuild the packed bank dropping tombstoned rows and remap every
        global row id: the row tables, the BM25 corpus and each tenant's
        `rows` list all move together (rows of compacted-away triples become
        -1).  Pending sessions are flushed first so the mapping is total.
        Retrieval results are unchanged (asserted in tests)."""
        self.flush()
        if self.wal_sink is not None:    # deterministic given store state
            self.wal_sink({"op": "compact"})
        before = self.vindex.n
        old_to_new = self.vindex.compact()
        bm_map = self.bm25.compact()
        if not np.array_equal(old_to_new, bm_map):
            raise StoreInvariantError(
                "compaction drift: the vector bank and the BM25 corpus "
                "disagree on which rows are tombstoned")
        keep = old_to_new >= 0
        self._row_tid = [tid for tid, k in zip(self._row_tid, keep) if k]
        for t in self._tenants.values():
            t.rows = [int(old_to_new[r]) if r >= 0 else -1 for r in t.rows]
        try:                             # graph row-incidence moves in step
            self.graph.compact_rows(old_to_new)
        except GraphInvariantError as e:
            raise StoreInvariantError(str(e)) from e
        if self.sharded is not None:     # global row ids moved wholesale
            self.sharded.invalidate()
        return {"rows_before": int(before), "rows_after": int(self.vindex.n),
                "dropped": int(before - self.vindex.n)}

    # -- persistence -------------------------------------------------------
    def snapshot(self, path: str, *, atomic: bool = False,
                 fsync: bool = False) -> int:
        """Serialize the full store state through checkpoint/io.py.
        Pending sessions are flushed first: a snapshot always captures a
        consistent, fully-indexed state.  `atomic`/`fsync` forward to
        `io.save` — the lifecycle runtime's rotation uses both so a crash
        mid-snapshot never clobbers the previous generation (see
        docs/STORAGE.md and docs/OPERATIONS.md).  Returns bytes written."""
        self.flush()
        n = self.vindex.n
        meta = {
            "version": SNAPSHOT_VERSION,
            "dim": self.dim,
            "bm25": {"k1": self.bm25.k1, "b": self.bm25.b,
                     "max_doc_len": self.bm25.max_doc_len},
            "ns_ids": dict(self._ns_ids),
            "tenants": {
                ns: {
                    "ns_id": t.ns_id,
                    "rows": [int(r) for r in t.rows],
                    "evicted": sorted(t.evicted),
                    "triples": [dataclasses.asdict(tr)
                                for tr in t.triples.all()],
                    "summaries": [dataclasses.asdict(s)
                                  for s in t.summaries.all()],
                } for ns, t in self._tenants.items()
            },
            "graph": self.graph.snapshot_meta(),
        }
        blob = np.frombuffer(msgpack.packb(meta, use_bin_type=True),
                             np.uint8)
        arrays = {
            "bank": self.vindex.bank.copy(),
            "bank_alive": self.vindex.alive(),
            "row_ns": self.vindex.row_namespaces(),
            "row_tid": np.asarray(self._row_tid, np.int32),
            "bm25_docs": self.bm25.doc_array(),
            "bm25_lens": self.bm25.len_array(),
            "bm25_ns": self.bm25.ns_array(),
            "bm25_alive": self.bm25.alive_array(),
            **self.graph.snapshot_arrays(),
            "meta": blob,
        }
        if self.graph.n_rows != n:
            raise StoreInvariantError(
                f"snapshot: graph row lanes ({self.graph.n_rows}) out of "
                f"sync with the bank ({n})")
        if arrays["row_ns"].shape != (n,) or arrays["row_tid"].shape != (n,):
            raise StoreInvariantError(
                f"snapshot: row tables ({arrays['row_ns'].shape[0]}) out of "
                f"sync with the bank ({n})")
        return ckpt_io.save(path, arrays, atomic=atomic, fsync=fsync)

    @classmethod
    def restore(cls, path: str, embedder,
                extractor: Optional[Extractor] = None,
                use_kernel: bool = True,
                tokenizer: HashTokenizer | None = None,
                quantize: str = "none", rescore: int = 4,
                shards: int = 1, mesh=None) -> "MemoryStore":
        """Reconstruct a store from `snapshot(path)`.  The result answers
        retrieval bit-identically to the store that wrote the snapshot
        (same bank bytes, same BM25 arrays, same triple/summary text).
        `quantize`/`rescore`/`shards`/`mesh` pick the restored index's
        device residency mode — the snapshot itself is always
        full-precision and placement-agnostic."""
        arrays = ckpt_io.load_raw(path)
        meta = msgpack.unpackb(arrays["meta"].tobytes(), raw=False)
        if meta["version"] != SNAPSHOT_VERSION:
            raise StoreInvariantError(
                f"snapshot version {meta['version']} != {SNAPSHOT_VERSION}")
        store = cls(embedder, extractor, dim=int(meta["dim"]),
                    use_kernel=use_kernel, tokenizer=tokenizer,
                    quantize=quantize, rescore=rescore,
                    shards=shards, mesh=mesh)
        store.vindex.load_rows(arrays["bank"], arrays["bank_alive"],
                               ns=arrays["row_ns"])
        bm = meta["bm25"]
        store.bm25.k1, store.bm25.b = float(bm["k1"]), float(bm["b"])
        store.bm25.max_doc_len = int(bm["max_doc_len"])
        store.bm25.load_rows(arrays["bm25_docs"], arrays["bm25_lens"],
                             arrays["bm25_ns"], arrays["bm25_alive"])
        store._row_tid = [int(x) for x in arrays["row_tid"]]
        store._ns_ids = {str(k): int(v) for k, v in meta["ns_ids"].items()}
        for ns, td in meta["tenants"].items():
            t = TenantState(ns_id=int(td["ns_id"]))
            for trd in td["triples"]:
                t.triples.add(Triple(**trd))
            for sd in td["summaries"]:
                t.summaries.add(Summary(**sd))
            t.rows = [int(r) for r in td["rows"]]
            t.evicted = set(int(i) for i in td["evicted"])
            store._tenants[str(ns)] = t
        try:
            store.graph = MemoryGraph.from_snapshot(arrays, meta["graph"])
        except GraphInvariantError as e:
            raise StoreInvariantError(str(e)) from e
        if len(store._row_tid) != store.vindex.n or \
                store.vindex.n != len(store.bm25) or \
                store.graph.n_rows != store.vindex.n:
            raise StoreInvariantError(
                f"restore: bank ({store.vindex.n}), BM25 "
                f"({len(store.bm25)}), row tables "
                f"({len(store._row_tid)}) and graph lanes "
                f"({store.graph.n_rows}) disagree")
        return store

    # -- sharded retrieval --------------------------------------------------
    def sharded_search(self, queries, q_ns, k: int):
        """Namespace-masked top-k over the shard-major device bank: one
        launch, returns (scores (Q,k) device f32, rows (Q,k) host i32
        global ids).  Rebuilds the shard layout lazily when stale (first
        search, after compaction/restore)."""
        if self.sharded is None:
            raise StoreInvariantError("store was built with shards=1")
        if self.sharded.stale:
            self.sharded.rebuild(self.vindex)
        return self.sharded.search(queries, q_ns, k)

    def shard_of_namespace(self, namespace: str) -> Optional[int]:
        """Which shard owns a namespace's rows (None if unknown tenant or
        unsharded)."""
        if self.sharded is None:
            return None
        t = self._tenants.get(namespace)
        return None if t is None else t.ns_id % self.shards

    def shard_down(self, shard: int) -> None:
        """Take one shard out of retrieval (graceful degradation: surviving
        shards keep answering, the service stamps affected responses
        `degraded`)."""
        if self.sharded is None:
            raise StoreInvariantError("store was built with shards=1")
        self.sharded.mark_down(shard)

    def shard_up(self, shard: int) -> None:
        if self.sharded is None:
            raise StoreInvariantError("store was built with shards=1")
        self.sharded.mark_up(shard)

    def down_shards(self) -> List[int]:
        return sorted(self.sharded.down) if self.sharded is not None else []

    # -- stats -------------------------------------------------------------
    def stats(self) -> dict:
        per_ns = {
            ns: {
                "triples": len(t.triples),
                "summaries": len(t.summaries),
                "evicted": len(t.evicted),
            } for ns, t in self._tenants.items()
        }
        out = {
            "namespaces": len(self._tenants),
            "bank_rows": self.vindex.n,
            "alive_rows": self.vindex.n_alive,
            "tombstones": self.vindex.n_dead,
            "bm25_docs": len(self.bm25),
            "pending": len(self._pending),
            "bank": {
                "quantize": self.vindex.quantize,
                "quantized": self.vindex.quantize != "none",
                "rescore": self.vindex.rescore,
                "hot_rows": self.vindex.n_resident,
                "warm_rows": self.vindex.n_warm,
                "rescore_hit_rate": (
                    self.vindex.counters["rescore_hits"]
                    / self.vindex.counters["rescore_rows"]
                    if self.vindex.counters["rescore_rows"] else None),
                **self.vindex.counters,
            },
            "per_namespace": per_ns,
            # flatten_metrics exports these as memori_graph_* gauges
            "graph": self.graph.stats(),
        }
        if self.tiers is not None:
            out["tiering"] = self.tiers.stats()
        if self.sharded is not None:
            out["shards"] = self.sharded.stats()
        return out
