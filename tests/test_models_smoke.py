"""Mandated per-architecture smoke tests: a REDUCED variant of each assigned
family (≤2 layers, d_model≤512, ≤4 experts) runs one forward/train step on
CPU; output shapes + finiteness asserted."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model_api import Model

KEY = jax.random.PRNGKey(0)


def _reduced(arch):
    cfg = get_config(arch).reduced()
    if cfg.use_moe:
        # drop-free routing for deterministic smoke numbers
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    return cfg


def _batch(cfg, B=2, S=24):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.num_image_tokens:
        batch["images"] = jax.random.normal(KEY, (B, cfg.num_image_tokens, 1152))
    if cfg.is_encoder_decoder:
        batch["audio"] = jax.random.normal(KEY, (B, cfg.encoder_seq_len,
                                                 cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_config_limits(arch):
    cfg = _reduced(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.use_moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch):
    cfg = _reduced(arch)
    model = Model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg)

    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0

    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_logits_shape(arch):
    cfg = _reduced(arch)
    model = Model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg, B=2, S=16)
    logits, caches = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert caches is not None
