"""Residual blocks: (mixer, ffn) pairs assembled from the layer zoo.

A block kind is a (mixer_kind, ffn_kind) tuple from ModelConfig.layer_kinds():
mixer ∈ {attn, ssm, rglru}, ffn ∈ {mlp, moe, none}.  Pre-norm residual wiring,
with stablelm-style parallel residual as a config option, and optional
cross-attention (whisper decoder).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import attention, mla, mlp, moe, norms, rglru, ssm

AUX_KEYS = ("moe_load_balance", "moe_router_z", "moe_drop_fraction")


def zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def block_specs(cfg, kind, *, cross: bool = False):
    mixer_kind, ffn_kind = kind
    s = {"norm1": norms.specs(cfg)}
    if mixer_kind == "attn":
        s["attn"] = mla.specs(cfg) if cfg.use_mla else attention.specs(cfg)
    elif mixer_kind == "ssm":
        s["ssm"] = ssm.specs(cfg)
    elif mixer_kind == "rglru":
        s["rglru"] = rglru.specs(cfg)
    else:
        raise ValueError(mixer_kind)
    if cross:
        s["norm_cross"] = norms.specs(cfg)
        s["cross_attn"] = attention.specs(cfg, cross=True)
    if ffn_kind == "mlp":
        s["norm2"] = norms.specs(cfg)
        s["mlp"] = mlp.specs(cfg)
    elif ffn_kind == "moe":
        s["norm2"] = norms.specs(cfg)
        s["moe"] = moe.specs(cfg)
    return s


def block_cache_specs(cfg, kind, batch, max_len, dtype, *, cross: bool = False,
                      enc_len: int = 0, window: int = 0):
    """Returns {name: (shape, logical_axes, dtype)} for this block's caches."""
    mixer_kind, _ = kind
    out = {}
    if mixer_kind == "attn":
        cs = mla.cache_specs(cfg, batch, max_len, dtype) if cfg.use_mla \
            else attention.cache_specs(cfg, batch, max_len, dtype, window=window)
        out.update(cs)
    elif mixer_kind == "ssm":
        out.update(ssm.cache_specs(cfg, batch, dtype))
    elif mixer_kind == "rglru":
        out.update(rglru.cache_specs(cfg, batch, dtype))
    if cross:
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        out["cross_k"] = ((batch, enc_len, kv, hd), ("batch", None, "kv_heads", "head_dim"), dtype)
        out["cross_v"] = ((batch, enc_len, kv, hd), ("batch", None, "kv_heads", "head_dim"), dtype)
    return out


def apply(params, cfg, x, kind, *, mode, positions, cache=None, cache_pos=None,
          mask_kind="causal", window=0, prefix_len=None, enc_out=None,
          enc_positions=None, rules=None, return_cache=False, use_rope=True):
    """One residual block.  Returns (x, new_cache, aux)."""
    mixer_kind, ffn_kind = kind
    aux = zero_aux()
    new_cache = {}
    h = norms.apply(params["norm1"], cfg, x)

    sub_cache = None
    if cache is not None and mixer_kind == "attn":
        if cfg.use_mla:
            sub_cache = {k: cache[k] for k in ("ckv", "k_rope") if k in cache}
        else:
            sub_cache = {k: cache[k] for k in
                         ("k", "v", "pos", "k_scale", "v_scale") if k in cache}
        sub_cache = sub_cache or None
    elif cache is not None and mixer_kind in ("ssm", "rglru"):
        keys = ("conv", "state") if mixer_kind == "ssm" else ("conv", "h")
        sub_cache = {k: cache[k] for k in keys if k in cache} or None

    if mixer_kind == "attn":
        if cfg.use_mla:
            attn_out, c = mla.apply(
                params["attn"], cfg, h, positions=positions, mode=mode,
                cache=sub_cache, cache_pos=cache_pos, window=window,
                return_cache=return_cache, mask_kind=mask_kind,
                prefix_len=prefix_len)
        else:
            attn_out, c = attention.apply(
                params["attn"], cfg, h, positions=positions, mode=mode,
                cache=sub_cache, cache_pos=cache_pos, mask_kind=mask_kind,
                window=window, prefix_len=prefix_len, use_rope=use_rope,
                return_cache=return_cache)
        if c:
            new_cache.update(c)
        mixed = attn_out
    elif mixer_kind == "ssm":
        mixed, c = ssm.apply(params["ssm"], cfg, h, mode=mode, cache=sub_cache,
                             return_cache=return_cache)
        if c:
            new_cache.update(c)
    else:  # rglru
        mixed, c = rglru.apply(params["rglru"], cfg, h, mode=mode,
                               cache=sub_cache, return_cache=return_cache)
        if c:
            new_cache.update(c)

    if cfg.parallel_residual and ffn_kind == "mlp":
        # stablelm-style: x + attn(n(x)) + mlp(n(x)) with a single norm
        ff = mlp.apply(params["mlp"], cfg, norms.apply(params["norm2"], cfg, x))
        x = x + mixed + ff
    else:
        x = x + mixed
        if enc_out is not None or "cross_attn" in params:
            hc = norms.apply(params["norm_cross"], cfg, x)
            if mode == "decode":
                cross_cache = {"k": cache["cross_k"], "v": cache["cross_v"]}
                cross_out, _ = attention.apply(
                    params["cross_attn"], cfg, hc, positions=positions,
                    mode="cross_decode", cache=cross_cache, use_rope=False)
                new_cache["cross_k"] = cache["cross_k"]
                new_cache["cross_v"] = cache["cross_v"]
            else:
                cross_out, cc = attention.apply(
                    params["cross_attn"], cfg, hc, positions=positions,
                    kv_x=enc_out, kv_positions=enc_positions, mode=mode,
                    use_rope=False, return_cache=return_cache)
                if cc:
                    new_cache["cross_k"] = cc["k"]
                    new_cache["cross_v"] = cc["v"]
            x = x + cross_out
        if ffn_kind == "mlp":
            h2 = norms.apply(params["norm2"], cfg, x)
            x = x + mlp.apply(params["mlp"], cfg, h2)
        elif ffn_kind == "moe":
            h2 = norms.apply(params["norm2"], cfg, x)
            y, aux = moe.apply(params["moe"], cfg, h2, rules=rules)
            x = x + y

    return x, (new_cache if new_cache else None), aux
