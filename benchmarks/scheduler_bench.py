"""Cross-client micro-batching scheduler benchmark.

Closed-loop multi-client load: C client threads each issue ONE retrieve at
a time, as fast as the service answers — the real deployment traffic shape
(SDK clients, server handlers, concurrent agents), which the positional
`retrieve_batch` API could never batch.  Two paths over the same data:

* **direct** — each call runs the full per-request pipeline alone (one
  embed, one masked search, one BM25 op, one fusion per CALL);
* **scheduled** — a mounted MemoryScheduler collects the concurrent
  clients' requests inside its micro-batch window and answers each tick
  with ONE batched launch per stage.

Reports throughput (requests/s) and per-request latency (p50/p99) for
each client count, plus the scheduled-vs-direct speedup.  The acceptance
bar from the PR: >= 2x throughput at 8 concurrent clients on CPU
(`--assert-speedup 2.0` enforces it in CI).

    PYTHONPATH=src python benchmarks/scheduler_bench.py \
        [--clients 1,2,4,8] [--seconds 2] [--tenants 8] \
        [--json BENCH_scheduler.json] [--assert-speedup 2.0]
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core import MemoryScheduler, MemoryService, Message
from repro.core.embedder import HashEmbedder

CITIES = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi", "Windhoek",
          "Sapporo"]
QUERIES = ["Which city does the user live in?",
           "What pet was adopted?",
           "What is the user's job?"]


def _build_service(tenants: int, sessions: int) -> MemoryService:
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800)
    for u in range(tenants):
        for s in range(sessions):
            svc.record(f"u{u}/c0", f"s{s}", [
                Message("U", f"I live in {CITIES[(u + s) % len(CITIES)]}.",
                        1700000000.0 + s),
                Message("U", f"I adopted a pet named P{u}_{s}.",
                        1700000000.0 + s),
                Message("U", "I work as a welder.", 1700000000.0 + s)])
    return svc


def _closed_loop(svc: MemoryService, clients: int, seconds: float) -> dict:
    """Each client thread retrieves in a closed loop for `seconds`;
    whether the call batches across clients is decided by whether a
    scheduler is mounted on `svc` (the client code is identical)."""
    lat: list[list[float]] = [[] for _ in range(clients)]
    stop = time.perf_counter() + seconds
    barrier = threading.Barrier(clients)

    def client(c: int) -> None:
        ns = f"u{c % len(svc.namespaces())}/c0"
        barrier.wait()
        i = 0
        while time.perf_counter() < stop:
            t0 = time.perf_counter()
            svc.retrieve(ns, QUERIES[i % len(QUERIES)])
            lat[c].append(time.perf_counter() - t0)
            i += 1

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    flat = np.asarray([x for per in lat for x in per])
    return {
        "requests": int(flat.size),
        "throughput_rps": float(flat.size / wall),
        "p50_ms": float(np.percentile(flat, 50) * 1e3),
        "p99_ms": float(np.percentile(flat, 99) * 1e3),
    }


def run(clients=(1, 2, 4, 8), seconds: float = 2.0, tenants: int = 8,
        sessions: int = 2, tick_interval: float = 0.002,
        max_batch: int = 64, json_path=None,
        assert_speedup=None) -> dict:
    svc = _build_service(tenants, sessions)
    # warm every executable both paths touch (search buckets up to the
    # pow2 ceiling of the largest client count)
    for n in (1, 2, 4, 8, 16):
        if n <= max(clients) * 2:
            svc.retrieve_batch([(f"u{i % tenants}/c0", QUERIES[0])
                                for i in range(n)])
    print(f"# Scheduler bench: {tenants} tenants, "
          f"{svc.stats()['bank_rows']} bank rows, {seconds:.1f}s per point, "
          f"tick={tick_interval * 1e3:.1f}ms, max_batch={max_batch}")
    report = {"tenants": tenants, "seconds": seconds,
              "tick_interval_s": tick_interval, "max_batch": max_batch,
              "points": []}
    for c in clients:
        direct = _closed_loop(svc, c, seconds)
        sched = MemoryScheduler(svc, tick_interval_s=tick_interval,
                                max_batch=max_batch)
        try:
            scheduled = _closed_loop(svc, c, seconds)
            st = sched.stats()
        finally:
            sched.close()
        speedup = scheduled["throughput_rps"] / direct["throughput_rps"]
        point = {"clients": c, "direct": direct, "scheduled": scheduled,
                 "speedup": speedup,
                 "avg_batch": st.get("avg_retrieves_per_launch")}
        report["points"].append(point)
        print(f"clients {c:2d}: direct {direct['throughput_rps']:7.1f} rps "
              f"(p50 {direct['p50_ms']:.1f}ms p99 {direct['p99_ms']:.1f}ms)"
              f" | scheduled {scheduled['throughput_rps']:7.1f} rps "
              f"(p50 {scheduled['p50_ms']:.1f}ms p99 "
              f"{scheduled['p99_ms']:.1f}ms) | {speedup:.2f}x, "
              f"avg batch {point['avg_batch']:.1f}")
    top = report["points"][-1]
    report["speedup_at_max_clients"] = top["speedup"]
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    if assert_speedup is not None and top["speedup"] < assert_speedup:
        raise AssertionError(
            f"scheduled path is only {top['speedup']:.2f}x the direct path "
            f"at {top['clients']} clients (needed {assert_speedup:.2f}x)")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", default="1,2,4,8",
                    help="comma-separated client counts")
    ap.add_argument("--seconds", type=float, default=2.0)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=2)
    ap.add_argument("--tick-interval", type=float, default=0.002)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_scheduler.json artifact")
    ap.add_argument("--assert-speedup", type=float, default=None,
                    help="fail unless scheduled >= this x direct at the "
                         "largest client count")
    args = ap.parse_args()
    run(clients=tuple(int(x) for x in args.clients.split(",")),
        seconds=args.seconds, tenants=args.tenants, sessions=args.sessions,
        tick_interval=args.tick_interval, max_batch=args.max_batch,
        json_path=args.json, assert_speedup=args.assert_speedup)
