"""Continuous-batching scheduler: admits queued requests into free engine
slots between decode steps, runs until the queue drains."""
from __future__ import annotations

from collections import deque
from typing import Dict, List

from repro.serving.engine import Engine
from repro.serving.requests import Request, Response


class ContinuousBatcher:
    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: deque[Request] = deque()
        self.finished: Dict[int, Response] = {}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, requests: List[Request] | None = None,
            max_steps: int = 100_000) -> Dict[int, Response]:
        for r in requests or []:
            self.submit(r)
        steps = 0
        while (self.queue or self.engine.slot_active.any()) and steps < max_steps:
            # admit as many queued requests as there are free slots
            while self.queue and self.engine.has_free_slot:
                self.engine.admit(self.queue.popleft())
            for resp in self.engine.step():
                self.finished[resp.request_id] = resp
            steps += 1
        return self.finished

    def utilization(self) -> float:
        st = self.engine.stats
        if st["decode_steps"] == 0:
            return 0.0
        return st["tokens_out"] / (st["decode_steps"] * self.engine.slots)
