# The paper's primary contribution: the Memori persistent memory layer —
# Advanced Augmentation (triples + summaries), hybrid retrieval over the
# sharded vector index + hashed BM25, token budgeting, and the SDK wrapper.
from repro.core.admission import (PRIORITY_HIGH, PRIORITY_LOW,  # noqa: F401
                                  PRIORITY_NORMAL, AdmissionController,
                                  AdmissionError, AdmissionPolicy,
                                  TenantPolicy, admission_policy_from_json,
                                  tenant_policy_from_json)
from repro.core.api import (CompactRequest, EvictRequest,  # noqa: F401
                            MemoryRequest, MemoryResponse, RawRetrieval,
                            RecordRequest, RetrievalPlan, RetrieveRequest)
from repro.core.augmentation import AdvancedAugmentation  # noqa: F401
from repro.core.extraction import LMExtractor, Message, RuleExtractor  # noqa: F401
from repro.core.graph import MemoryGraph  # noqa: F401
from repro.core.lifecycle import (BackpressureError, LifecyclePolicy,  # noqa: F401
                                  LifecycleRuntime)
from repro.core.memory import ANSWER_PROMPT, MemoriMemory, RetrievedContext  # noqa: F401
from repro.core.scheduler import MemoryScheduler  # noqa: F401
from repro.core.sdk import HttpMemory, MemoriClient, RetryPolicy  # noqa: F401
from repro.core.service import MemoryService, NamespaceView  # noqa: F401
from repro.core.shards import ShardedBank  # noqa: F401
from repro.core.store import (MemoryStore, StoreInvariantError,  # noqa: F401
                              TenantState)
from repro.core.summaries import Summary, SummaryStore  # noqa: F401
from repro.core.tiering import TierManager, TierPolicy  # noqa: F401
from repro.core.triples import Triple, TripleStore  # noqa: F401
