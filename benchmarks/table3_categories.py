"""Paper Table 3 analogue: question-category distribution of the benchmark."""
from __future__ import annotations

import collections
import time

from repro.data.locomo_synth import CATEGORIES, LOCOMO_WEIGHTS, generate_conversation


def run(csv_rows):
    print("\n# Table 3 — question category distribution")
    t0 = time.time()
    counts = collections.Counter()
    for seed in range(4):
        conv = generate_conversation(seed=seed, n_sessions=6, noise_turns=20)
        counts.update(q.category for q in conv.questions)
    us = (time.time() - t0) * 1e6 / 4
    print(f"{'category':14s} {'synthetic n':>11s} {'LoCoMo n':>9s}")
    for c in CATEGORIES:
        print(f"{c:14s} {counts[c]:11d} {LOCOMO_WEIGHTS[c]:9d}")
    csv_rows.append(("table3/categories", us, sum(counts.values())))
    return csv_rows


if __name__ == "__main__":
    run([])
