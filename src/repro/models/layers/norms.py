"""RMSNorm / LayerNorm (param specs + apply)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.common.module import ParamSpec


def specs(cfg, dim: int | None = None):
    d = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), ("embed",), init="ones"),
                "bias": ParamSpec((d,), ("embed",), init="zeros")}
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def apply(params, cfg, x):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = xf.mean(-1, keepdims=True)
        var = ((xf - mean) ** 2).mean(-1, keepdims=True)
        y = (xf - mean) / jnp.sqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf / jnp.sqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return ((xf / jnp.sqrt(var + eps)) * scale.astype(jnp.float32)).astype(x.dtype)
