"""Production serving launcher: pjit'd prefill + decode on a real mesh, with
the Memori memory layer in front.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b [--multipod]
    PYTHONPATH=src python -m repro.launch.serve --host-demo
    PYTHONPATH=src python -m repro.launch.serve --host-demo \
        --snapshot-path /tmp/memori.snap --flush-interval 8

`--snapshot-path` makes the memory layer durable: the service restores from
the snapshot on boot (a restarted server answers identically to the one
that wrote it) and writes a fresh snapshot on shutdown.  `--flush-interval`
switches ingestion to the async batched path: sessions are enqueued and
flushed through one embed call per N pending sessions.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="memori-agent")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--host-demo", action="store_true")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--snapshot-path", default=None,
                    help="restore the memory store from this snapshot on "
                         "boot (if it exists) and write it back on shutdown")
    ap.add_argument("--flush-interval", type=int, default=None,
                    help="auto-flush pending sessions once this many are "
                         "queued (async batched ingestion); default: "
                         "synchronous record")
    args = ap.parse_args()

    if args.host_demo:
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                                   + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.configs import get_config
    from repro.core import MemoriClient, MemoryService
    from repro.core.embedder import HashEmbedder
    from repro.data.tokenizer import HashTokenizer
    from repro.models.model_api import Model
    from repro.serving.engine import Engine
    from repro.serving.sampler import SamplerConfig

    cfg = get_config(args.arch)
    if args.host_demo:
        cfg = cfg.reduced(layers=2, d_model=128)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashTokenizer(cfg.vocab_size)
    engine = Engine(model, params, max_len=args.max_len, slots=2,
                    sampler=SamplerConfig(temperature=0.8, top_k=40),
                    tokenizer=tok)
    # one multi-tenant service fronts every conversation on this host;
    # with --snapshot-path it picks up exactly where the last run stopped
    if args.snapshot_path and os.path.exists(args.snapshot_path):
        service = MemoryService.restore(
            args.snapshot_path, HashEmbedder(), use_kernel=False,
            budget=800, flush_every=args.flush_interval)
        print(f"restored memory store from {args.snapshot_path}: "
              f"{service.stats()}")
    else:
        service = MemoryService(HashEmbedder(), budget=800, use_kernel=False,
                                flush_every=args.flush_interval)
    llm = lambda p: engine.generate([p[-500:]], max_new_tokens=12)[0]  # noqa: E731
    client = MemoriClient(llm, service.namespace("u0/demo"))

    print(client.chat("I work as a translator and I live in Cusco."))
    client.end_session()
    [ctx] = service.retrieve_batch([("u0/demo", "Where does the user live?")])
    print(f"retrieved {len(ctx.triples)} triples, {ctx.token_count} tokens")
    print("service:", service.stats())
    print("engine:", engine.stats)
    if args.snapshot_path:
        n = service.snapshot(args.snapshot_path)
        print(f"snapshot: wrote {n} bytes -> {args.snapshot_path}")


if __name__ == "__main__":
    main()
