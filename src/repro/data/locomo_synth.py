"""Synthetic LoCoMo-like benchmark (Maharana et al. 2024 analogue).

The real LoCoMo dataset + GPT-4.1-mini judge are unavailable offline, so this
module generates multi-session two-speaker conversations with *planted facts*
and questions in the paper's four reasoning categories (single-hop,
multi-hop, temporal, open-domain), sized so a full conversation ≈ 26k tokens
(the paper's Table 2 full-context figure).

Evaluation uses a deterministic ORACLE READER: it answers correctly iff the
supporting facts are surfaced in the retrieved context (the paper: accuracy
"serves as a direct reflection of how well the Advanced Augmentation pipeline
structured, preserved, and surfaced the relevant facts") — plus a documented
context-rot model (Hong et al. 2025): the probability of a reader slip grows
with injected-context size, which is what makes the full-context ceiling an
imperfect 100% in the paper.  All randomness is hash-derived → exactly
reproducible.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Sequence, Tuple

from repro.common.utils import stable_hash
from repro.core.extraction import Message

DAY = 86400.0
BASE_TS = 1672531200.0          # 2023-01-01

NAMES = ["Caroline", "Melanie", "Gordon", "Adam", "Luiz", "Joanna", "Nate",
         "Audrey", "Marcus", "Priya", "Tomas", "Elena"]

FOODS = ["sushi", "lasagna", "pad thai", "falafel", "ramen", "tacos",
         "paella", "pierogi", "biryani", "gumbo"]
COLORS = ["teal", "crimson", "ochre", "indigo", "sage green", "burgundy"]
HOBBIES = ["rock climbing", "watercolor painting", "birdwatching", "chess",
           "pottery", "salsa dancing", "archery", "kayaking", "origami",
           "stargazing", "fencing", "baking sourdough"]
JOBS = ["teacher", "nurse", "architect", "data analyst", "chef",
        "electrician", "librarian", "paramedic", "translator", "botanist"]
CITIES = ["Lisbon", "Osaka", "Tallinn", "Valparaiso", "Galway", "Tbilisi",
          "Ljubljana", "Cusco", "Windhoek", "Da Nang"]
PETS = ["puppy", "kitten", "parrot", "hedgehog", "gecko", "rabbit"]
PET_NAMES = ["Max", "Luna", "Mochi", "Biscuit", "Nimbus", "Pepper"]
ITEMS = ["telescope", "espresso machine", "mountain bike", "record player",
         "sewing machine", "drone", "typewriter", "kayak"]
PLACES = ["Iceland", "Morocco", "Patagonia", "Kyoto", "the Azores",
          "Yellowstone", "Sicily", "Jordan"]
SKILLS = ["Portuguese", "the cello", "woodworking", "beekeeping",
          "sign language", "calligraphy"]

# vocab for the opt-in graph-chain categories (generate_conversation(...,
# graph_chains=True)) — deliberately disjoint from FOODS/PLACES/CITIES/
# HOBBIES/SKILLS so a chain answer can never be reached by lexical overlap
# with the question's own words
ALLERGENS = ["peanuts", "strawberries", "shellfish", "gluten", "dairy",
             "kiwi"]
TRIPS = ["Banff", "Cappadocia", "Big Sur", "Mount Fuji", "Svalbard",
         "Zanzibar", "Bariloche", "Hokkaido"]
ACTIVITIES = ["aikido", "glassblowing", "bouldering", "ceramics", "parkour",
              "tango"]

NOISE = [
    "How have you been lately?",
    "The weather here has been so strange this week.",
    "Did you watch anything good recently?",
    "Work has been keeping me pretty busy.",
    "I can't believe how fast this year is going.",
    "We should catch up more often, honestly.",
    "My commute was a nightmare this morning.",
    "I finally cleaned out the garage this weekend.",
    "Have you talked to the others recently?",
    "I've been sleeping terribly, probably too much coffee.",
    "That reminds me of something funny that happened.",
    "Anyway, enough about that.",
    "The neighbors are renovating again, the noise is constant.",
    "I tried that new cafe downtown, it was alright.",
    "My phone battery dies so fast these days.",
    "I keep meaning to go to the gym and never do.",
    "The traffic around the stadium was unbelievable.",
    "I reorganized my bookshelf by color, very satisfying.",
]

MONTHS = ["January", "February", "March", "April", "May", "June", "July",
          "August", "September", "October", "November", "December"]


@dataclasses.dataclass
class Question:
    qid: str
    category: str                 # single_hop | multi_hop | temporal | open_domain
    question: str
    answer: str
    # each support is a list of strings that must co-occur on one context line
    supports: List[List[str]]
    min_supports: int = -1        # -1 => all required


@dataclasses.dataclass
class Conversation:
    conversation_id: str
    speakers: Tuple[str, str]
    sessions: List[Tuple[str, List[Message]]]      # (session_id, messages)
    questions: List[Question]

    def all_messages(self) -> List[Message]:
        return [m for _, msgs in self.sessions for m in msgs]


def _month_year(ts: float) -> str:
    import time as _t
    tm = _t.gmtime(ts)
    return f"{MONTHS[tm.tm_mon - 1]} {tm.tm_year}"


def _ym(ts: float) -> str:
    import time as _t
    tm = _t.gmtime(ts)
    return f"{tm.tm_year}-{tm.tm_mon:02d}"


def generate_conversation(seed: int = 0, n_sessions: int = 12,
                          noise_turns: int = 165,
                          name_pair=None,
                          graph_chains: bool = False) -> Conversation:
    """Defaults are sized so a full conversation ≈ 26k tokens — the paper's
    Table-2 full-context figure (26,031 tokens).  `name_pair` pins the two
    speakers (multi-conversation stores need disjoint speaker names).

    `graph_chains=True` additionally plants facts whose questions are
    answerable only through the memory graph (GRAPH_CATEGORIES:
    `multi_hop_graph` ≥2-hop entity chains, `temporal_graph` succession
    within a session) — the graph-stage scoreboard (benchmarks/
    graph_bench.py).  Off by default, and the disabled path consumes zero
    extra randomness, so default conversations are byte-identical to
    pre-graph ones."""
    rng = random.Random(seed)
    a, b = name_pair if name_pair else rng.sample(NAMES, 2)
    conv_id = f"conv{seed}"

    # --- plan facts ---------------------------------------------------------
    facts: Dict[str, Dict[str, object]] = {}
    for sp in (a, b):
        facts[sp] = {
            "food": rng.choice(FOODS),
            "color": rng.choice(COLORS),
            "hobbies": rng.sample(HOBBIES, 3),
            "job0": rng.choice(JOBS),
            "city": rng.choice(CITIES),
            "pet": rng.choice(PETS),
            "pet_name": rng.choice(PET_NAMES),
            "item": rng.choice(ITEMS),
            "place": rng.choice(PLACES),
            "skill": rng.choice(SKILLS),
        }
    # make the two speakers' jobs distinct so multi-hop identification works
    facts[b]["job0"] = rng.choice([j for j in JOBS if j != facts[a]["job0"]])
    job1 = {sp: rng.choice([j for j in JOBS
                            if j not in (facts[a]["job0"], facts[b]["job0"])])
            for sp in (a, b)}

    # --- schedule fact reveals over sessions --------------------------------
    reveals: Dict[int, List[Tuple[str, str]]] = {i: [] for i in range(n_sessions)}

    def put(sess, sp, text):
        reveals[sess].append((sp, text))

    sess_of: Dict[str, int] = {}
    for sp in (a, b):
        f = facts[sp]
        order = list(range(n_sessions))
        rng.shuffle(order)
        # cycle if there are more facts than sessions (small smoke configs)
        it = iter(order * 8)
        def nxt(tag):
            s = next(it)
            sess_of[f"{sp}:{tag}"] = s
            return s
        put(nxt("food"), sp, f"My favorite food is {f['food']}.")
        put(nxt("color"), sp, f"My favorite color is {f['color']}.")
        for i, h in enumerate(f["hobbies"]):
            put(nxt(f"hobby{i}"), sp, rng.choice(
                [f"I really love {h}.", f"I like {h}."]))
        put(nxt("job0"), sp, f"I work as a {f['job0']}.")
        put(nxt("city"), sp, f"I live in {f['city']}.")
        put(nxt("pet"), sp, f"I adopted a {f['pet']} named {f['pet_name']}.")
        put(nxt("item"), sp, f"I bought a {f['item']} last week.")
        put(nxt("place"), sp, f"I went to {f['place']}.")
        put(nxt("skill"), sp, f"I am learning {f['skill']}.")
        # temporal change: job switch in a later session than job0
        s_change = sess_of[f"{sp}:job0"]
        later = [s for s in range(n_sessions) if s > s_change]
        s_new = rng.choice(later) if later else n_sessions - 1
        sess_of[f"{sp}:job1"] = s_new
        put(s_new, sp,
            f"I used to work as a {f['job0']}, but now I am a {job1[sp]}.")

    # --- graph-chain facts (opt-in) -----------------------------------------
    # chain A (entity, 2-hop): pet -> pet_name -> allergen; the question
    # names the pet species, never the pet's name or the allergen.
    # chain B (causal, version chain): job0 -> job1 via the "works as"
    # supersession; the question names only the former job.
    # chain C (temporal, succession): trip -> activity planted as ONE
    # message (two clauses), so extraction order — and the temporal edge —
    # survives the turn shuffle; the question names only the trip.
    chains: List[Tuple[str, str, str, str]] = []
    if graph_chains:
        al2 = rng.sample(ALLERGENS, 2)
        trip2 = rng.sample(TRIPS, 2)
        act2 = rng.sample(ACTIVITIES, 2)
        for sp, al, trip, act in zip((a, b), al2, trip2, act2):
            chains.append((sp, al, trip, act))
            put(rng.randrange(n_sessions), sp,
                f"{facts[sp]['pet_name']} is allergic to {al}.")
            put(rng.randrange(n_sessions), sp,
                f"I went to {trip}. I started {act} classes.")

    # --- build sessions -------------------------------------------------------
    sessions: List[Tuple[str, List[Message]]] = []
    for s in range(n_sessions):
        ts = BASE_TS + s * 7 * DAY
        msgs: List[Message] = []
        turns: List[Tuple[str, str]] = []
        for sp, text in reveals[s]:
            turns.append((sp, text))
        for _ in range(noise_turns):
            turns.append((rng.choice((a, b)), rng.choice(NOISE)))
        rng.shuffle(turns)
        # prepend greetings for realism
        turns = [(a, f"Hey {b}!"), (b, f"Hi {a}, good to hear from you.")] + turns
        msgs = [Message(sp, tx, ts) for sp, tx in turns]
        sessions.append((f"s{s}", msgs))

    # --- questions -------------------------------------------------------------
    qs: List[Question] = []
    qn = 0

    def add(category, question, answer, supports, min_supports=-1):
        nonlocal qn
        qs.append(Question(f"{conv_id}-q{qn}", category, question, answer,
                           supports, min_supports))
        qn += 1

    # Question phrasing mixes exact wording (favors lexical/BM25 retrieval)
    # with paraphrases (favor the semantic/dense path) — the complementarity
    # the paper's hybrid search exploits.  `rng` choices keep it reproducible.
    for sp in (a, b):
        f = facts[sp]
        # single-hop (the dominant category, as in LoCoMo Table 3)
        add("single_hop", rng.choice([
            f"What is {sp}'s favorite food?",
            f"Which dish does {sp} enjoy the most?"]), f["food"],
            [[sp, f["food"]]])
        add("single_hop", rng.choice([
            f"What is {sp}'s favorite color?",
            f"Which shade is {sp} most into?"]), f["color"],
            [[sp, f["color"]]])
        add("single_hop", rng.choice([
            f"Which city does {sp} live in?",
            f"Which town is {sp} based in?"]), f["city"],
            [[sp, f["city"]]])
        add("single_hop", rng.choice([
            f"What pet did {sp} adopt?",
            f"What animal does {sp} have as a companion?"]), f["pet"],
            [[sp, f["pet"]]])
        add("single_hop", rng.choice([
            f"What did {sp} buy recently?",
            f"What did {sp} purchase the other week?"]), f["item"],
            [[sp, f["item"]]])
        add("single_hop", rng.choice([
            f"What is {sp} learning?",
            f"What new skill is {sp} studying?"]), f["skill"],
            [[sp, f["skill"]]])
        add("single_hop", rng.choice([
            f"Where did {sp} travel to?",
            f"Where did {sp} go on a trip?"]), f["place"],
            [[sp, f["place"]]])
        add("single_hop", rng.choice([
            f"What does {sp} work as now?",
            f"What does {sp} do for a living these days?"]), job1[sp],
            [[sp, job1[sp]]])
        # multi-hop
        add("multi_hop", f"What is the name of {sp}'s {f['pet']}?",
            f["pet_name"],
            [[sp, f["pet"]], [f["pet"], f["pet_name"]]])
        add("multi_hop",
            f"Which city does the person who first worked as a {f['job0']} live in?",
            f["city"], [[sp, f["job0"]], [sp, f["city"]]])
        add("multi_hop",
            f"What food does the person learning {f['skill']} like most?",
            f["food"], [[sp, f["skill"]], [sp, f["food"]]])
        # temporal
        ts_place = BASE_TS + sess_of[f"{sp}:place"] * 7 * DAY
        add("temporal", rng.choice([
            f"When did {sp} travel to {f['place']}?",
            f"In which month was {sp}'s trip to {f['place']}?"]),
            _month_year(ts_place), [[f["place"], _ym(ts_place)]])
        add("temporal",
            f"What did {sp} work as before becoming a {job1[sp]}?",
            f["job0"], [[sp, f["job0"]]])
        ts_item = BASE_TS + sess_of[f"{sp}:item"] * 7 * DAY
        add("temporal", f"In which month did {sp} buy the {f['item']}?",
            _month_year(ts_item), [[f["item"], _ym(ts_item)]])
        # open-domain
        add("open_domain", rng.choice([
            f"What hobbies does {sp} enjoy?",
            f"What pastimes is {sp} interested in?"]),
            ", ".join(f["hobbies"]),
            [[sp, h] for h in f["hobbies"]], min_supports=2)

    # graph-chain questions: supports name only the chain's FAR end (the
    # triple the flat retriever has no lexical/semantic bridge to)
    for sp, al, trip, act in chains:
        f = facts[sp]
        add("multi_hop_graph",
            f"What food can {sp}'s {f['pet']} never eat?", al,
            [[f["pet_name"], al]])
        add("multi_hop_graph",
            f"What is the former {f['job0']}'s current profession?",
            job1[sp], [[sp, job1[sp]]])
        add("temporal_graph",
            f"Which class did {sp} start right after the trip to {trip}?",
            act, [[sp, act]])

    return Conversation(conv_id, (a, b), sessions, qs)


# ---------------------------------------------------------------------------
# Oracle reader + judge
# ---------------------------------------------------------------------------

def _support_found(context_lower_lines: List[str], support: List[str]) -> bool:
    needles = [s.lower() for s in support]
    return any(all(n in line for n in needles) for line in context_lower_lines)


def context_rot_p(tokens: int, coef: float = 0.035) -> float:
    """Documented reader-slip model (context rot, Hong et al. 2025): failure
    probability grows with injected tokens; ~0 below 1k, ~13% at 26k."""
    import math
    return min(0.30, coef * math.log2(1.0 + tokens / 1000.0))


def oracle_read(question: Question, context_text: str, *,
                rot_coef: float = 0.035, salt: str = "") -> str:
    """Deterministic reader: answers the gold answer iff the supports are in
    the context and the context-rot coin doesn't fire."""
    lines = [ln.lower() for ln in context_text.splitlines() if ln.strip()]
    found = [s for s in question.supports if _support_found(lines, s)]
    need = len(question.supports) if question.min_supports < 0 else question.min_supports
    if len(found) < need:
        return "I don't know"
    p = context_rot_p(len(context_text.split()), rot_coef)
    coin = stable_hash(question.qid + salt, 10_000) / 10_000.0
    if coin < p:
        return "I don't remember exactly"
    if question.category == "open_domain":
        hobbies = [s[-1] for s in found]
        return ", ".join(hobbies)
    return question.answer


def judge(question: Question, answer: str) -> bool:
    """Generous containment judge (paper Appendix B analogue)."""
    al = answer.lower()
    if question.category == "open_domain":
        gold_items = [g.strip().lower() for g in question.answer.split(",")]
        hits = sum(1 for g in gold_items if g in al)
        return hits * 2 >= len(gold_items)
    return question.answer.lower() in al


CATEGORIES = ("single_hop", "multi_hop", "temporal", "open_domain")

# the opt-in categories graph_chains=True adds (kept out of CATEGORIES:
# default conversations, and every consumer weighting by LOCOMO_WEIGHTS,
# never see them)
GRAPH_CATEGORIES = ("multi_hop_graph", "temporal_graph")

# LoCoMo question-count weights (paper Table 3, adversarial excluded)
LOCOMO_WEIGHTS = {"multi_hop": 282, "temporal": 321, "open_domain": 96,
                  "single_hop": 830}
