"""BM25 keyword index, TPU-adapted (DESIGN.md §3).

Classic BM25 walks inverted lists — pointer-chasing the TPU hates.  Here
terms hash into a fixed id space and documents are fixed-width padded id
rows, so scoring a query against the whole bank is a dense vectorised
comparison:  tf(t, d) = sum_j [doc_ids[d, j] == t].  Ranking semantics match
textbook BM25 up to hash collisions (property-tested against a dict-based
oracle in tests/).

Storage is a preallocated capacity-doubling row block (like VectorIndex):
`add` writes into the next free slots in amortized O(1) per document, and
the device-side doc/length arrays are capacity-padded buffers updated IN
PLACE on append (donated `dynamic_update_slice`, update width padded to a
power of two) — steady-state scoring re-uploads nothing and keeps stable
`(B, capacity)` shapes while the corpus grows within a capacity bucket, so
a background flusher appending documents every interval neither re-stacks
the corpus nor mints new executables per document count.

Multi-tenant extension: documents may carry a namespace tag (one per call
or one per document), and scoring can be scoped to one namespace — df, N,
and avg_len are then computed over that namespace's live documents only, so
a scoped query ranks exactly as it would against an isolated per-tenant
index.  `topk_batch` scores a whole batch of scoped queries as ONE stacked
(B, N) device op with a per-query selection mask; the single-query `topk`
is the B == 1 case of the same code path, so batched == sequential exactly.
`remove(ids)` tombstones documents (ids keep their slots — the row==doc-id
alignment with the triple store and vector bank survives — but dead docs
never score or surface again); `compact()` drops them for real and returns
the old→new id mapping.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2 as _next_pow2
from repro.data.tokenizer import HashTokenizer, default_tokenizer


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_append(docs, lens, new_docs, new_lens, start):
    """Write new doc rows + lengths at [start, start+m) in place (the
    capacity-resident mirror of VectorIndex._dev_append)."""
    docs = jax.lax.dynamic_update_slice(docs, new_docs, (start, 0))
    lens = jax.lax.dynamic_update_slice(lens, new_lens, (start,))
    return docs, lens


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_compact(docs, lens, gather, n_new):
    """Repack live doc rows in place: new row r takes old row `gather[r]`
    for r < n_new; the tail resets to the -1/1.0 unfilled defaults.  The
    sparse mirror of VectorIndex._dev_compact — a compaction moves zero
    doc-block bytes host->device and keeps the capacity (and with it every
    scoring executable keyed on it)."""
    live = jnp.arange(docs.shape[0]) < n_new
    docs = jnp.where(live[:, None], docs[gather], -1)
    lens = jnp.where(live, lens[gather], 1.0)
    return docs, lens


class BM25Index:
    def __init__(self, k1: float = 1.5, b: float = 0.75, max_doc_len: int = 32,
                 tokenizer: HashTokenizer | None = None, capacity: int = 256):
        self.k1 = k1
        self.b = b
        self.max_doc_len = max_doc_len
        self.tokenizer = tokenizer or default_tokenizer()
        self.n = 0
        self._docs = np.full((capacity, max_doc_len), -1, np.int32)
        self._lens = np.ones((capacity,), np.float32)
        self._ns = np.full((capacity,), -1, np.int32)   # -1 == untagged
        self._alive = np.zeros((capacity,), bool)
        # capacity-resident device buffers (lazily uploaded once per
        # capacity, then updated in place on add)
        self._cached_cap = -1                            # device-cache key
        self._docs_dev = None
        self._lens_dev = None

    # -- storage -----------------------------------------------------------
    def _grow(self, m: int) -> None:
        need = self.n + m
        cap = self._docs.shape[0]
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        docs = np.full((cap, self.max_doc_len), -1, np.int32)
        docs[: self.n] = self._docs[: self.n]
        lens = np.ones((cap,), np.float32)
        lens[: self.n] = self._lens[: self.n]
        ns = np.full((cap,), -1, np.int32)
        ns[: self.n] = self._ns[: self.n]
        alive = np.zeros((cap,), bool)
        alive[: self.n] = self._alive[: self.n]
        self._docs, self._lens, self._ns, self._alive = docs, lens, ns, alive
        self._invalidate_device()         # re-upload once per doubling

    def _invalidate_device(self) -> None:
        self._docs_dev = None
        self._lens_dev = None
        self._cached_cap = -1

    def add(self, texts: Sequence[str],
            namespace: Union[int, Sequence[int], None] = None) -> List[int]:
        """Append documents; `namespace` is one tag for the whole call or a
        per-document sequence (the batched multi-tenant ingest path)."""
        m = len(texts)
        if np.ndim(namespace) == 0:
            ns_per_doc = [(-1 if namespace is None else int(namespace))] * m
        else:
            ns_per_doc = [int(x) for x in namespace]
            if len(ns_per_doc) != m:
                raise ValueError(
                    f"{len(ns_per_doc)} namespace tags for {m} documents")
        self._grow(m)
        n0 = self.n
        ids = []
        for t, ns in zip(texts, ns_per_doc):
            tok = self.tokenizer.encode(t)[: self.max_doc_len]
            i = self.n
            self._docs[i] = -1
            self._docs[i, : len(tok)] = tok
            self._lens[i] = max(1, len(tok))
            self._ns[i] = ns
            self._alive[i] = True
            self.n += 1
            ids.append(i)
        if m and self._docs_dev is not None:
            # in-place device append, width padded to a power of two (the
            # pad rows read back the -1/1.0 defaults they already hold)
            cap = self._docs.shape[0]
            m_pad = max(m, min(_next_pow2(m), cap - n0))
            self._docs_dev, self._lens_dev = _dev_append(
                self._docs_dev, self._lens_dev,
                jnp.asarray(self._docs[n0: n0 + m_pad]),
                jnp.asarray(self._lens[n0: n0 + m_pad]), jnp.int32(n0))
        return ids

    def remove(self, ids: Sequence[int]) -> int:
        """Tombstone documents by id.  Returns #newly removed."""
        removed = 0
        for i in ids:
            i = int(i)
            if 0 <= i < self.n and self._alive[i]:
                self._alive[i] = False
                removed += 1
        return removed

    def compact(self) -> np.ndarray:
        """Physically drop tombstoned documents.  Returns the old→new id
        mapping as an (n_old,) int64 array (-1 for dropped docs); the kept
        docs keep their relative order.  Capacity is sticky (like
        VectorIndex.compact): scoring shapes stay keyed on the same bucket
        across auto-compactions."""
        n_old = self.n
        alive = self._alive[:n_old]
        old_to_new = np.full((n_old,), -1, np.int64)
        keep = np.where(alive)[0]
        old_to_new[keep] = np.arange(keep.size)
        n_new = int(keep.size)
        cap = self._docs.shape[0]
        docs = np.full((cap, self.max_doc_len), -1, np.int32)
        docs[:n_new] = self._docs[keep]
        lens = np.ones((cap,), np.float32)
        lens[:n_new] = self._lens[keep]
        ns = np.full((cap,), -1, np.int32)
        ns[:n_new] = self._ns[keep]
        alive_new = np.zeros((cap,), bool)
        alive_new[:n_new] = True
        self._docs, self._lens, self._ns, self._alive = \
            docs, lens, ns, alive_new
        self.n = n_new
        if self._docs_dev is not None:
            # device-side repack: donated gather in place, capacity sticky —
            # no (capacity, L) doc-block re-upload, the scoring executables
            # (keyed on capacity) survive the compaction untouched
            gather = np.zeros((cap,), np.int32)
            gather[:n_new] = keep
            self._docs_dev, self._lens_dev = _dev_compact(
                self._docs_dev, self._lens_dev, jnp.asarray(gather),
                jnp.int32(n_new))
        else:
            self._invalidate_device()
        return old_to_new

    # -- snapshot surface (see core/store.py) ------------------------------
    def doc_array(self) -> np.ndarray:
        return self._docs[: self.n].copy()

    def len_array(self) -> np.ndarray:
        return self._lens[: self.n].copy()

    def ns_array(self) -> np.ndarray:
        return self._ns[: self.n].copy()

    def alive_array(self) -> np.ndarray:
        return self._alive[: self.n].copy()

    def load_rows(self, docs, lens, ns, alive) -> None:
        """Bulk-load a snapshot's rows (replaces any current content)."""
        docs = np.asarray(docs, np.int32)
        n = docs.shape[0]
        if docs.shape[1] != self.max_doc_len:
            raise ValueError(f"doc width {docs.shape[1]} != "
                             f"max_doc_len {self.max_doc_len}")
        self.n = 0
        cap = max(64, _next_pow2(n))
        self._docs = np.full((cap, self.max_doc_len), -1, np.int32)
        self._lens = np.ones((cap,), np.float32)
        self._ns = np.full((cap,), -1, np.int32)
        self._alive = np.zeros((cap,), bool)
        self._docs[:n] = docs
        self._lens[:n] = np.asarray(lens, np.float32)
        self._ns[:n] = np.asarray(ns, np.int32)
        self._alive[:n] = np.asarray(alive, bool)
        self.n = n
        self._invalidate_device()

    def __len__(self):
        return self.n

    @property
    def alive_count(self) -> int:
        return int(self._alive[: self.n].sum())

    def _arrays(self):
        """Capacity-padded device buffers — uploaded once per capacity
        bucket (first query, or after grow/compact/load), then updated in
        place by `add`.  Never rebuilt per query or per append."""
        cap = self._docs.shape[0]
        if self._cached_cap != cap or self._docs_dev is None:
            self._docs_dev = jnp.asarray(self._docs)
            self._lens_dev = jnp.asarray(self._lens)
            self._cached_cap = cap
        return self._docs_dev, self._lens_dev

    def _selection(self, namespace: Optional[int]) -> np.ndarray:
        """(N,) bool: live docs, restricted to `namespace` when given."""
        sel = self._alive[: self.n].copy()
        if namespace is not None:
            sel &= self._ns[: self.n] == int(namespace)
        return sel

    # -- scoring -----------------------------------------------------------
    def scores(self, query: str, namespace: Optional[int] = None) -> jnp.ndarray:
        """BM25 scores over all docs -> (N,) f32 (empty -> (0,)).  Docs
        outside the selection (dead, or other namespaces when `namespace` is
        given) score 0; corpus statistics (N, df, avg_len) come from the
        selection only, so scoped scores equal an isolated index's."""
        if self.n == 0:
            return jnp.zeros((0,), jnp.float32)
        sel = self._selection(namespace)
        return self._scores_batch([self._terms(query)],
                                  sel[None])[0][: self.n]

    def _terms(self, query: str) -> List[int]:
        return list(dict.fromkeys(self.tokenizer.encode(query)))

    def _scores_batch(self, term_lists: Sequence[List[int]],
                      sels: np.ndarray, sel_dev=None) -> jnp.ndarray:
        """Stacked scoring: B scoped queries against the whole corpus in one
        device op -> (B, capacity) f32 (unfilled/unselected slots score 0).
        `sels` is the (B, n) per-query selection mask over the filled
        prefix; `sel_dev` optionally passes its capacity-padded device
        upload in (so topk_batch_dev builds/transfers the mask once).
        Term frequencies are computed ONCE over the union of all
        query terms and gathered per query, so the corpus is streamed once
        for the whole batch; df/idf/avg_len stay per-query (computed over
        each query's own selection, matching an isolated index's
        statistics).  Every device shape here is keyed on the capacity, not
        the doc count — appends within a bucket reuse the same executables."""
        B = len(term_lists)
        N = self.n
        if N == 0:
            return jnp.zeros((B, 0), jnp.float32)
        docs, lens = self._arrays()                        # (cap, L), (cap,)
        cap = self._docs.shape[0]
        if sel_dev is None:
            sel_pad = np.zeros((B, cap), bool)
            sel_pad[:, :N] = sels
            sel_dev = jnp.asarray(sel_pad)
        n_sel = sels.sum(axis=1)                                  # (B,)
        union = list(dict.fromkeys(t for ts in term_lists for t in ts))
        live = [b for b in range(B) if term_lists[b] and n_sel[b]]
        if not union or not live:
            return jnp.zeros((B, cap), jnp.float32)
        uidx = {t: i for i, t in enumerate(union)}
        T = max(len(ts) for ts in term_lists)
        idx = np.zeros((B, T), np.int32)
        valid = np.zeros((B, T), np.float32)
        for b, ts in enumerate(term_lists):
            idx[b, : len(ts)] = [uidx[t] for t in ts]
            valid[b, : len(ts)] = 1.0
        # tf over the union, once for the whole batch: (cap, U)
        tf_u = jnp.stack([(docs == t).sum(axis=1).astype(jnp.float32)
                          for t in union], axis=1)
        G = tf_u[:, jnp.asarray(idx)]                             # (cap, B, T)
        # the single device sync per batch: per-query df over its selection
        df = np.asarray(jnp.einsum("nbt,bn->bt",
                                   (G > 0).astype(jnp.float32),
                                   sel_dev.astype(jnp.float32)),
                        np.float32) * valid                        # (B, T)
        lens_np = self._lens[: N]
        avg = np.asarray([float(lens_np[sels[b]].mean()) if n_sel[b] else 1.0
                          for b in range(B)], np.float32)
        n_sel_f = n_sel.astype(np.float32)[:, None]
        idf = np.where(df > 0,
                       np.log(1.0 + (n_sel_f - df + 0.5) / (df + 0.5)),
                       0.0).astype(np.float32) * valid
        norm = self.k1 * (1.0 - self.b
                          + self.b * lens[None, :] / jnp.asarray(avg)[:, None])
        contrib = (jnp.asarray(idf)[None, :, :] * G * (self.k1 + 1.0)
                   / (G + jnp.swapaxes(norm, 0, 1)[:, :, None]))   # (cap, B, T)
        out = jnp.swapaxes(contrib.sum(axis=2), 0, 1)              # (B, cap)
        row_live = jnp.asarray(
            np.asarray([bool(term_lists[b]) and bool(n_sel[b])
                        for b in range(B)]))[:, None]
        return jnp.where(sel_dev & row_live, out, 0.0)

    def topk(self, query: str, k: int, namespace: Optional[int] = None):
        """Top-k (scores, global doc ids), restricted to the selection.
        Variable-length output (<= min(k, selection size))."""
        if self.n == 0:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        s, ids = self.topk_batch([query], k, namespaces=[namespace])
        m = ids[0] >= 0
        return s[0][m], ids[0][m]

    def topk_batch_dev(self, queries: Sequence[str], k: int,
                       namespaces: Optional[Sequence[Optional[int]]] = None):
        """Batched scoped top-k, all on device: one stacked (B, N) scoring
        op + one `jax.lax.top_k` over the selection-masked scores (the old
        per-query host argsort loop is gone).  Returns DEVICE arrays
        (scores (B, k) f32, ids (B, k) i32); slots beyond a query's
        selection size hold (0, -1).  Ties rank the lower doc id first,
        matching a stable host argsort."""
        B = len(queries)
        if B == 0 or self.n == 0:
            return (jnp.zeros((B, k), jnp.float32),
                    jnp.full((B, k), -1, jnp.int32))
        if namespaces is None:
            namespaces = [None] * B
        sels = np.stack([self._selection(ns) for ns in namespaces])
        sel_pad = np.zeros((B, self._docs.shape[0]), bool)
        sel_pad[:, : self.n] = sels
        sel_dev = jnp.asarray(sel_pad)     # built + uploaded once, shared
        S = self._scores_batch([self._terms(q) for q in queries], sels,
                               sel_dev=sel_dev)
        key = jnp.where(sel_dev, S, -jnp.inf)
        # k clamps to the CAPACITY, not the doc count: unfilled slots are
        # -inf-masked into (0, -1) anyway, and keying the top-k width on
        # capacity keeps one executable while the corpus grows in a bucket
        kk = min(k, self._docs.shape[0])
        s, idx = jax.lax.top_k(key, kk)
        live = s > -jnp.inf
        s = jnp.where(live, s, 0.0)
        idx = jnp.where(live, idx, -1).astype(jnp.int32)
        if kk < k:
            s = jnp.pad(s, ((0, 0), (0, k - kk)))
            idx = jnp.pad(idx, ((0, 0), (0, k - kk)), constant_values=-1)
        return s, idx

    def topk_batch(self, queries: Sequence[str], k: int,
                   namespaces: Optional[Sequence[Optional[int]]] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-array wrapper over `topk_batch_dev` (the device op is the
        single implementation; this just pulls the (B, k) result across)."""
        s, idx = self.topk_batch_dev(queries, k, namespaces=namespaces)
        return np.asarray(s, np.float32), np.asarray(idx, np.int64)
