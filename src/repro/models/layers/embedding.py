"""Token embedding + (optionally tied) output head."""
from __future__ import annotations

import jax.numpy as jnp

from repro.common.module import ParamSpec


def specs(cfg):
    s = {"table": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                            init="normal", scale=0.02)}
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                                 init="scaled_normal", scale=1.0)
    return s


def embed(params, cfg, tokens):
    # clip (not NaN-fill) on out-of-range ids: tokenizer/vocab mismatches
    # should degrade, not poison the whole forward.
    x = jnp.take(params["table"], tokens, axis=0, mode="clip")
    return x.astype(cfg.cdtype)


def logits(params, cfg, x):
    if cfg.tie_embeddings:
        w = params["table"].astype(cfg.cdtype)
        out = jnp.einsum("...d,vd->...v", x, w)
    else:
        out = jnp.einsum("...d,dv->...v", x, params["unembed"].astype(cfg.cdtype))
    return out.astype(jnp.dtype(cfg.logits_dtype))
