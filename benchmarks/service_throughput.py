"""Multi-tenant MemoryService throughput: the tentpole metrics of the
storage engine.

* retrieval — batched vs sequential: N tenants each hold a few ingested
  sessions in one packed bank; a batch of per-tenant queries is answered
  either as N sequential `retrieve` calls (N embed calls + N top-k
  launches) or as ONE `retrieve_batch` (one embed call + one
  namespace-masked topk_mips launch + one stacked BM25 scoring op).
* ingestion — batched vs sequential: B sessions ingested either as B
  synchronous `record` calls (B embed calls + B bank appends) or enqueued
  and drained by ONE `flush()` (one embed call + one bank append).
* compaction — tombstone half the bank, time `compact()`, report the
  reclaimed rows.

Wall-clock here is CPU (kernel off by default — Pallas interpret mode would
time the emulator, not the algorithm); on TPU the batched paths additionally
amortize kernel launch + HBM bank streaming across the whole batch.

    PYTHONPATH=src python benchmarks/service_throughput.py [--kernel]
        [--mode retrieve|ingest|compact|all] [--tenants N] [--sessions S]
        [--batches 1,8,32] [--json BENCH_service.json]
"""
from __future__ import annotations

import json
import time

from repro.core.extraction import Message
from repro.core.service import MemoryService
from repro.core.embedder import HashEmbedder

BATCH_SIZES = (1, 8, 32)
N_TENANTS = 32
SESSIONS_PER_TENANT = 3

FACTS = [
    "I work as a {job} and I live in {city}.",
    "I adopted a {pet} named {name}.",
    "My favorite color is {color}.",
]
JOBS = ["botanist", "welder", "pilot", "baker", "cartographer", "luthier"]
CITIES = ["tallinn", "porto", "cusco", "sapporo", "tromso", "windhoek"]
PETS = ["hedgehog", "parrot", "gecko", "ferret", "axolotl", "magpie"]
NAMES = ["biscuit", "olive", "comet", "pickle", "juniper", "maple"]
COLORS = ["indigo", "ochre", "teal", "crimson", "sage", "amber"]


def _sessions(n_tenants: int, per_tenant: int):
    out = []
    for u in range(n_tenants):
        ns = f"user{u}/c0"
        for s in range(per_tenant):
            texts = [f.format(job=JOBS[(u + s) % len(JOBS)],
                              city=CITIES[(u + s) % len(CITIES)],
                              pet=PETS[(u + s) % len(PETS)],
                              name=NAMES[(u + s) % len(NAMES)],
                              color=COLORS[(u + s) % len(COLORS)])
                     for f in FACTS]
            msgs = [Message(f"user{u}", t, 1700000000.0 + s) for t in texts]
            out.append((ns, f"s{s}", msgs))
    return out


def _build_service(use_kernel: bool, n_tenants: int = N_TENANTS,
                   per_tenant: int = SESSIONS_PER_TENANT) -> MemoryService:
    svc = MemoryService(HashEmbedder(), budget=800, use_kernel=use_kernel)
    for ns, sid, msgs in _sessions(n_tenants, per_tenant):
        svc.record(ns, sid, msgs)
    return svc


def _time(fn, iters: int = 5) -> float:
    fn()                       # warmup (jit caches, lazy arrays)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run_retrieval(csv_rows, use_kernel: bool = False,
                  n_tenants: int = N_TENANTS,
                  per_tenant: int = SESSIONS_PER_TENANT,
                  batches=BATCH_SIZES, json_out=None):
    print("\n# MemoryService throughput — batched vs sequential retrieval"
          + (" [pallas kernel]" if use_kernel else " [jnp ref path]"))
    svc = _build_service(use_kernel, n_tenants, per_tenant)
    queries = [(f"user{u}/c0", f"Which city does user{u} live in?")
               for u in range(n_tenants)]
    for B in dict.fromkeys(min(b, len(queries)) for b in batches):
        batch = queries[:B]
        t_seq = _time(lambda: [svc.retrieve(ns, q) for ns, q in batch])
        t_bat = _time(lambda: svc.retrieve_batch(batch))
        speedup = t_seq / t_bat
        qps_seq = B / t_seq
        qps_bat = B / t_bat
        print(f"batch {B:3d}: sequential {t_seq*1e3:8.1f}ms ({qps_seq:7.1f} q/s)"
              f" | batched {t_bat*1e3:8.1f}ms ({qps_bat:7.1f} q/s)"
              f" | speedup {speedup:5.2f}x")
        csv_rows.append((f"service/batch{B}", t_bat * 1e6,
                         f"{speedup:.2f}x vs sequential"))
        if json_out is not None:
            json_out.append({"batch": B, "t_seq_ms": t_seq * 1e3,
                             "t_batched_ms": t_bat * 1e3,
                             "speedup": speedup})
    return csv_rows


def run_ingest(csv_rows, use_kernel: bool = False,
               n_tenants: int = N_TENANTS,
               per_tenant: int = SESSIONS_PER_TENANT,
               batches=BATCH_SIZES, json_out=None):
    print("\n# MemoryService throughput — batched (enqueue+flush) vs "
          "sequential (record) ingestion")
    sessions = _sessions(n_tenants, per_tenant)
    for B in dict.fromkeys(min(b, len(sessions)) for b in batches):
        batch = sessions[:B]

        def seq():
            svc = MemoryService(HashEmbedder(), budget=800,
                                use_kernel=use_kernel)
            for ns, sid, msgs in batch:
                svc.record(ns, sid, msgs)

        def bat():
            svc = MemoryService(HashEmbedder(), budget=800,
                                use_kernel=use_kernel)
            for ns, sid, msgs in batch:
                svc.enqueue(ns, sid, msgs)
            svc.flush()

        t_seq = _time(seq, iters=3)
        t_bat = _time(bat, iters=3)
        speedup = t_seq / t_bat
        print(f"batch {B:3d}: sequential {t_seq*1e3:8.1f}ms "
              f"({B/t_seq:7.1f} sess/s) | batched {t_bat*1e3:8.1f}ms "
              f"({B/t_bat:7.1f} sess/s) | speedup {speedup:5.2f}x")
        csv_rows.append((f"service/ingest{B}", t_bat * 1e6,
                         f"{speedup:.2f}x vs sequential record"))
        if json_out is not None:
            json_out.append({"batch": B, "t_seq_ms": t_seq * 1e3,
                             "t_batched_ms": t_bat * 1e3,
                             "speedup": speedup})
    return csv_rows


def run_compact(csv_rows, use_kernel: bool = False,
                n_tenants: int = N_TENANTS,
                per_tenant: int = SESSIONS_PER_TENANT, json_out=None):
    print("\n# MemoryService — bank compaction (tombstone reclamation)")
    svc = _build_service(use_kernel, n_tenants, per_tenant)
    for u in range(0, n_tenants, 2):      # evict every other tenant
        svc.evict(f"user{u}/c0")
    st = svc.stats()
    t0 = time.perf_counter()
    info = svc.compact()
    dt = time.perf_counter() - t0
    print(f"compact: {info['rows_before']} -> {info['rows_after']} rows "
          f"({info['dropped']} reclaimed, {st['tombstones']} tombstones) "
          f"in {dt*1e3:.1f}ms")
    csv_rows.append(("service/compact", dt * 1e6,
                     f"{info['dropped']} rows reclaimed"))
    if json_out is not None:
        json_out.update({"t_ms": dt * 1e3, **info})
    return csv_rows


def run(csv_rows, use_kernel: bool = False, mode: str = "all",
        n_tenants: int = N_TENANTS, per_tenant: int = SESSIONS_PER_TENANT,
        batches=BATCH_SIZES, json_path=None):
    report = {"retrieval": [], "ingestion": [], "compaction": {}}
    if mode in ("retrieve", "all"):
        run_retrieval(csv_rows, use_kernel, n_tenants, per_tenant, batches,
                      json_out=report["retrieval"])
    if mode in ("ingest", "all"):
        run_ingest(csv_rows, use_kernel, n_tenants, per_tenant, batches,
                   json_out=report["ingestion"])
    if mode in ("compact", "all"):
        run_compact(csv_rows, use_kernel, n_tenants, per_tenant,
                    json_out=report["compaction"])
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\nwrote {json_path}")
    return csv_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", action="store_true",
                    help="route dense search through the Pallas kernel "
                         "(interpret mode off-TPU: slow, for parity checks)")
    ap.add_argument("--mode", default="all",
                    choices=["retrieve", "ingest", "compact", "all"])
    ap.add_argument("--tenants", type=int, default=N_TENANTS)
    ap.add_argument("--sessions", type=int, default=SESSIONS_PER_TENANT)
    ap.add_argument("--batches", default=",".join(map(str, BATCH_SIZES)),
                    help="comma-separated batch sizes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_service.json artifact")
    args = ap.parse_args()
    run([], use_kernel=args.kernel, mode=args.mode, n_tenants=args.tenants,
        per_tenant=args.sessions,
        batches=tuple(int(b) for b in args.batches.split(",")),
        json_path=args.json)
