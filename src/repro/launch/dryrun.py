"""Multi-pod dry-run: AOT-lower and compile every (arch × input-shape) on the
production meshes, print memory/cost analysis, and dump roofline artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--out artifacts]

The FIRST TWO LINES below must run before any other import: jax locks the
device count on first init, and the dry-run (only the dry-run) needs 512
placeholder host devices to build the 2×16×16 production mesh.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ASSIGNED_ARCHS, get_config  # noqa: E402
from repro.launch import mesh as mesh_lib             # noqa: E402
from repro.launch.sharding import build_step, supported  # noqa: E402
from repro.models.config import INPUT_SHAPES          # noqa: E402

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped or "-done." in stripped:
            continue
        hit = None
        for op in _COLLECTIVES:
            if re.search(rf"\b{op}(-start)?\(", stripped):
                hit = op
                break
        if hit is None:
            continue
        # result shapes appear on the LHS before the op call
        lhs = stripped.split(f" {hit}", 1)[0]
        nbytes = 0
        for m in _SHAPE_RE.finditer(lhs):
            dt, dims = m.group(1), m.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[hit] += nbytes
        counts[hit] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def model_flops(cfg, shape) -> float:
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch            # decode: 1 token


def _compile_and_measure(cfg, shape, mesh, variant: str = "") -> dict:
    bundle = build_step(cfg, shape, mesh, variant=variant)
    t0 = time.time()
    lowered = bundle.fn.lower(*bundle.args)
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "bundle": bundle, "mem": mem, "hlo": hlo,
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "lower_s": t_lower, "compile_s": t_compile,
    }


def apply_variant(cfg, variant: str, multi_pod: bool):
    """§Perf hillclimb variants (EXPERIMENTS.md §Perf)."""
    import dataclasses
    if not variant or variant == "baseline":
        return cfg
    if variant == "moe_local":
        shards = 32 if multi_pod else 16      # batch-axis size
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="local",
                                         local_shards=shards))
    if variant == "mla_absorbed":
        return dataclasses.replace(cfg, mla_absorbed_train=True)
    if variant == "kv_int8":
        return dataclasses.replace(cfg, kv_cache_quant="int8")
    if variant == "kv_replicated":
        return cfg          # rules change, handled in build_decode_step
    if variant == "kv_replicated+int8":
        return dataclasses.replace(cfg, kv_cache_quant="int8")
    if variant == "serve_mesh_32x8":
        return cfg          # mesh change, handled in run_one
    if variant == "serve_mesh_32x8+int8":
        return dataclasses.replace(cfg, kv_cache_quant="int8")
    if variant == "moe_local+mla_absorbed":
        shards = 32 if multi_pod else 16
        return dataclasses.replace(
            cfg, mla_absorbed_train=True,
            moe=dataclasses.replace(cfg.moe, dispatch="local",
                                    local_shards=shards))
    raise KeyError(variant)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            probes: bool = True, cfg=None, variant: str = "") -> dict:
    from repro.launch import roofline as rf
    cfg = cfg or get_config(arch)
    cfg = apply_variant(cfg, variant, multi_pod)
    shape = INPUT_SHAPES[shape_name]
    ok, why = supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "variant": variant or "baseline",
           "status": "skipped" if not ok else "?", "skip_reason": why}
    if not ok:
        print(f"[dryrun] SKIP {arch} × {shape_name}: {why}")
        return rec

    if variant.startswith("serve_mesh"):
        # serving-specific mesh: model axis sized to divide the kv heads so
        # the decode cache shards cleanly (same 256 chips, different shape)
        mesh = jax.make_mesh((32, 8), ("data", "model"))
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    with mesh:
        full = _compile_and_measure(cfg, shape, mesh, variant=variant)
    chips = mesh.devices.size
    mem = full["mem"]

    def _mem_field(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    rec.update({
        "status": "ok",
        "chips": chips,
        "meta": full["bundle"].meta,
        "lower_s": round(full["lower_s"], 2),
        "compile_s": round(full["compile_s"], 2),
        "hlo_flops_scanbody_once": full["flops"],
        "hlo_bytes_scanbody_once": full["bytes_accessed"],
        "collective_bytes_scanbody_once": full["coll"],
        "model_flops": model_flops(cfg, shape),
        "memory": {
            "argument_bytes": _mem_field("argument_size_in_bytes"),
            "output_bytes": _mem_field("output_size_in_bytes"),
            "temp_bytes": _mem_field("temp_size_in_bytes"),
            "generated_code_bytes": _mem_field("generated_code_size_in_bytes"),
            "alias_bytes": _mem_field("alias_size_in_bytes"),
        },
    })
    print(f"[dryrun] OK {arch} × {shape_name} × {rec['mesh']} "
          f"(lower {full['lower_s']:.1f}s, compile {full['compile_s']:.1f}s)")
    print(f"  memory_analysis: {mem}")

    # --- probe-corrected totals (single-pod roofline only) -----------------
    if probes and not multi_pod:
        pcfgs = rf.probe_configs(cfg)
        pmetrics = []
        for pc in pcfgs:
            with mesh:
                pm = _compile_and_measure(pc, shape, mesh, variant=variant)
            entry = {"flops": pm["flops"], "bytes": pm["bytes_accessed"]}
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute", "total"):
                entry[f"coll_{k}"] = float(pm["coll"][k])
            pmetrics.append(entry)
        pred = rf.extrapolate(cfg, pcfgs, pmetrics)
        rec["hlo_flops"] = pred["flops"]
        rec["hlo_bytes_accessed"] = pred["bytes"]
        rec["collective_bytes"] = {
            k.replace("coll_", ""): v for k, v in pred.items()
            if k.startswith("coll_")}
        rec["probe_layers"] = [c.num_layers for c in pcfgs]
        rec["roofline"] = rf.roofline_terms(
            pred["flops"], pred["bytes"], pred["coll_total"])
        rec["useful_flops_ratio"] = (
            (rec["model_flops"] / chips) / max(1.0, pred["flops"]))
        print(f"  corrected: flops={pred['flops']:.3e}/chip "
              f"bytes={pred['bytes']:.3e}/chip coll={pred['coll_total']:.3e}B/chip")
        print(f"  roofline: {rec['roofline']} "
              f"useful_ratio={rec['useful_flops_ratio']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
                if args.variant:
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] cached {tag}")
                    results.append(json.load(open(path)))
                    continue
                try:
                    rec = run_one(arch, shape_name, mp, variant=args.variant)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "variant": args.variant or "baseline",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[dryrun] ERROR {tag}: {e!r}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                results.append(rec)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
