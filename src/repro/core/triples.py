"""Semantic triples — the atomic memory unit of Advanced Augmentation.

Each triple is (subject, predicate, object) plus provenance: the conversation
and session it came from, its timestamp, and the id of the session summary it
links to — "granular facts are never divorced from their broader context"
(paper §2.1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


def normalize_entity(s: str) -> str:
    """Canonical form of an entity mention: casefold + whitespace collapse.
    `Triple.key()` and the memory graph's node interning (core/graph.py)
    share this function, so "Caroline", "caroline" and "  Caroline " are ONE
    version chain and ONE graph node instead of silently splitting."""
    return " ".join(s.split()).lower()


@dataclasses.dataclass(frozen=True)
class Triple:
    subject: str
    predicate: str
    object: str
    conversation_id: str = ""
    session_id: str = ""
    timestamp: float = 0.0
    source_text: str = ""
    confidence: float = 1.0

    def text(self) -> str:
        return f"{self.subject} {self.predicate} {self.object}"

    def render(self) -> str:
        """Prompt rendering (paper Appendix A: timestamped factual triples)."""
        ts = time.strftime("%Y-%m-%d", time.gmtime(self.timestamp)) if self.timestamp else "?"
        return f"[{ts}] ({self.subject}; {self.predicate}; {self.object})"

    def key(self) -> str:
        return f"{normalize_entity(self.subject)}|" \
               f"{normalize_entity(self.predicate)}"


class TripleStore:
    """Append-only store with contradiction bookkeeping: triples sharing
    (subject, predicate) are versions of one evolving attribute; retrieval
    surfaces all of them and the answering policy prefers the most recent
    (paper Appendix A instruction 4)."""

    def __init__(self):
        self._triples: List[Triple] = []
        self._by_key: Dict[str, List[int]] = {}

    def add(self, triple: Triple) -> int:
        tid = len(self._triples)
        self._triples.append(triple)
        self._by_key.setdefault(triple.key(), []).append(tid)
        return tid

    def get(self, tid: int) -> Triple:
        return self._triples[tid]

    def latest_for_key(self, key: str) -> Optional[Triple]:
        ids = self._by_key.get(key)
        if not ids:
            return None
        return max((self._triples[i] for i in ids), key=lambda t: t.timestamp)

    def versions(self, tid: int) -> List[Triple]:
        return [self._triples[i] for i in self._by_key[self._triples[tid].key()]]

    def superseded_ids(self) -> List[int]:
        """Ids of every triple that is NOT the latest version of its
        (subject, predicate) key — the rows a service may physically evict
        from its indices once conflict resolution has settled on the newest
        value.  Tie-breaking matches latest_for_key (first max by timestamp)."""
        out: List[int] = []
        for ids in self._by_key.values():
            if len(ids) < 2:
                continue
            latest = max(ids, key=lambda i: self._triples[i].timestamp)
            out.extend(i for i in ids if i != latest)
        return out

    def __len__(self) -> int:
        return len(self._triples)

    def __iter__(self):
        return iter(self._triples)

    def all(self) -> List[Triple]:
        return list(self._triples)
