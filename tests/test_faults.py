"""Fault-injection layer (checkpoint/faults.py): the POSIX power-loss
model behind every crash test — torn writes, bit flips, disk-full, crash
points around write/fsync/rename/dir-fsync — plus the parent-directory
fsync regression in checkpoint/io.py (a freshly created file's direntry
can vanish on power loss unless the parent directory is fsync'd)."""
import errno
import os

import numpy as np
import pytest

from repro.checkpoint import faults
from repro.checkpoint.faults import (FaultRule, FaultyFS, InjectedCrash,
                                     RealFS)
from repro.checkpoint.io import load_raw, save
from repro.checkpoint.wal import WriteAheadLog, atomic_write_bytes


# -- the filesystem model ------------------------------------------------------

def test_realfs_is_the_default_and_writes_normally(tmp_path):
    assert isinstance(faults.active(), RealFS)
    p = str(tmp_path / "f")
    faults.active().write_file(p, b"hello", fsync=True)
    with open(p, "rb") as f:
        assert f.read() == b"hello"


def test_install_swaps_and_restores_the_active_fs(tmp_path):
    fs = FaultyFS(str(tmp_path))
    before = faults.active()
    with faults.install(fs):
        assert faults.active() is fs
    assert faults.active() is before


def test_power_loss_removes_unsynced_files(tmp_path):
    fs = FaultyFS(str(tmp_path))
    synced, unsynced = str(tmp_path / "a"), str(tmp_path / "b")
    with faults.install(fs):
        fs.write_file(synced, b"one", fsync=True)
        fs.fsync_dir(str(tmp_path))
        fs.write_file(unsynced, b"two", fsync=False)
        fs.simulate_power_loss()
    assert os.path.exists(synced)
    assert not os.path.exists(unsynced)


def test_power_loss_reverts_unsynced_overwrite_of_durable_file(tmp_path):
    fs = FaultyFS(str(tmp_path))
    p = str(tmp_path / "a")
    with faults.install(fs):
        fs.write_file(p, b"old", fsync=True)
        fs.fsync_dir(str(tmp_path))
        fs.write_file(p, b"new", fsync=False)   # in place, never fsync'd
        fs.simulate_power_loss()
    with open(p, "rb") as f:
        assert f.read() == b"old"


def test_content_fsync_without_dir_fsync_loses_new_entry(tmp_path):
    """The precise failure io.py's bugfix closes: fsync(file) makes the
    CONTENT durable, but a brand-new file's directory entry needs the
    parent dir fsync'd too."""
    fs = FaultyFS(str(tmp_path))
    p = str(tmp_path / "fresh")
    with faults.install(fs):
        fs.write_file(p, b"data", fsync=True)   # no fsync_dir
        fs.simulate_power_loss()
    assert not os.path.exists(p)


def test_rename_without_dir_fsync_can_revert(tmp_path):
    fs = FaultyFS(str(tmp_path))
    tmp, dst = str(tmp_path / "t.tmp"), str(tmp_path / "t")
    with faults.install(fs):
        fs.write_file(tmp, b"payload", fsync=True)
        fs.replace(tmp, dst)
        fs.simulate_power_loss()                # no fsync_dir
    assert not os.path.exists(dst)


def test_enospc_mode_raises_oserror_without_crashing_the_model(tmp_path):
    fs = FaultyFS(str(tmp_path),
                  rules=[FaultRule("write", mode="enospc", nth=2)])
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    with faults.install(fs):
        fs.write_file(a, b"x", fsync=True)
        with pytest.raises(OSError) as ei:
            fs.write_file(b, b"y", fsync=True)
        assert ei.value.errno == errno.ENOSPC
        fs.fsync_dir(str(tmp_path))
        fs.simulate_power_loss()
    assert os.path.exists(a) and not os.path.exists(b)


def test_rules_fire_on_nth_match_and_repeat(tmp_path):
    fs = FaultyFS(str(tmp_path), rules=[
        FaultRule("write", path_substr="wal", nth=2)])
    with faults.install(fs):
        fs.write_file(str(tmp_path / "wal-1"), b"x", fsync=True)  # 1st: ok
        with pytest.raises(InjectedCrash):
            fs.write_file(str(tmp_path / "wal-2"), b"x", fsync=True)
        # non-repeating rule is spent
        fs.write_file(str(tmp_path / "wal-3"), b"x", fsync=True)
    assert [t[0] for t in fs.trips] == ["write"]


def test_paths_outside_the_root_pass_through(tmp_path):
    inside, outside = tmp_path / "in", tmp_path / "out"
    inside.mkdir(), outside.mkdir()
    fs = FaultyFS(str(inside), rules=[FaultRule("write", path_substr="")])
    p = str(outside / "f")
    with faults.install(fs):
        fs.write_file(p, b"x", fsync=True)      # rule must not fire
    assert os.path.exists(p)


# -- WAL under injected faults -------------------------------------------------

def test_wal_append_crash_before_rename_loses_nothing_durable(tmp_path):
    fs = FaultyFS(str(tmp_path),
                  rules=[FaultRule("replace", path_substr="wal-00000002")])
    d = str(tmp_path / "w")
    with faults.install(fs):
        wal = WriteAheadLog(d)
        wal.append({"op": "a"})
        with pytest.raises(InjectedCrash):
            wal.append({"op": "b"})
        fs.simulate_power_loss()
    wal2 = WriteAheadLog(d)
    assert [r["op"] for _, r in wal2.replay_records()] == ["a"]
    assert wal2.replay_stopped_seq is None      # clean tail, not corrupt


def test_wal_torn_write_never_becomes_a_segment(tmp_path):
    """A torn tmp-file write crashes before the rename: power loss leaves
    at most a stray .tmp, never a half-written wal-*.msgpack segment."""
    fs = FaultyFS(str(tmp_path),
                  rules=[FaultRule("write", mode="torn",
                                   path_substr="wal-00000002")])
    d = str(tmp_path / "w")
    with faults.install(fs):
        wal = WriteAheadLog(d)
        wal.append({"op": "a"})
        with pytest.raises(InjectedCrash):
            wal.append({"op": "b"})
        fs.simulate_power_loss()
    names = os.listdir(d)
    assert "wal-00000002.msgpack" not in names
    wal2 = WriteAheadLog(d)
    assert [r["op"] for _, r in wal2.replay_records()] == ["a"]


def test_wal_fsync_crash_means_segment_not_durable(tmp_path):
    fs = FaultyFS(str(tmp_path),
                  rules=[FaultRule("fsync", path_substr="wal-00000001")])
    d = str(tmp_path / "w")
    with faults.install(fs):
        wal = WriteAheadLog(d)
        with pytest.raises(InjectedCrash):
            wal.append({"op": "a"})
        fs.simulate_power_loss()
    wal2 = WriteAheadLog(d)
    assert list(wal2.replay_records()) == []


def test_atomic_write_goes_through_the_fault_layer(tmp_path):
    fs = FaultyFS(str(tmp_path))
    p = str(tmp_path / "blob")
    with faults.install(fs):
        atomic_write_bytes(p, b"payload")
        fs.simulate_power_loss()    # full sequence incl. dir fsync survives
    with open(p, "rb") as f:
        assert f.read() == b"payload"


# -- the checkpoint/io.py regression ------------------------------------------

def test_save_fsync_survives_power_loss(tmp_path):
    """Regression: save(fsync=True) must fsync the PARENT DIRECTORY too,
    or the freshly created snapshot can vanish wholesale on power loss."""
    fs = FaultyFS(str(tmp_path))
    p = str(tmp_path / "state.msgpack")
    tree = {"x": np.arange(8, dtype=np.int64), "y": np.ones((2, 3), np.float32)}
    with faults.install(fs):
        save(p, tree, fsync=True)
        fs.simulate_power_loss()
        assert os.path.exists(p), \
            "snapshot direntry lost: parent dir was not fsync'd"
    got = load_raw(p)
    np.testing.assert_array_equal(got["x"], tree["x"])
    np.testing.assert_array_equal(got["y"], tree["y"])


def test_save_without_dir_fsync_would_lose_the_file(tmp_path):
    """Counterexample proving the model detects the bug the fix closes: if
    the dir fsync is crashed out, power loss erases the entry."""
    fs = FaultyFS(str(tmp_path),
                  rules=[FaultRule("fsync_dir", path_substr="")])
    p = str(tmp_path / "state.msgpack")
    with faults.install(fs):
        with pytest.raises(InjectedCrash):
            save(p, {"x": np.arange(4)}, fsync=True)
        fs.simulate_power_loss()
        assert not os.path.exists(p)


def test_save_atomic_survives_power_loss(tmp_path):
    fs = FaultyFS(str(tmp_path))
    p = str(tmp_path / "snap.msgpack")
    with faults.install(fs):
        save(p, {"x": np.arange(4)}, atomic=True, fsync=True)
        fs.simulate_power_loss()
    assert (load_raw(p)["x"] == np.arange(4)).all()
