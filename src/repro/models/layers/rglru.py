"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block layout (Griffin "recurrent block"):
    x ── linear ─ conv1d ─ RG-LRU ──┐
    x ── linear ─ GeLU ─────────────┴─ ⊙ ── linear out

RG-LRU:  r_t = σ(W_a x_t + b_a),  i_t = σ(W_x x_t + b_x)
         a_t = exp(-c · softplus(Λ) · r_t)
         h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

Training/prefill run the recurrence as a jax.lax.associative_scan (log-depth
on TPU); decode is the O(1) step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.module import ParamSpec


def width(cfg):
    return cfg.rglru.width or cfg.d_model


def specs(cfg):
    d = cfg.d_model
    w = width(cfg)
    W = cfg.rglru.conv_width
    return {
        "in_proj_x": ParamSpec((d, w), ("embed", "state"), init="scaled_normal", scale=1.0),
        "in_proj_gate": ParamSpec((d, w), ("embed", "state"), init="scaled_normal", scale=1.0),
        "conv_w": ParamSpec((W, w), (None, "state"), init="scaled_normal", scale=1.0),
        "conv_b": ParamSpec((w,), ("state",), init="zeros"),
        "wa": ParamSpec((w, w), ("state", None), init="scaled_normal", scale=1.0),
        "ba": ParamSpec((w,), ("state",), init="zeros"),
        "wx": ParamSpec((w, w), ("state", None), init="scaled_normal", scale=1.0),
        "bx": ParamSpec((w,), ("state",), init="zeros"),
        "lam": ParamSpec((w,), ("state",), init="rglru_lambda"),
        "out_proj": ParamSpec((w, d), ("state", "embed"), init="scaled_normal", scale=1.0),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b


def _gates(params, cfg, xb):
    f32 = jnp.float32
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xb.astype(f32), params["wa"].astype(f32))
                       + params["ba"].astype(f32))
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xb.astype(f32), params["wx"].astype(f32))
                       + params["bx"].astype(f32))
    log_a = -cfg.rglru.c_exponent * jax.nn.softplus(params["lam"].astype(f32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xb.astype(f32))
    return a, b


def apply(params, cfg, x, *, mode: str = "train", cache=None,
          return_cache: bool = False):
    """x: (B,L,d); cache = {"conv": (B,W-1,w), "h": (B,w)}."""
    dt_ = x.dtype
    B_, L, d = x.shape
    W = cfg.rglru.conv_width

    xb = jnp.einsum("bld,dw->blw", x, params["in_proj_x"].astype(dt_))
    gate = jnp.einsum("bld,dw->blw", x, params["in_proj_gate"].astype(dt_))

    if mode == "decode":
        window = jnp.concatenate([cache["conv"].astype(dt_), xb], axis=1)
        conv_out = (window * params["conv_w"].astype(dt_)).sum(1, keepdims=True)
        conv_out = conv_out + params["conv_b"].astype(dt_)
        new_conv = window[:, 1:]
        a, b = _gates(params, cfg, conv_out)
        h = a[:, 0] * cache["h"].astype(jnp.float32) + b[:, 0]
        y = h[:, None]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "h": h.astype(cache["h"].dtype)}
    else:
        conv_out = _causal_conv(xb, params["conv_w"].astype(dt_),
                                params["conv_b"].astype(dt_))
        a, b = _gates(params, cfg, conv_out)
        if mode == "prefill" and cache is not None:
            # fold the incoming state into the first step
            b = b.at[:, 0].add(a[:, 0] * cache["h"].astype(jnp.float32))

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        Q = 1024   # two-level recurrence: assoc-scan within chunks, lax.scan
        if L > Q:  # across chunks — bounds XLA compile for 32k+ prefills
            if L % Q:
                pad = Q - L % Q
                a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
                b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            nc = a.shape[1] // Q
            w = a.shape[-1]
            ac = a.reshape(B_, nc, Q, w).transpose(1, 0, 2, 3)
            bc = b.reshape(B_, nc, Q, w).transpose(1, 0, 2, 3)

            def chunk_step(h_prev, inp):
                a_blk, b_blk = inp                       # (B, Q, w)
                A_pre, B_pre = jax.lax.associative_scan(
                    combine, (a_blk, b_blk), axis=1)
                h_blk = A_pre * h_prev[:, None] + B_pre  # prefix · carry + local
                return h_blk[:, -1], h_blk

            h0 = jnp.zeros((B_, w), jnp.float32)
            _, h_chunks = jax.lax.scan(chunk_step, h0, (ac, bc))
            h_seq = h_chunks.transpose(1, 0, 2, 3).reshape(B_, nc * Q, w)[:, :L]
        else:
            _, h_seq = jax.lax.associative_scan(combine, (a, b), axis=1)
        y = h_seq
        new_cache = None
        if return_cache:
            new_cache = {"conv": xb[:, -(W - 1):].astype(dt_),
                         "h": h_seq[:, -1].astype(dt_)}

    y = y.astype(dt_) * jax.nn.gelu(gate)
    out = jnp.einsum("blw,wd->bld", y, params["out_proj"].astype(dt_))
    return out, new_cache


def init_cache(cfg, batch: int, dtype):
    w = width(cfg)
    return {"conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w), dtype),
            "h": jnp.zeros((batch, w), dtype)}


def cache_specs(cfg, batch: int, dtype):
    w = width(cfg)
    return {"conv": ((batch, cfg.rglru.conv_width - 1, w), ("batch", None, "state"), dtype),
            "h": ((batch, w), ("batch", "state"), dtype)}
