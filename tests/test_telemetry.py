"""Telemetry registry (obs/telemetry.py): Prometheus-exact histogram and
counter exposition, lock-correct concurrent recording checked against a
numpy oracle, scrape-while-recording consistency, per-request span trees
propagated frontend -> scheduler -> plan stages, bounded ring buffers for
traces and structured events (FIFO eviction), slow-query events, the JSONL
event sink, and the disabled-mode no-op guarantees the overhead bench's
baseline relies on."""
import json
import threading

import numpy as np
import pytest

from repro.core import MemoryScheduler, MemoryService
from repro.core.embedder import HashEmbedder
from repro.core.api import RetrieveRequest
from repro.core.extraction import Message
from repro.obs.telemetry import (DEFAULT_BUCKETS, Counter, Histogram,
                                 Telemetry, get_telemetry, new_request_id,
                                 set_telemetry, span_names, walk_spans)


@pytest.fixture()
def tel():
    """A fresh registry swapped in as the process-wide one (restored on
    exit so the remaining suite keeps its accumulated metrics)."""
    prev = get_telemetry()
    t = set_telemetry(Telemetry(slow_query_s=None))
    yield t
    set_telemetry(prev)
    t.close()


# -- histograms: exact Prometheus semantics -----------------------------------

def test_histogram_exposition_exact():
    h = Histogram("memori_test_seconds", "a test histogram",
                  buckets=(0.1, 1.0))
    h.observe(0.05)          # le=0.1
    h.observe(0.1)           # boundary: buckets are closed above (v <= le)
    h.observe(0.5, n=3)      # le=1.0, batched
    h.observe(7.0)           # +Inf only
    assert h.exposition() == [
        "# HELP memori_test_seconds a test histogram",
        "# TYPE memori_test_seconds histogram",
        'memori_test_seconds_bucket{le="0.1"} 2',
        'memori_test_seconds_bucket{le="1"} 5',
        'memori_test_seconds_bucket{le="+Inf"} 6',
        "memori_test_seconds_sum 8.65",
        "memori_test_seconds_count 6",
    ]
    assert h.count == 6


def test_counter_exposition_exact():
    c = Counter("memori_test_things", "things that happened")
    c.inc()
    c.inc(2.5)
    assert c.exposition() == [
        "# HELP memori_test_things_total things that happened",
        "# TYPE memori_test_things_total counter",
        "memori_test_things_total 3.5",
    ]


def test_histogram_concurrent_observations_match_numpy_oracle():
    rng = np.random.default_rng(7)
    per_thread = [rng.gamma(2.0, 0.01, size=2000) for _ in range(8)]
    h = Histogram("memori_oracle_seconds", buckets=DEFAULT_BUCKETS)
    threads = [threading.Thread(
        target=lambda vals=vals: [h.observe(v) for v in vals])
        for vals in per_thread]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    everything = np.concatenate(per_thread)
    counts, total = h.snapshot()
    # oracle: right-closed buckets, exactly Prometheus's v <= le
    edges = np.array((-np.inf,) + tuple(DEFAULT_BUCKETS) + (np.inf,))
    want, _ = np.histogram(everything, bins=np.nextafter(edges, np.inf))
    assert counts.tolist() == want.tolist()
    assert h.count == everything.size            # no observation lost
    assert total == pytest.approx(float(everything.sum()), rel=1e-9)


def test_scrape_while_recording_stays_consistent():
    h = Histogram("memori_live_seconds", buckets=(0.001, 0.01, 0.1))
    stop = threading.Event()

    def recorder():
        i = 0
        while not stop.is_set():
            h.observe(0.0005 * (1 + i % 300))
            i += 1
    t = threading.Thread(target=recorder)
    t.start()
    try:
        last_count, last_sum = 0, 0.0
        for _ in range(300):
            counts, total = h.snapshot()
            cum = counts.sum()
            # cumulative count and sum only move forward, and each
            # snapshot's (counts, sum) pair is internally consistent
            assert cum >= last_count
            assert total >= last_sum - 1e-12
            assert total <= 0.15 * cum + 1e-9    # max observable value
            last_count, last_sum = cum, total
    finally:
        stop.set()
        t.join()
    assert h.count > 0


def test_histogram_rejects_empty_buckets():
    with pytest.raises(ValueError, match="bucket"):
        Histogram("memori_bad", buckets=())


# -- span trees ---------------------------------------------------------------

def test_span_tree_nesting_and_attrs(tel):
    tr = tel.start_trace("rid-1", op="retrieve")
    with tel.activate([tr]):
        with tel.span("outer", tenant="acme"):
            with tel.span("inner", batch=4) as sp:
                sp.set(launches=1)
        tr.add_completed("queued", 0.25)
    tel.finish_trace(tr)
    d = tel.get_trace("rid-1")
    assert span_names(d) == ["retrieve", "outer", "inner", "queued"]
    spans = {s["name"]: s for s in walk_spans(d["root"])}
    assert spans["outer"]["attrs"] == {"tenant": "acme"}
    assert spans["inner"]["attrs"] == {"batch": 4, "launches": 1}
    assert spans["inner"]["start_s"] >= spans["outer"]["start_s"]
    assert spans["queued"]["duration_s"] == 0.25
    assert d["duration_s"] >= spans["outer"]["duration_s"]


def test_activate_replaces_and_restores(tel):
    a = tel.start_trace("a", op="x")
    b = tel.start_trace("b", op="y")
    with tel.activate([a, None, a]):                  # dedup + None filter
        assert tel.current_traces() == [a]
        with tel.activate([b]):                       # REPLACE, not union
            with tel.span("only-b"):
                pass
        with tel.span("only-a"):
            pass
    tel.finish_trace(a)
    tel.finish_trace(b)
    assert span_names(tel.get_trace("a")) == ["x", "only-a"]
    assert span_names(tel.get_trace("b")) == ["y", "only-b"]


def test_span_survives_exception_unwind(tel):
    tr = tel.start_trace("boom", op="r")
    with pytest.raises(RuntimeError):
        with tel.activate([tr]):
            with tel.span("doomed"):
                raise RuntimeError("kaboom")
    tel.finish_trace(tr)
    d = tel.get_trace("boom")
    spans = {s["name"]: s for s in walk_spans(d["root"])}
    assert spans["doomed"]["duration_s"] is not None  # closed on unwind


def test_full_stack_span_tree_scheduler_to_plan(tel):
    """The tentpole acceptance path without HTTP: a traced retrieve
    submitted through the scheduler carries queue wait, the shared tick,
    and every executed plan stage in ONE tree."""
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800)
    sched = MemoryScheduler(svc, tick_interval_s=0.002, max_batch=16)
    try:
        svc.record("acme/c0", "s0",
                   [Message("U", "I live in Madrid.", 1.0)])
        tr = tel.start_trace("full-1", op="retrieve")
        fut = sched.submit_many(
            [RetrieveRequest(namespace="acme/c0", query="Which city?")],
            traces=[tr])[0]
        assert fut.result(timeout=30).status == "ok"
        tel.finish_trace(tr)
        names = span_names(tel.get_trace("full-1"))
        for want in ("queued", "scheduler.tick", "plan.embed", "plan.dense",
                     "plan.sparse", "plan.fuse", "plan.budget"):
            assert want in names, f"{want} missing from {names}"
        # the tick span closed before the future resolved: every span in
        # the serialized tree has a duration
        for s in walk_spans(tel.get_trace("full-1")["root"]):
            assert s["duration_s"] is not None
        # the plan stages carry the batch size the launch amortized
        spans = {s["name"]: s for s in walk_spans(
            tel.get_trace("full-1")["root"])}
        assert spans["plan.dense"]["attrs"]["batch"] >= 1
        assert spans["scheduler.tick"]["attrs"]["batch_size"] >= 1
    finally:
        sched.close()


# -- ring buffers + events ----------------------------------------------------

def test_trace_ring_evicts_oldest_first():
    tel = Telemetry(trace_capacity=4, slow_query_s=None)
    for i in range(6):
        tel.finish_trace(tel.start_trace(f"r{i}", op="x"))
    recent = [t["request_id"] for t in tel.recent_traces(limit=10)]
    assert recent == ["r2", "r3", "r4", "r5"]        # FIFO eviction
    assert tel.get_trace("r0") is None and tel.get_trace("r1") is None
    assert tel.get_trace("r5")["request_id"] == "r5"


def test_event_ring_evicts_oldest_first_and_filters():
    tel = Telemetry(event_capacity=3, slow_query_s=None)
    for i in range(5):
        tel.event("tick" if i % 2 else "tock", i=i)
    got = tel.events()
    assert [e["i"] for e in got] == [2, 3, 4]
    assert [e["i"] for e in tel.events(kind="tick")] == [3]
    assert [e["i"] for e in tel.events(limit=1)] == [4]


def test_slow_query_event_and_counter():
    tel = Telemetry(slow_query_s=0.0)
    tr = tel.start_trace("slowpoke", op="retrieve")
    tel.finish_trace(tr)
    tel.finish_trace(tr)                             # idempotent: one event
    evs = tel.events(kind="slow_query")
    assert len(evs) == 1 and evs[0]["request_id"] == "slowpoke"
    assert tel.counter("memori_slow_queries").value == 1


def test_jsonl_event_sink(tmp_path):
    path = str(tmp_path / "events.jsonl")
    tel = Telemetry(event_sink=path, slow_query_s=None)
    tel.event("admission_reject", tenants=["acme"], requests=3)
    tel.event("shard_down", shard=1)
    tel.close()
    rows = [json.loads(ln) for ln in
            open(path, encoding="utf-8").read().splitlines()]
    assert [r["kind"] for r in rows] == ["admission_reject", "shard_down"]
    assert rows[0]["tenants"] == ["acme"] and rows[1]["shard"] == 1
    assert all(r["ts"] > 0 for r in rows)


# -- disabled mode + ids ------------------------------------------------------

def test_disabled_telemetry_is_a_no_op():
    tel = Telemetry(enabled=False)
    assert tel.start_trace("x", op="y") is None
    tel.inc("memori_nope")
    tel.observe("memori_nada", 0.5)
    with tel.activate([None]):
        with tel.span("ghost") as sp:
            sp.set(batch=1)                          # handle still works
    tel.finish_trace(None)
    tel.event("invisible")
    assert tel.metrics() == [] and tel.events() == []
    assert tel.recent_traces() == [] and tel.render() == ""


def test_request_ids_are_unique_hex():
    ids = {new_request_id() for _ in range(256)}
    assert len(ids) == 256
    assert all(len(i) == 16 and int(i, 16) >= 0 for i in ids)


def test_registry_reuses_metric_instances():
    tel = Telemetry()
    h1 = tel.histogram("memori_same_seconds")
    h2 = tel.histogram("memori_same_seconds")
    assert h1 is h2
    c1 = tel.counter("memori_same_things")
    assert tel.counter("memori_same_things") is c1
