"""Filesystem fault injection for durability testing.

Every crash-durability-relevant filesystem mutation in the checkpoint layer
(`wal.py`, `io.py`, `replication.py`) routes through the module-level active
`FilesystemOps` — `RealFS` in production (a zero-overhead passthrough), or a
`FaultyFS` installed by tests.  `FaultyFS` does two things:

1. **Injects faults** at named crash points.  A `FaultRule` matches an op
   ("write", "fsync", "replace", "fsync_dir", "unlink", "ship") plus a path
   substring, fires on the nth hit, and applies a mode: `crash` (raise
   `InjectedCrash` before the op), `torn` (write a prefix, then crash),
   `bitflip` (silently corrupt one bit and continue), `enospc` (raise
   ENOSPC), `delay` (sleep, for slow-sink latency).

2. **Models the durable view** of the tree under its root — which bytes
   would survive power loss at this instant, per POSIX crash semantics:
   a file's *content* is on stable storage only after its fd is fsync'd,
   and a *directory entry* (creation, rename, unlink) is durable only
   after the parent directory is fsync'd.  `simulate_power_loss()` rewinds
   the real tree to that durable view, so a test can assert exactly what a
   crash at any injected point would leave behind — this is what catches
   the write-without-parent-dir-fsync class of bug.

The model is deliberately conservative: an entry promoted by a dir fsync
whose content was never fsync'd comes back as an empty (torn) file, and an
in-place overwrite without fsync reverts to the old content.
"""
from __future__ import annotations

import errno
import os
import random
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Set

OPS = ("write", "fsync", "replace", "fsync_dir", "unlink", "ship")
MODES = ("crash", "torn", "bitflip", "enospc", "delay")


class InjectedCrash(Exception):
    """Raised at an injected crash point (stands in for kill -9 at that
    instant: the process stops, the durable view is whatever was synced)."""


class FaultRule:
    """One injection site: fires when `op` matches, `path_substr` is in the
    path, and the match count reaches `nth` (every match >= nth when
    `repeat`)."""

    def __init__(self, op: str, mode: str = "crash", path_substr: str = "",
                 nth: int = 1, delay_s: float = 0.0, repeat: bool = False):
        if op not in OPS:
            raise ValueError(f"op {op!r} not in {OPS}")
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        self.op = op
        self.mode = mode
        self.path_substr = path_substr
        self.nth = int(nth)
        self.delay_s = float(delay_s)
        self.repeat = repeat
        self.hits = 0     # matching op invocations seen
        self.fired = 0    # times the fault actually triggered

    def matches(self, op: str, path: str) -> bool:
        if op != self.op or self.path_substr not in path:
            return False
        self.hits += 1
        fire = self.hits >= self.nth if self.repeat else self.hits == self.nth
        if fire:
            self.fired += 1
        return fire


class RealFS:
    """Production passthrough: plain os calls, no bookkeeping."""

    def write_file(self, path: str, blob: bytes, fsync: bool = True) -> None:
        with open(path, "wb") as f:
            f.write(blob)
            if fsync:
                f.flush()
                os.fsync(f.fileno())

    def replace(self, src: str, dst: str) -> None:
        os.replace(src, dst)

    def fsync_dir(self, path: str) -> None:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def unlink(self, path: str) -> None:
        os.unlink(path)

    def trip(self, op: str, path: str) -> None:
        """Named crash point with no filesystem side effect (e.g. "ship")."""


_TOMB = object()      # directory entry removal awaiting parent-dir fsync
_VOLATILE = object()  # entry whose content was never fsync'd


class FaultyFS(RealFS):
    """Fault-injecting filesystem with a power-loss durable-view model.

    Tracks three layers for every file it touches under `root`:
      - `_durable`: entry + content guaranteed to survive power loss
      - `_synced`: content fsync'd to stable storage (entry maybe not)
      - `_pending[dir]`: entry mutations awaiting that directory's fsync
    Files already on disk at first touch are seeded as durable (they
    predate the faulty window).  Paths outside `root` pass straight
    through to the real ops with no modeling.
    """

    def __init__(self, root: str, rules: Optional[List[FaultRule]] = None,
                 seed: int = 0):
        self.root = os.path.abspath(root)
        self.rules: List[FaultRule] = list(rules or [])
        self.trips: List[tuple] = []          # (op, mode, path) fired log
        self._rng = random.Random(seed)
        self._durable: Dict[str, bytes] = {}
        self._synced: Dict[str, bytes] = {}
        self._pending: Dict[str, Dict[str, object]] = {}
        self._tracked: Set[str] = set()

    def add_rule(self, rule: FaultRule) -> FaultRule:
        self.rules.append(rule)
        return rule

    # -- rule machinery ----------------------------------------------------
    def _inside(self, path: str) -> bool:
        return os.path.abspath(path).startswith(self.root + os.sep) or \
            os.path.abspath(path) == self.root

    def _fire(self, op: str, path: str) -> Optional[FaultRule]:
        for rule in self.rules:
            if rule.matches(op, path):
                self.trips.append((op, rule.mode, path))
                return rule
        return None

    def trip(self, op: str, path: str) -> None:
        rule = self._fire(op, path)
        if rule is None:
            return
        if rule.mode == "delay":
            time.sleep(rule.delay_s)
        elif rule.mode == "enospc":
            raise OSError(errno.ENOSPC, os.strerror(errno.ENOSPC), path)
        else:
            raise InjectedCrash(f"injected {rule.mode} at {op}({path})")

    # -- durable-view bookkeeping ------------------------------------------
    def _seed(self, path: str) -> None:
        """A file that predates our first touch is durable as-is."""
        if path in self._tracked:
            return
        self._tracked.add(path)
        if os.path.isfile(path):
            with open(path, "rb") as f:
                blob = f.read()
            self._durable[path] = blob
            self._synced[path] = blob

    def _pending_of(self, path: str) -> Dict[str, object]:
        return self._pending.setdefault(os.path.dirname(path), {})

    def _note_write(self, path: str, blob: bytes, synced: bool) -> None:
        if synced:
            self._synced[path] = blob
            if path in self._durable:
                # in-place overwrite of a durable entry: content durable now
                self._durable[path] = blob
                self._pending_of(path).pop(path, None)
            else:
                self._pending_of(path)[path] = blob
        else:
            self._synced.pop(path, None)
            if path not in self._durable:
                self._pending_of(path)[path] = _VOLATILE
            # durable file overwritten without fsync: model power loss as
            # reverting to the old durable content

    # -- ops ---------------------------------------------------------------
    def write_file(self, path: str, blob: bytes, fsync: bool = True) -> None:
        path = os.path.abspath(path)
        if not self._inside(path):
            return super().write_file(path, blob, fsync=fsync)
        self._seed(path)
        rule = self._fire("write", path)
        if rule is not None:
            if rule.mode == "delay":
                time.sleep(rule.delay_s)
            elif rule.mode == "enospc":
                raise OSError(errno.ENOSPC, os.strerror(errno.ENOSPC), path)
            elif rule.mode == "bitflip":
                blob = self._flip(blob)           # silent corruption
            elif rule.mode == "torn":
                prefix = blob[: max(1, len(blob) // 2)]
                with open(path, "wb") as f:
                    f.write(prefix)
                self._note_write(path, prefix, synced=True)
                raise InjectedCrash(f"injected torn write at {path}")
            else:                                 # crash before the write
                raise InjectedCrash(f"injected crash at write({path})")
        with open(path, "wb") as f:
            f.write(blob)
            if fsync:
                f.flush()
                try:
                    self.trip("fsync", path)
                except Exception:
                    self._note_write(path, blob, synced=False)
                    raise
                os.fsync(f.fileno())
        self._note_write(path, blob, synced=fsync)

    def replace(self, src: str, dst: str) -> None:
        src, dst = os.path.abspath(src), os.path.abspath(dst)
        if not self._inside(dst):
            return super().replace(src, dst)
        self._seed(src)
        self._seed(dst)
        self.trip("replace", dst)
        os.replace(src, dst)
        content = self._synced.pop(src, None)
        if src in self._durable:
            self._pending_of(src)[src] = _TOMB
        else:
            self._pending_of(src).pop(src, None)
        self._pending_of(dst)[dst] = content if content is not None \
            else _VOLATILE
        if content is not None:
            self._synced[dst] = content

    def fsync_dir(self, path: str) -> None:
        path = os.path.abspath(path)
        if not self._inside(path):
            return super().fsync_dir(path)
        self.trip("fsync_dir", path)
        super().fsync_dir(path)
        for p, content in self._pending.pop(path, {}).items():
            if content is _TOMB:
                self._durable.pop(p, None)
            elif content is _VOLATILE:
                # entry made durable, content never synced: torn file
                self._durable[p] = self._synced.get(p, b"")
            else:
                self._durable[p] = content  # type: ignore[assignment]

    def unlink(self, path: str) -> None:
        path = os.path.abspath(path)
        if not self._inside(path):
            return super().unlink(path)
        self._seed(path)
        self.trip("unlink", path)
        os.unlink(path)
        self._synced.pop(path, None)
        if path in self._durable:
            self._pending_of(path)[path] = _TOMB
        else:
            self._pending_of(path).pop(path, None)

    def _flip(self, blob: bytes) -> bytes:
        if not blob:
            return blob
        buf = bytearray(blob)
        i = self._rng.randrange(len(buf))
        buf[i] ^= 1 << self._rng.randrange(8)
        return bytes(buf)

    # -- power loss --------------------------------------------------------
    def simulate_power_loss(self) -> List[str]:
        """Rewind the real tree under `root` to the durable view: tracked
        files revert to their durable bytes (or vanish if their entry was
        never made durable).  Returns the paths that changed or vanished.
        The model then continues from the post-loss state."""
        changed = []
        for path in sorted(self._tracked):
            if path in self._durable:
                on_disk = None
                if os.path.isfile(path):
                    with open(path, "rb") as f:
                        on_disk = f.read()
                if on_disk != self._durable[path]:
                    os.makedirs(os.path.dirname(path), exist_ok=True)
                    with open(path, "wb") as f:
                        f.write(self._durable[path])
                    changed.append(path)
            elif os.path.isfile(path):
                os.unlink(path)
                changed.append(path)
        self._pending.clear()
        self._synced = dict(self._durable)
        return changed


_ACTIVE: RealFS = RealFS()


def active() -> RealFS:
    """The filesystem ops currently in effect (RealFS unless a test
    installed a FaultyFS)."""
    return _ACTIVE


@contextmanager
def install(fs: RealFS):
    """Swap the active filesystem ops for the duration of the block."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = fs
    try:
        yield fs
    finally:
        _ACTIVE = prev
