"""Segmented write-ahead log for the memory store's lifecycle runtime.

One directory holds the full durable state of a `MemoryStore`:

    <dir>/
      MANIFEST.msgpack            advisory index (retained generations)
      snapshot-00000007.msgpack   full-store snapshot, name encodes the WAL
                                  seq it covers ("everything through seq 7")
      wal-00000008.msgpack        one segment per durable mutation after it
      wal-00000009.msgpack

Every append and every snapshot is written **atomically**: the bytes go to a
`*.tmp` sibling, are fsync'd, and are `os.replace`d into the final name (the
directory is fsync'd after the rename), so a crash at any instant leaves
either the complete file or no file — never a torn segment under its real
name.  Each segment is self-describing (version + seq + CRC32 of the
payload), so recovery validates what it reads instead of trusting it.

Recovery = newest restorable snapshot + ordered replay of the segments with
seq greater than the snapshot's coverage.  Rotation writes a fresh snapshot,
re-points the manifest, prunes snapshot generations beyond the retention
count, and only then truncates WAL segments — and only those at or below the
coverage of the *oldest retained* snapshot, so every retained generation can
still be brought fully up to date from the segments that remain.

The log stores opaque msgpack records; what they mean is the store's
business (`MemoryStore.wal_record types`, replayed by `MemoryStore.
apply_wal`).  See docs/OPERATIONS.md for the operator view and
docs/STORAGE.md for the record format.
"""
from __future__ import annotations

import os
import re
import time
import warnings
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import msgpack

from repro.checkpoint import faults
from repro.obs.telemetry import FSYNC_LATENCY, get_telemetry

SEGMENT_VERSION = 1
MANIFEST_NAME = "MANIFEST.msgpack"
_SEG_RE = re.compile(r"^wal-(\d{8})\.msgpack$")
_SNAP_RE = re.compile(r"^snapshot-(\d{8})\.msgpack$")


def fsync_dir(path: str) -> None:
    """Flush a directory entry table (the rename durability point)."""
    faults.active().fsync_dir(path)


def atomic_write_bytes(path: str, blob: bytes) -> None:
    """tmp + fsync + rename + dir-fsync: the file exists completely or not
    at all, and survives power loss once this returns.  All three steps
    route through `checkpoint.faults` so tests can crash between them."""
    tel = get_telemetry()
    t0 = time.perf_counter()
    fs = faults.active()
    tmp = path + ".tmp"
    fs.write_file(tmp, blob, fsync=True)
    fs.replace(tmp, path)
    fs.fsync_dir(os.path.dirname(os.path.abspath(path)))
    tel.inc("memori_wal_fsyncs",
            help="atomic durable writes (file fsync + rename + dir fsync)")
    tel.observe(FSYNC_LATENCY, time.perf_counter() - t0,
                help="atomic durable write latency (fsync + rename + "
                     "dir fsync)")


class CorruptSegmentError(RuntimeError):
    """A WAL segment failed validation (bad version, seq, or checksum)."""


# every field a segment envelope may carry; anything else means the
# envelope bytes themselves were damaged (the CRC only covers the payload,
# so a flipped bit in an envelope KEY would otherwise go unnoticed)
_ENVELOPE_KEYS = frozenset({"version", "seq", "count", "crc", "payload"})


class WriteAheadLog:
    def __init__(self, dirpath: str):
        self.dir = os.path.abspath(dirpath)
        os.makedirs(self.dir, exist_ok=True)
        # seq numbering continues past everything ever named on disk —
        # including snapshots' coverage, so a post-recovery append can never
        # collide with a truncated-away segment's seq.  A group segment is
        # named by its FIRST seq but owns a run of them, so the tail comes
        # from the newest segment's record count (a bounded header peek —
        # the payload is never loaded at open time).
        segs = self.segment_seqs()
        tail = segs[-1] + self.segment_record_count(segs[-1]) - 1 \
            if segs else 0
        snaps = max((s for s, _ in self.snapshots()), default=0)
        self._next_seq = max(tail, snaps) + 1
        # file seq replay last stopped at (None = clean); see quarantine_from
        self.replay_stopped_seq: Optional[int] = None
        # called with the absolute path of every freshly sealed segment
        # (segments are immutable once named, so "written" == "sealed");
        # the replication shipper hangs off this to stream segments to a
        # follower.  Must not raise — durability is the local fsync, the
        # hook is best-effort propagation.
        self.on_seal = None

    # -- paths -------------------------------------------------------------
    def _seg_path(self, seq: int) -> str:
        return os.path.join(self.dir, f"wal-{seq:08d}.msgpack")

    def snapshot_path(self, wal_through: int) -> str:
        """The snapshot file covering every segment with seq <=
        `wal_through` (the coverage is encoded in the name, so recovery
        needs no manifest to pair snapshots with segments)."""
        return os.path.join(self.dir, f"snapshot-{wal_through:08d}.msgpack")

    # -- scan --------------------------------------------------------------
    def segment_seqs(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _SEG_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def snapshots(self) -> List[Tuple[int, str]]:
        """[(wal_through, path)] sorted oldest -> newest."""
        out = []
        for name in os.listdir(self.dir):
            m = _SNAP_RE.match(name)
            if m:
                out.append((int(m.group(1)), os.path.join(self.dir, name)))
        return sorted(out)

    def latest_snapshot(self) -> Optional[Tuple[int, str]]:
        snaps = self.snapshots()
        return snaps[-1] if snaps else None

    @property
    def last_seq(self) -> int:
        """Seq of the most recently appended segment (0 if none ever)."""
        return self._next_seq - 1

    # -- append ------------------------------------------------------------
    def append(self, record: dict) -> int:
        """Durably append one record as its own segment.  Returns the seq.
        When this returns, the record survives kill -9 / power loss."""
        seq = self._next_seq
        payload = msgpack.packb(record, use_bin_type=True)
        envelope = msgpack.packb({
            "version": SEGMENT_VERSION,
            "seq": seq,
            "crc": zlib.crc32(payload),
            "payload": payload,
        }, use_bin_type=True)
        tel = get_telemetry()
        with tel.span("wal.append", seq=seq, bytes=len(envelope)):
            atomic_write_bytes(self._seg_path(seq), envelope)
        tel.inc("memori_wal_appends", help="WAL segments appended")
        self._next_seq = seq + 1
        if self.on_seal is not None:
            self.on_seal(self._seg_path(seq))
        return seq

    def append_group(self, records: List[dict]) -> Tuple[int, int]:
        """Group commit: durably append several records as ONE segment file
        (one atomic write, one fsync).  The file is named by the first seq
        and owns `len(records)` consecutive seqs; its CRC covers the whole
        group, so a torn / corrupt group replays all-or-nothing — recovery
        can never apply a prefix of a group.  Returns (first_seq, last_seq).

        This is what coalesces a multi-writer scheduler tick (batched
        flush + evictions + compaction) into a single fsync instead of one
        per mutation (see LifecycleRuntime.group_commit for the commit
        ordering contract)."""
        records = list(records)
        if not records:
            raise ValueError("append_group needs at least one record")
        if len(records) == 1:
            seq = self.append(records[0])
            return seq, seq
        first = self._next_seq
        payload = msgpack.packb(records, use_bin_type=True)
        envelope = msgpack.packb({
            "version": SEGMENT_VERSION,
            "seq": first,
            "count": len(records),
            "crc": zlib.crc32(payload),
            "payload": payload,
        }, use_bin_type=True)
        tel = get_telemetry()
        with tel.span("wal.group_commit", seq=first, records=len(records),
                      bytes=len(envelope)):
            atomic_write_bytes(self._seg_path(first), envelope)
        tel.inc("memori_wal_group_commits",
                help="multi-record WAL group-commit segments")
        self._next_seq = first + len(records)
        if self.on_seal is not None:
            self.on_seal(self._seg_path(first))
        return first, first + len(records) - 1

    # -- read / replay -----------------------------------------------------
    def segment_record_count(self, seq: int) -> int:
        """Record count of one segment from its envelope header alone — a
        bounded read that never loads the payload (flush payloads carry raw
        embedding vectors and can be large).  The envelope packs its keys
        in order (version, seq, [count], crc, payload), so the count, when
        present, always precedes the payload bytes.  Undecodable headers
        count as 1: replay stops at that file regardless."""
        try:
            with open(self._seg_path(seq), "rb") as f:
                head = f.read(96)
            u = msgpack.Unpacker(raw=False)
            u.feed(head)
            for _ in range(u.read_map_header()):
                key = u.unpack()
                if key == "payload":
                    break
                val = u.unpack()
                if key == "count":
                    return int(val)
            return 1
        except Exception:
            return 1

    def quarantine_from(self, file_seq: int) -> List[str]:
        """Set aside every segment file with name seq >= `file_seq`
        (renamed to `*.corrupt`, invisible to scans but preserved for
        forensics).  Called by recovery when replay stops inside the log:
        the un-replayable tail must not keep shadowing the seq space —
        otherwise records appended AFTER the remount would sit behind the
        corrupt file forever and every future recovery would silently drop
        them despite their acknowledged-durable fsync."""
        moved = []
        for seq in self.segment_seqs():
            if seq >= file_seq:
                path = self._seg_path(seq)
                faults.active().replace(path, path + ".corrupt")
                moved.append(os.path.basename(path) + ".corrupt")
        if moved:
            fsync_dir(self.dir)
            get_telemetry().event("wal_quarantine", dir=self.dir,
                                  from_seq=int(file_seq), files=moved)
            warnings.warn(f"WAL quarantined un-replayable tail: {moved}",
                          stacklevel=2)
        return moved

    def file_seq_of(self, record_seq: int) -> int:
        """The name seq of the segment file holding `record_seq` (group
        files own a run of record seqs past their name)."""
        owner = 0
        for seq in self.segment_seqs():
            if seq <= record_seq:
                owner = seq
        return owner

    def _read_env(self, seq: int):
        """Decode + validate one segment file's envelope; returns
        (count, decoded payload) — a dict for single-record segments, a
        list for groups.  Raises CorruptSegmentError."""
        with open(self._seg_path(seq), "rb") as f:
            raw = f.read()
        try:
            env = msgpack.unpackb(raw, raw=False)
            version, crc = env["version"], env["crc"]
            payload = env["payload"]
        except Exception as e:
            raise CorruptSegmentError(f"segment {seq}: undecodable ({e})")
        extra = set(env) - _ENVELOPE_KEYS
        if extra:
            raise CorruptSegmentError(
                f"segment {seq}: unknown envelope fields {sorted(extra)}")
        if version != SEGMENT_VERSION:
            raise CorruptSegmentError(
                f"segment {seq}: version {version} != {SEGMENT_VERSION}")
        if env.get("seq") != seq:
            raise CorruptSegmentError(
                f"segment file {seq} claims seq {env.get('seq')}")
        if zlib.crc32(payload) != crc:
            raise CorruptSegmentError(f"segment {seq}: checksum mismatch")
        count = int(env.get("count", 1))
        decoded = msgpack.unpackb(payload, raw=False)
        if count > 1:
            if not isinstance(decoded, list) or len(decoded) != count:
                raise CorruptSegmentError(
                    f"segment {seq}: group claims {count} records, payload "
                    f"holds {len(decoded) if isinstance(decoded, list) else 1}")
        elif not isinstance(decoded, dict):
            # records are dicts by contract; a list here means a group's
            # count field was corrupted down to 1 — the payload CRC cannot
            # catch that (the payload is intact, the envelope is not)
            raise CorruptSegmentError(
                f"segment {seq}: single-record payload decodes to "
                f"{type(decoded).__name__}, not a record")
        return count, decoded

    def read_segment(self, seq: int) -> dict:
        """Decode + validate one single-record segment; raises
        CorruptSegmentError (group segments read via read_records)."""
        count, decoded = self._read_env(seq)
        if count > 1:
            raise CorruptSegmentError(
                f"segment {seq} is a {count}-record group; use "
                "read_records()")
        return decoded

    def read_records(self, seq: int) -> List[dict]:
        """Decode + validate one segment file into its record list (length
        1 for classic segments)."""
        count, decoded = self._read_env(seq)
        return decoded if count > 1 else [decoded]

    def replay_records(self, after_seq: int = 0
                       ) -> Iterator[Tuple[int, dict]]:
        """Yield (seq, record) in order for every valid record with
        seq > after_seq — group segments expand to their consecutive seq
        run.  Replay stops at the first invalid segment (with a warning):
        everything after an undecodable record has unknown provenance and
        must not be applied.  Where replay stopped is left in
        `replay_stopped_seq` (the FILE's name seq) so recovery can
        quarantine the dead tail before accepting new appends."""
        self.replay_stopped_seq = None
        segs = self.segment_seqs()
        for i, seq in enumerate(segs):
            if seq <= after_seq:
                # records are consecutive across segment files, so this
                # file ends at segs[i+1] - 1: when that is still <= the
                # coverage, skip by name alone — no read, no checksum (only
                # the last covered file, whose extent the name alone can't
                # bound, needs decoding to find a straddling group tail)
                nxt = segs[i + 1] if i + 1 < len(segs) else None
                if nxt is not None and nxt <= after_seq + 1:
                    continue
            try:
                records = self.read_records(seq)
            except CorruptSegmentError as e:
                # fully-covered corrupt files were already skipped by name
                # above; reaching here means this file's extent cannot be
                # bounded without decoding it — it may be a group whose
                # tail straddles past the coverage, so nothing after it
                # may be applied
                self.replay_stopped_seq = seq
                warnings.warn(f"WAL replay stopped: {e}", stacklevel=2)
                return
            for j, rec in enumerate(records):
                if seq + j <= after_seq:
                    continue
                yield seq + j, rec

    # -- rotation ----------------------------------------------------------
    def commit_snapshot(self, wal_through: int, retain: int = 2) -> dict:
        """Called after the snapshot file for `wal_through` is atomically in
        place: re-point the manifest, prune generations beyond `retain`, and
        truncate segments no retained generation still needs.  Returns a
        summary dict (snapshots kept, segments dropped)."""
        snaps = self.snapshots()
        if wal_through not in [s for s, _ in snaps]:
            raise FileNotFoundError(
                f"no snapshot file for wal_through={wal_through}")
        keep = snaps[-retain:] if retain else snaps
        # carry each retained generation's recorded birth forward; the one
        # being committed (no prior record) is born now.  Births live in the
        # manifest, not in file mtimes: a restore/copy rewrites mtimes, and
        # the mount path's snapshot-age accounting must survive that.
        births = self.snapshot_births()
        now = time.time()
        self.write_manifest(keep, {s: births.get(s, now) for s, _ in keep})
        dropped_snaps = 0
        for through, path in snaps[:-retain] if retain else []:
            faults.active().unlink(path)
            dropped_snaps += 1
        # only segments every retained snapshot already covers may go
        oldest_covered = min(s for s, _ in keep)
        dropped_segs = 0
        for seq in self.segment_seqs():
            if seq <= oldest_covered:
                faults.active().unlink(self._seg_path(seq))
                dropped_segs += 1
        fsync_dir(self.dir)
        return {"retained_snapshots": len(keep),
                "dropped_snapshots": dropped_snaps,
                "truncated_segments": dropped_segs}

    # -- manifest (advisory: recovery trusts the directory scan) -----------
    def write_manifest(self, snaps: List[Tuple[int, str]],
                       births: Optional[Dict[int, float]] = None) -> None:
        births = births or {}
        entries = []
        for s, p in snaps:
            entry = {"wal_through": s, "name": os.path.basename(p)}
            if s in births:
                entry["born_unix"] = float(births[s])
            entries.append(entry)
        atomic_write_bytes(os.path.join(self.dir, MANIFEST_NAME),
                           msgpack.packb({
                               "version": SEGMENT_VERSION,
                               "snapshots": entries,
                           }, use_bin_type=True))

    def snapshot_births(self) -> Dict[int, float]:
        """Recorded creation time (unix) per snapshot generation, from the
        manifest.  Generations committed before births were recorded are
        simply absent — callers fall back to (clamped) file mtime."""
        manifest = self.read_manifest()
        if not manifest:
            return {}
        out: Dict[int, float] = {}
        for entry in manifest.get("snapshots", []):
            try:
                if "born_unix" in entry:
                    out[int(entry["wal_through"])] = float(entry["born_unix"])
            except (TypeError, ValueError, KeyError):
                continue
        return out

    def read_manifest(self) -> Optional[dict]:
        path = os.path.join(self.dir, MANIFEST_NAME)
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return msgpack.unpackb(f.read(), raw=False)
