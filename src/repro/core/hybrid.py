"""Hybrid retrieval: cosine similarity over triple embeddings + BM25 keyword
matching (paper §3.3), fused by weighted reciprocal-rank fusion."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def rrf_fuse(rankings: Sequence[Sequence[int]], weights: Sequence[float] = None,
             c: float = 60.0) -> List[Tuple[int, float]]:
    """Weighted reciprocal-rank fusion.  rankings: lists of doc ids, best
    first.  Returns (doc_id, fused_score) sorted descending.  Within one
    ranking only a doc's best (first) rank counts — a duplicated id must not
    accumulate score, or any upstream bug that emits duplicates silently
    inflates that doc's fused rank."""
    weights = weights or [1.0] * len(rankings)
    scores: Dict[int, float] = {}
    for ranking, w in zip(rankings, weights):
        seen = set()
        for rank, doc in enumerate(ranking):
            if doc < 0 or doc in seen:
                continue
            seen.add(doc)
            scores[doc] = scores.get(doc, 0.0) + w / (c + rank + 1.0)
    return sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))


def hybrid_search(query_text: str, query_vec, vindex, bm25, top_k: int = 24,
                  dense_weight: float = 1.0, sparse_weight: float = 0.7,
                  pool: int = 64) -> List[Tuple[int, float]]:
    """Returns [(triple_id, fused_score)] best-first, length <= top_k."""
    if vindex.n == 0:
        return []
    pool = min(pool, vindex.n)
    _, dense_ids = vindex.search(query_vec, k=pool)
    dense_rank = [int(i) for i in dense_ids[0] if i >= 0]
    _, sparse_ids = bm25.topk(query_text, k=pool)
    sparse_rank = [int(i) for i in sparse_ids]
    fused = rrf_fuse([dense_rank, sparse_rank],
                     weights=[dense_weight, sparse_weight])
    return fused[:top_k]
