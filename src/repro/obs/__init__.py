"""Observability: the telemetry registry (metrics + traces + events)."""
from repro.obs.telemetry import (Counter, Histogram, Span,  # noqa: F401
                                 Telemetry, Trace, get_telemetry,
                                 new_request_id, set_telemetry, span_names,
                                 walk_spans)
