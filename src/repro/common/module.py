"""Minimal functional module system.

Layers describe their parameters as trees of `ParamSpec` (shape + logical
axes + init law).  `materialize` turns a spec tree into arrays with
path-deterministic RNG; `axes_of` extracts the logical-axes tree used by the
partitioner; `stack` prepends a scanned-layers dimension.  This keeps a single
source of truth for shape, init and sharding without a framework dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import fold_key

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | scaled_normal
    scale: float = 0.02
    dtype: Optional[Any] = None   # overrides the model param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def stack(spec_tree: PyTree, n: int) -> PyTree:
    """Prepend a scanned-layers dim to every spec in the tree."""
    def _stack(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(n, *s.shape), axes=("layers", *s.axes))
    return jax.tree.map(_stack, spec_tree, is_leaf=is_spec)


def _init_leaf(key: jax.Array, spec: ParamSpec, default_dtype) -> jax.Array:
    dtype = spec.dtype or default_dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "scaled_normal":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / np.sqrt(max(1, fan_in))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dtype)
    if spec.init == "rglru_lambda":
        # RG-LRU Λ init: uniform such that a = sigmoid(Λ) in [0.9, 0.999].
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        return jnp.log(u / (1.0 - u)).astype(dtype)
    if spec.init == "ssm_alog":
        # Mamba2 A_log init: A in [1, 16], store log A.
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "ssm_dt_bias":
        # dt bias init so softplus(dt_bias) in [1e-3, 1e-1].
        u = jax.random.uniform(key, spec.shape, jnp.float32, np.log(1e-3), np.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def materialize(key: jax.Array, spec_tree: PyTree, param_dtype=jnp.float32) -> PyTree:
    """Spec tree -> array tree, RNG keyed by tree path (order-independent)."""
    def _leaf(path, spec):
        k = fold_key(key, *[str(getattr(p, "key", getattr(p, "idx", p))) for p in path])
        return _init_leaf(k, spec, param_dtype)
    return jax.tree_util.tree_map_with_path(_leaf, spec_tree, is_leaf=is_spec)


def abstract(spec_tree: PyTree, param_dtype=jnp.float32) -> PyTree:
    """Spec tree -> ShapeDtypeStruct tree (no allocation) for AOT lowering."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or param_dtype),
        spec_tree, is_leaf=is_spec,
    )


def axes_of(spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def spec_tree_to_pspecs(spec_tree: PyTree, rules) -> PyTree:
    """Spec tree -> PartitionSpec tree via MeshRules (divisibility-guarded)."""
    return jax.tree.map(
        lambda s: rules.spec_for(s.axes, s.shape), spec_tree, is_leaf=is_spec
    )


def shardings_of(spec_tree: PyTree, rules) -> PyTree:
    return jax.tree.map(
        lambda s: rules.sharding_for(s.axes, s.shape), spec_tree, is_leaf=is_spec
    )
