"""Lifecycle runtime soak benchmark.

Sustained ingest + retrieve with the WHOLE runtime live — background
flusher, bounded-queue backpressure, auto-compaction and snapshot rotation
all running against a durable directory — measuring what the lifecycle
subsystem actually promises:

* enqueue stays amortized O(1) for the client: p50/p99 per-enqueue latency
  while the daemon drains the queue behind it;
* retrieval keeps answering concurrently (p50/p99 per-batch latency);
* recovery is fast and *correct*: after the soak the directory is recovered
  (newest snapshot + WAL replay), timed, and the recovered service's
  answers are verified identical to the live one's.

    PYTHONPATH=src python benchmarks/lifecycle_bench.py \
        [--seconds 6] [--tenants 16] [--flush-interval 0.05] \
        [--max-pending 512] [--json BENCH_lifecycle.json]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import LifecyclePolicy, MemoryService, Message
from repro.core.embedder import HashEmbedder

CITIES = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi", "Windhoek",
          "Sapporo"]
PETS = ["parrot", "gecko", "hedgehog", "magpie", "ferret", "otter"]


def _pcts(xs):
    if not xs:
        return {"p50_us": None, "p99_us": None, "mean_us": None}
    a = np.asarray(xs) * 1e6
    return {"p50_us": float(np.percentile(a, 50)),
            "p99_us": float(np.percentile(a, 99)),
            "mean_us": float(a.mean())}


def run(seconds: float = 6.0, tenants: int = 16,
        flush_interval: float = 0.05, max_pending: int = 512,
        snapshot_interval: float = 2.0, json_path=None,
        data_dir=None) -> dict:
    own_dir = data_dir is None
    data_dir = data_dir or tempfile.mkdtemp(prefix="memori-lifecycle-")
    policy = LifecyclePolicy(
        flush_interval_s=flush_interval, max_pending=max_pending,
        backpressure="block", compact_tombstone_ratio=0.2,
        compact_min_tombstones=8, compact_idle_s=0.0,
        snapshot_interval_s=snapshot_interval, snapshot_retain=2,
        tick_s=0.01)
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800,
                        policy=policy, data_dir=os.path.join(data_dir, "d"))
    print(f"# Lifecycle soak: {seconds:.0f}s, {tenants} tenants, "
          f"flush_interval={flush_interval}s, max_pending={max_pending}, "
          f"snapshot_interval={snapshot_interval}s")
    enq_lat, ret_lat = [], []
    i, t_end = 0, time.perf_counter() + seconds
    while time.perf_counter() < t_end:
        ns = f"u{i % tenants}/c0"
        msgs = [Message("U", f"I live in {CITIES[i % len(CITIES)]}.",
                        1700000000.0 + i),
                Message("U", f"I adopted a {PETS[i % len(PETS)]} named "
                        f"N{i}.", 1700000000.0 + i)]
        t0 = time.perf_counter()
        svc.enqueue(ns, f"s{i}", msgs)
        enq_lat.append(time.perf_counter() - t0)
        if i % 16 == 15:             # interleaved reads (flush + search)
            batch = [(f"u{j % tenants}/c0",
                      "Which city does the user live in?")
                     for j in range(i, i + 4)]
            t0 = time.perf_counter()
            svc.retrieve_batch(batch)
            ret_lat.append(time.perf_counter() - t0)
        if i % 64 == 63:             # churn for the auto-compactor
            svc.evict(f"u{i % tenants}/c0")
        i += 1
    st = svc.stats()
    live_answers = [c.text for c in svc.retrieve_batch(
        [(f"u{j}/c0", "Which city does the user live in?")
         for j in range(tenants)])]
    # handoff without a final snapshot: recovery must work from whatever
    # the runtime had made durable plus the final flush segment.  Stop the
    # daemon first — recovery may not race a live writer's rotation (a
    # directory has one writer at a time; see docs/OPERATIONS.md)
    svc.close(final_snapshot=False)
    rt_stats = st["lifecycle"]
    t0 = time.perf_counter()
    recovered = MemoryService.recover(os.path.join(data_dir, "d"),
                                      HashEmbedder(), use_kernel=False,
                                      budget=800)
    t_recover = time.perf_counter() - t0
    rec_answers = [c.text for c in recovered.retrieve_batch(
        [(f"u{j}/c0", "Which city does the user live in?")
         for j in range(tenants)])]
    identical = rec_answers == live_answers
    report = {
        "seconds": seconds, "tenants": tenants,
        "sessions_enqueued": i,
        "enqueue": _pcts(enq_lat),
        "retrieve_batch4": _pcts(ret_lat),
        "flushes": rt_stats["flushes"],
        "auto_compactions": rt_stats["auto_compactions"],
        "rotations": rt_stats["rotations"],
        "wal_segments_at_end": st["wal_segments"],
        "bank_rows": st["bank_rows"],
        "recovery_s": t_recover,
        "recovered_identical": identical,
    }
    print(f"sessions {i}: enqueue p50 {report['enqueue']['p50_us']:.0f}us "
          f"p99 {report['enqueue']['p99_us']:.0f}us | retrieve(B=4) p50 "
          f"{report['retrieve_batch4']['p50_us']:.0f}us | flushes "
          f"{report['flushes']}, compactions {report['auto_compactions']}, "
          f"rotations {report['rotations']}")
    print(f"recovery: {t_recover*1e3:.0f}ms for {st['bank_rows']} rows, "
          f"identical={identical}")
    if not identical:
        raise AssertionError("recovered service diverged from the live one")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    if own_dir:
        shutil.rmtree(data_dir, ignore_errors=True)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=6.0)
    ap.add_argument("--tenants", type=int, default=16)
    ap.add_argument("--flush-interval", type=float, default=0.05)
    ap.add_argument("--max-pending", type=int, default=512)
    ap.add_argument("--snapshot-interval", type=float, default=2.0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_lifecycle.json artifact")
    args = ap.parse_args()
    run(seconds=args.seconds, tenants=args.tenants,
        flush_interval=args.flush_interval, max_pending=args.max_pending,
        snapshot_interval=args.snapshot_interval, json_path=args.json)
