"""MemoryService — the multi-tenant memory layer (ROADMAP north-star).

MemoriMemory is single-tenant: one object, one bank, one kernel launch per
query.  A production deployment serves millions of (user, conversation)
namespaces, and the amortization that makes that affordable on TPU is
*batching*: pending queries across tenants are embedded in ONE
`embed_texts` call and scored in ONE namespace-masked `topk_mips` launch
against a packed multi-tenant bank (per-row namespace ids; cross-namespace
hits masked to NEG_INF before the top-k merge — kernels/topk_mips.py).

Isolation invariants:
  * a triple recorded under namespace A can never surface for namespace B
    (dense path: kernel mask; sparse path: BM25 per-namespace scoping);
  * `retrieve_batch([(ns, q), ...])` returns results identical to the same
    retrieves issued sequentially (asserted in tests/test_service.py);
  * tombstoned rows (evict / evict_superseded) never surface again, and
    their vectors are physically zeroed.

`namespace(name)` returns a MemoriMemory-compatible view, so MemoriClient
and the serving launchers run against the service unchanged.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.bm25 import BM25Index
from repro.core.budget import TokenBudgeter
from repro.core.extraction import Extractor, Message, RuleExtractor
from repro.core.hybrid import rrf_fuse
from repro.core.memory import ANSWER_PROMPT, MemoriMemory, RetrievedContext
from repro.core.summaries import Summary, SummaryStore
from repro.core.triples import Triple, TripleStore
from repro.core.vector_index import VectorIndex
from repro.data.tokenizer import HashTokenizer, default_tokenizer


@dataclasses.dataclass
class _Tenant:
    """Per-namespace state.  Bank rows and BM25 doc ids share one global id
    space (row == doc id); `rows[local_tid] -> global row` maps back."""
    ns_id: int
    triples: TripleStore = dataclasses.field(default_factory=TripleStore)
    summaries: SummaryStore = dataclasses.field(default_factory=SummaryStore)
    rows: List[int] = dataclasses.field(default_factory=list)
    evicted: Set[int] = dataclasses.field(default_factory=set)  # local tids


class MemoryService:
    def __init__(self, embedder, extractor: Optional[Extractor] = None,
                 dim: int = 256, budget: int = 1300, top_k: int = 10,
                 tokenizer: HashTokenizer | None = None,
                 use_kernel: bool = True,
                 dense_weight: float = 1.0, sparse_weight: float = 0.7,
                 pool: int = 64):
        self.embedder = embedder
        self.extractor = extractor or RuleExtractor()
        self.tokenizer = tokenizer or default_tokenizer()
        self.budgeter = TokenBudgeter(budget=budget, tokenizer=self.tokenizer)
        self.top_k = top_k
        self.dense_weight = dense_weight
        self.sparse_weight = sparse_weight
        self.pool = pool
        self.vindex = VectorIndex(dim=dim, use_kernel=use_kernel)
        self.bm25 = BM25Index(tokenizer=self.tokenizer)
        self._tenants: Dict[str, _Tenant] = {}
        self._ns_ids: Dict[str, int] = {}      # survives evict(): tombstoned
        #                                        rows keep a retired ns id
        self._row_ns: List[int] = []           # global row -> namespace id
        self._row_tid: List[int] = []          # global row -> local tid

    # -- tenancy -----------------------------------------------------------
    def _tenant(self, namespace: str) -> _Tenant:
        t = self._tenants.get(namespace)
        if t is None:
            ns_id = self._ns_ids.setdefault(namespace, len(self._ns_ids))
            t = self._tenants[namespace] = _Tenant(ns_id=ns_id)
        return t

    def namespaces(self) -> List[str]:
        return list(self._tenants)

    def namespace(self, name: str) -> "NamespaceView":
        return NamespaceView(self, name)

    # -- write path ----------------------------------------------------------
    def record(self, namespace: str, session_id: str,
               messages: Sequence[Message]) -> Tuple[List[Triple], Summary]:
        """Ingest one session for one tenant: extract triples + summary,
        embed in one call, append to the packed bank / scoped BM25."""
        t = self._tenant(namespace)
        triples, summary = self.extractor.extract(namespace, session_id,
                                                  messages)
        t.summaries.add(summary)
        if triples:
            texts = [tr.text() for tr in triples]
            vecs = self.embedder.embed_texts(texts)
            rows = self.vindex.add(vecs)
            bids = self.bm25.add(texts, namespace=t.ns_id)
            for tr, row, bid in zip(triples, rows, bids):
                tid = t.triples.add(tr)
                # global row, BM25 doc id and row-table slots stay aligned
                assert int(row) == int(bid) == len(self._row_ns)
                t.rows.append(int(row))
                self._row_ns.append(t.ns_id)
                self._row_tid.append(tid)
        return triples, summary

    # -- read path -------------------------------------------------------------
    def retrieve(self, namespace: str, query: str,
                 top_k: Optional[int] = None) -> RetrievedContext:
        return self.retrieve_batch([(namespace, query)], top_k=top_k)[0]

    def retrieve_batch(self, requests: Sequence[Tuple[str, str]],
                       top_k: Optional[int] = None) -> List[RetrievedContext]:
        """[(namespace, query), ...] -> per-request RetrievedContext.

        The cross-tenant hot path: one embed_texts call for every pending
        query, one masked topk_mips launch against the packed bank.  The
        per-request results are identical to sequential retrieve() calls."""
        if not requests:
            return []
        k = top_k or self.top_k
        # reads never allocate tenant state: unknown namespaces stay unknown
        # (no leak from typo'd/adversarial queries, evict() stays evicted)
        tenants = [self._tenants.get(ns) for ns, _ in requests]
        qvecs = self.embedder.embed_texts([q for _, q in requests])
        dense_ids = None
        if self.vindex.n and self.vindex.n_alive:
            # unknown tenants get a never-assigned ns id (>= 0, so it can't
            # collide with the -1 tombstone label): they match no bank row
            unused = len(self._ns_ids)
            q_ns = np.asarray([t.ns_id if t else unused for t in tenants],
                              np.int32)
            row_ns = np.asarray(self._row_ns, np.int32)
            pool = min(self.pool, self.vindex.n)
            _, dense_ids = self.vindex.search_masked(qvecs, q_ns, row_ns,
                                                     k=pool)
        out: List[RetrievedContext] = []
        for r, ((ns, qtext), t) in enumerate(zip(requests, tenants)):
            if t is None:
                text = MemoriMemory.render([], [])
                out.append(RetrievedContext([], [], text,
                                            self.tokenizer.count(text)))
                continue
            dense_rank = [] if dense_ids is None else \
                [int(i) for i in dense_ids[r] if i >= 0]
            _, sparse_ids = self.bm25.topk(qtext, k=self.pool,
                                           namespace=t.ns_id)
            sparse_rank = [int(i) for i in sparse_ids]
            fused = rrf_fuse([dense_rank, sparse_rank],
                             weights=[self.dense_weight, self.sparse_weight])
            scored = [(t.triples.get(self._row_tid[g]), score)
                      for g, score in fused[:k]]
            ctx = self.budgeter.select(scored, t.summaries)
            text = MemoriMemory.render(ctx.triples, ctx.summaries)
            out.append(RetrievedContext(ctx.triples, ctx.summaries, text,
                                        self.tokenizer.count(text)))
        return out

    def answer_prompt(self, namespace: str, question: str
                      ) -> Tuple[str, RetrievedContext]:
        ctx = self.retrieve(namespace, question)
        return ANSWER_PROMPT.format(memories=ctx.text,
                                    question=question), ctx

    # -- eviction ----------------------------------------------------------------
    def evict(self, namespace: str) -> int:
        """Drop a whole tenant: tombstone its bank rows + BM25 docs, free its
        stores.  Returns the number of rows evicted."""
        t = self._tenants.pop(namespace, None)
        if t is None:
            return 0
        live = [row for tid, row in enumerate(t.rows) if tid not in t.evicted]
        self.vindex.delete(live)
        self.bm25.remove(live)
        return len(live)

    def evict_superseded(self, namespace: str) -> int:
        """Physically evict triples superseded under conflict resolution
        (triples.latest_for_key keeps the newest version of every
        (subject, predicate) key; the older versions leave the indices)."""
        t = self._tenants.get(namespace)
        if t is None:
            return 0
        fresh = [tid for tid in t.triples.superseded_ids()
                 if tid not in t.evicted]
        rows = [t.rows[tid] for tid in fresh]
        self.vindex.delete(rows)
        self.bm25.remove(rows)
        t.evicted.update(fresh)
        return len(fresh)

    # -- stats ----------------------------------------------------------------------
    def stats(self) -> dict:
        per_ns = {
            ns: {
                "triples": len(t.triples),
                "summaries": len(t.summaries),
                "evicted": len(t.evicted),
            } for ns, t in self._tenants.items()
        }
        return {
            "namespaces": len(self._tenants),
            "bank_rows": self.vindex.n,
            "alive_rows": self.vindex.n_alive,
            "tombstones": self.vindex.n_dead,
            "bm25_docs": len(self.bm25),
            "per_namespace": per_ns,
        }


class NamespaceView:
    """MemoriMemory-compatible facade over one namespace of a MemoryService:
    MemoriClient (and anything else written against MemoriMemory's surface)
    runs on the shared service unchanged.  The namespace key IS the
    conversation scope, so record_session's conversation_id is subsumed by
    it (kept in the signature for drop-in compatibility)."""

    def __init__(self, service: MemoryService, namespace: str):
        self.service = service
        self.namespace = namespace
        self._seen_conversation_id: Optional[str] = None

    def record_session(self, conversation_id: str, session_id: str,
                       messages: Sequence[Message]):
        # the namespace key IS the scope, so conversation_id is otherwise
        # ignored — warn a drop-in caller who reuses one view across several
        # conversation_ids, since those scopes silently merge here
        if self._seen_conversation_id is None:
            self._seen_conversation_id = conversation_id
        elif conversation_id != self._seen_conversation_id:
            warnings.warn(
                f"NamespaceView({self.namespace!r}) saw conversation_id="
                f"{conversation_id!r} after {self._seen_conversation_id!r}: "
                "both record into the same namespace scope — use "
                f"service.namespace({conversation_id!r}) for a separate "
                "scope.", stacklevel=2)
        return self.service.record(self.namespace, session_id, messages)

    def retrieve(self, query: str,
                 top_k: Optional[int] = None) -> RetrievedContext:
        return self.service.retrieve(self.namespace, query, top_k=top_k)

    def answer_prompt(self, question: str) -> Tuple[str, RetrievedContext]:
        return self.service.answer_prompt(self.namespace, question)

    def stats(self) -> dict:
        t = self.service._tenants.get(self.namespace)
        if t is None:
            return {"triples": 0, "summaries": 0, "evicted": 0}
        return {"triples": len(t.triples), "summaries": len(t.summaries),
                "evicted": len(t.evicted)}
