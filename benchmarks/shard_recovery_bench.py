"""Kill-a-shard recovery soak benchmark.

Sustained ingest + retrieve against a SHARDED store with a durable
directory and a follower sink attached (every sealed WAL segment — shard
logs included — streams to the follower), then the failure drill the
replication layer exists for:

* **degraded-mode availability**: with one shard marked down, what
  fraction of a full-fleet retrieval batch still answers with data (the
  survivors must be bit-identical to the healthy baseline, the victims
  flagged `degraded`, and nothing may fail);
* **recovery time**: lose the shard's disk outright (`rm -rf shard-01/`),
  re-materialize it from the follower's shipped segments, and recover —
  timed end to end;
* **the correctness gate**: the recovered service must answer
  bit-identically to the live one (per-tenant retrieval texts AND the
  sha256 of the bank-row prefix).  CI fails on any divergence.

    PYTHONPATH=src python benchmarks/shard_recovery_bench.py \
        [--seconds 4] [--shards 2] [--tenants 8] \
        [--json BENCH_shard_recovery.json]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro.checkpoint.replication import (DirectorySink,
                                          restore_missing_from_follower)
from repro.core import MemoryService, Message
from repro.core.embedder import HashEmbedder

CITIES = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi", "Windhoek",
          "Sapporo"]
PETS = ["parrot", "gecko", "hedgehog", "magpie", "ferret", "otter"]
QUERY = "Which city does the user live in?"


def _pcts(xs):
    if not xs:
        return {"p50_us": None, "p99_us": None}
    a = np.asarray(xs) * 1e6
    return {"p50_us": float(np.percentile(a, 50)),
            "p99_us": float(np.percentile(a, 99))}


def _bank_sha(svc, rows=None):
    bank = np.ascontiguousarray(
        svc.vindex.bank if rows is None else svc.vindex.bank[:rows])
    return hashlib.sha256(bank.tobytes()).hexdigest()


def run(seconds: float = 4.0, shards: int = 2, tenants: int = 8,
        json_path=None, data_dir=None) -> dict:
    own_dir = data_dir is None
    root = data_dir or tempfile.mkdtemp(prefix="memori-shardrec-")
    d = os.path.join(root, "data")
    follower = os.path.join(root, "follower")
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800,
                        shards=shards, data_dir=d)
    svc.attach_follower(follower)             # sync: RPO = 0 segments
    print(f"# Shard recovery soak: {seconds:.0f}s, shards={shards}, "
          f"tenants={tenants}, follower={follower}")

    # -- soak: flush-per-session ingest with interleaved reads -------------
    i, t_end = 0, time.perf_counter() + seconds
    while time.perf_counter() < t_end:
        ns = f"u{i % tenants}/c0"
        svc.enqueue(ns, f"s{i}", [
            Message("U", f"I live in {CITIES[i % len(CITIES)]}.",
                    1700000000.0 + i),
            Message("U", f"I adopted a {PETS[i % len(PETS)]} named N{i}.",
                    1700000000.0 + i)])
        svc.flush()          # durable: shard parts + cross-shard commit
        if i == 2:
            svc.rotate()     # one mid-soak snapshot generation
        i += 1
    queries = [(f"u{j}/c0", QUERY) for j in range(tenants)]
    live = [c.text for c in svc.retrieve_batch(queries)]
    bank_rows = int(svc.vindex.n)
    live_sha = _bank_sha(svc)
    shipped = svc.stats().get("replication") or {}

    # -- degraded mode: one shard down, survivors keep answering -----------
    down = 1 % shards
    victims = [j for j in range(tenants)
               if svc.store.shard_of_namespace(f"u{j}/c0") == down]
    svc.set_shard_down(down)
    deg_lat, answered, flagged = [], 0, 0
    for _ in range(20):
        t0 = time.perf_counter()
        got = svc.retrieve_batch(queries)
        deg_lat.append(time.perf_counter() - t0)
        for j, c in enumerate(got):
            if c.degraded:
                flagged += 1
            else:
                answered += 1
                if c.text != live[j]:
                    raise AssertionError(
                        f"survivor u{j} diverged in degraded mode")
    total = 20 * tenants
    availability = answered / total
    assert flagged == 20 * len(victims), "degraded flags != downed tenants"
    svc.set_shard_up(down)
    print(f"degraded mode: {availability:.0%} of requests answered with "
          f"shard {down} down ({len(victims)}/{tenants} tenants flagged), "
          f"batch p50 {_pcts(deg_lat)['p50_us']:.0f}us")

    # -- kill the shard's disk, restore from follower, recover -------------
    svc.close(final_snapshot=False)
    shard_dir = os.path.join(d, f"shard-{down:02d}")
    shutil.rmtree(shard_dir)
    t0 = time.perf_counter()
    restored = restore_missing_from_follower(DirectorySink(follower), d)
    t_restore = time.perf_counter() - t0
    t0 = time.perf_counter()
    recovered = MemoryService.recover(d, HashEmbedder(), use_kernel=False,
                                      budget=800)
    t_recover = time.perf_counter() - t0
    rec = [c.text for c in recovered.retrieve_batch(queries)]
    texts_identical = rec == live
    bank_identical = (int(recovered.vindex.n) == bank_rows
                      and _bank_sha(recovered) == live_sha)
    recovered.close(final_snapshot=False)

    report = {
        "seconds": seconds, "shards": shards, "tenants": tenants,
        "sessions_flushed": i, "bank_rows": bank_rows,
        "segments_shipped": shipped.get("shipped"),
        "ship_failures": shipped.get("failed"),
        "degraded_availability": availability,
        "degraded_batch": _pcts(deg_lat),
        "restore_files": len(restored),
        "restore_s": t_restore,
        "recovery_s": t_recover,
        "recovered_texts_identical": texts_identical,
        "recovered_bank_identical": bank_identical,
    }
    print(f"sessions {i}, bank rows {bank_rows}: shipped "
          f"{shipped.get('shipped')} segments ({shipped.get('failed')} "
          f"failed)")
    print(f"recovery: restored {len(restored)} files from follower in "
          f"{t_restore*1e3:.0f}ms, recovered in {t_recover*1e3:.0f}ms, "
          f"texts_identical={texts_identical} "
          f"bank_identical={bank_identical}")
    if not (texts_identical and bank_identical):
        raise AssertionError(
            "recovered service diverged from the live one after "
            "kill-a-shard recovery")
    if shipped.get("failed"):
        raise AssertionError(f"{shipped['failed']} WAL segments failed to "
                             "ship during the soak")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    if own_dir:
        shutil.rmtree(root, ignore_errors=True)
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=4.0)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_shard_recovery.json artifact")
    args = ap.parse_args()
    run(seconds=args.seconds, shards=args.shards, tenants=args.tenants,
        json_path=args.json)
