"""Correctness of the §Perf hillclimb variants: every optimisation must match
its baseline numerically (exactly for MoE-local at drop-free capacity and MLA
absorption, to tolerance for int8 KV)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model_api import Model

KEY = jax.random.PRNGKey(11)


def _moe_cfg(dispatch, shards=2):
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     dispatch=dispatch, local_shards=shards))


def test_moe_local_dispatch_matches_global():
    """Drop-free capacity => identical routing => identical outputs."""
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 4, 512)}
    cfg_g = _moe_cfg("global")
    cfg_l = _moe_cfg("local", shards=2)
    model_g, model_l = Model(cfg_g), Model(cfg_l)
    params = model_g.init_params(KEY)          # same spec tree for both
    lg, _ = model_g.train_loss(params, batch)
    ll, _ = model_l.train_loss(params, batch)
    np.testing.assert_allclose(float(lg), float(ll), rtol=1e-5)


def test_mla_absorbed_train_matches_decompressed():
    cfg = get_config("deepseek-v3-671b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    cfg_a = dataclasses.replace(cfg, mla_absorbed_train=True)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 4, 512)}
    params = Model(cfg).init_params(KEY)
    l0, _ = Model(cfg).train_loss(params, batch)
    l1, _ = Model(cfg_a).train_loss(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=2e-4)


def test_kv_int8_decode_close_to_fp():
    cfg = get_config("qwen3-8b").reduced()
    cfg_q = dataclasses.replace(cfg, kv_cache_quant="int8")
    S = 12
    toks = jax.random.randint(KEY, (2, S + 1), 4, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    params = Model(cfg).init_params(KEY)

    _, c0 = Model(cfg).prefill(params, batch)
    c0 = Model(cfg).prepare_decode_caches(c0, S, S + 4)
    ref, _ = Model(cfg).decode_step(params, toks[:, S:S + 1], c0, jnp.int32(S))

    mq = Model(cfg_q)
    _, c1 = mq.prefill(params, batch)
    c1 = mq.prepare_decode_caches(c1, S, S + 4)
    got, _ = mq.decode_step(params, toks[:, S:S + 1], c1, jnp.int32(S))

    # int8 cache: probabilities shift slightly; logits stay close
    err = float(jnp.max(jnp.abs(got - ref)))
    denom = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert err / denom < 0.05, (err, denom)
