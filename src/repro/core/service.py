"""MemoryService — the multi-tenant memory layer (ROADMAP north-star).

MemoriMemory is single-tenant: one object, one bank, one kernel launch per
query.  A production deployment serves millions of (user, conversation)
namespaces, and the amortization that makes that affordable on TPU is
*batching*: pending queries across tenants are embedded in ONE
`embed_texts` call and scored in ONE namespace-masked `topk_mips` launch
against a packed multi-tenant bank (per-row namespace ids; cross-namespace
hits masked to NEG_INF before the top-k merge — kernels/topk_mips.py), the
sparse side is ONE stacked (B, N) BM25 scoring op with per-query namespace
masks, and the dense/sparse rankings fuse in ONE on-device
`rrf_fuse_batch` (core/hybrid.py).  The bank, its alive/namespace labels
and the row-count all live device-resident (core/vector_index.py): a
steady-state `retrieve_batch` issues zero bank H2D transfers and zero
recompiles while the bank grows within a power-of-two capacity bucket.
Writes amortize the same way: `enqueue()` queues sessions for free and
`flush()` ingests everything pending across all tenants through one
`embed_texts` call and one in-place device bank append (`record()` is the
synchronous enqueue-then-flush).

Storage — the packed bank, the BM25 corpus, the per-tenant triple/summary
stores and the row↔namespace↔triple mapping — lives in `core/store.py`'s
MemoryStore, which also provides `compact()` (tombstone reclamation with
row-id remapping) and `snapshot()` / `MemoryService.restore()` persistence.
Everything that happens *between* requests — WAL-backed incremental
persistence, the time-based background flusher with backpressure,
auto-compaction and snapshot rotation — lives in `core/lifecycle.py`'s
LifecycleRuntime; pass `policy=`/`data_dir=` to mount one (or
`MemoryService.recover(data_dir, ...)` to come back after a crash), and the
service routes writes, maintenance and the read path through its lock.

Public-facing batch sizes are ragged, so `retrieve_batch` pads every batch
to the next power-of-two Q bucket (padded queries carry a never-assigned
namespace id and match nothing): the whole read path — masked `topk_mips`,
stacked BM25, on-device RRF — sees only bucketed shapes, bounding the
executable count regardless of traffic shape.

Isolation invariants:
  * a triple recorded under namespace A can never surface for namespace B
    (dense path: kernel mask; sparse path: BM25 per-namespace scoping);
  * `retrieve_batch([(ns, q), ...])` returns results identical to the same
    retrieves issued sequentially (asserted in tests/test_service.py);
  * tombstoned rows (evict / evict_superseded) never surface again, and
    their vectors are physically zeroed (compact() then reclaims them).

The public surface is typed (core/api.py): `retrieve_batch` takes
`RetrieveRequest`s (tuples still accepted) and runs them through an
explicit `RetrievalPlan` — embed → dense → sparse → fuse → budget, with
dense-only / sparse-only / raw (no-budget) variants — in `execute()`, the
engine behind every read.  Per-request `top_k`, dense/sparse weights and
stage sets are honored inside the shared launches (fusion at max(k) +
per-row slicing, a (B, R) weight matrix, -1-masked rankings).  Mount a
`MemoryScheduler` (`start_scheduler()`, core/scheduler.py) and the sync
wrappers coalesce concurrent clients' single requests into one batched
launch per tick — continuous batching for memory ops.

`namespace(name)` returns a MemoriMemory-compatible view, so MemoriClient
and the serving launchers run against the service unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from typing import Any, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2
from repro.core.admission import AdmissionError
from repro.core.api import (RawRetrieval, RetrievalPlan, RetrieveRequest,
                            as_retrieve_request)
from repro.core.budget import TokenBudgeter
from repro.core.extraction import Extractor, Message
from repro.core.hybrid import rrf_fuse_batch
from repro.core.lifecycle import LifecyclePolicy, LifecycleRuntime
from repro.core.memory import ANSWER_PROMPT, MemoriMemory, RetrievedContext
from repro.core.store import MemoryStore
from repro.core.summaries import Summary
from repro.core.triples import Triple
from repro.data.tokenizer import HashTokenizer
from repro.obs.telemetry import (GRAPH_EXPAND_LATENCY, RECORD_LATENCY,
                                 RETRIEVE_LATENCY, get_telemetry)

# graph-stage fallbacks when neither the request nor the plan sets them:
# 2 hops reaches friend-of-a-fact chains, causal/temporal edges slightly
# discounted against direct co-occurrence, and the expanded ranking fuses
# below the dense column's weight (it corroborates, it does not dominate)
_GRAPH_HOPS = 2
_GRAPH_EDGE_WEIGHTS = (1.0, 0.9, 0.9)
_GRAPH_WEIGHT = 0.6


@dataclasses.dataclass(frozen=True)
class _Resolved:
    """One request's options after plan/service defaults are folded in."""
    k: int
    dense_weight: float
    sparse_weight: float
    dense: bool
    sparse: bool
    graph: bool
    budget: bool
    hops: int = _GRAPH_HOPS
    edge_weights: Tuple[float, float, float] = _GRAPH_EDGE_WEIGHTS
    graph_weight: float = _GRAPH_WEIGHT


class MemoryService:
    def __init__(self, embedder=None, extractor: Optional[Extractor] = None,
                 dim: int = 256, budget: int = 1300, top_k: int = 10,
                 tokenizer: HashTokenizer | None = None,
                 use_kernel: bool = True,
                 dense_weight: float = 1.0, sparse_weight: float = 0.7,
                 pool: int = 64, flush_every: Optional[int] = None,
                 store: Optional[MemoryStore] = None,
                 policy: Optional[LifecyclePolicy] = None,
                 data_dir: Optional[str] = None,
                 runtime: Optional[LifecycleRuntime] = None,
                 plan: Optional[RetrievalPlan] = None,
                 quantize: str = "none", rescore: int = 4,
                 shards: int = 1, mesh=None):
        if store is None and runtime is not None:
            store = runtime.store
        if store is None:
            if embedder is None:
                raise ValueError("MemoryService needs an embedder or a store")
            store = MemoryStore(embedder, extractor, dim=dim,
                                use_kernel=use_kernel, tokenizer=tokenizer,
                                quantize=quantize, rescore=rescore,
                                shards=shards, mesh=mesh)
        self.store = store
        self.embedder = store.embedder
        self.extractor = store.extractor
        self.tokenizer = store.tokenizer
        self.budgeter = TokenBudgeter(budget=budget, tokenizer=self.tokenizer)
        self.top_k = top_k
        self.dense_weight = dense_weight
        self.sparse_weight = sparse_weight
        self.pool = pool
        self.flush_every = flush_every
        self.plan = plan or RetrievalPlan()
        # a mounted MemoryScheduler (core/scheduler.py) re-routes the sync
        # read wrappers through its cross-client micro-batching ticks
        self.scheduler = None
        if runtime is not None:
            if runtime.store is not self.store:
                raise ValueError("runtime is mounted on a different store")
        elif policy is not None or data_dir is not None:
            runtime = LifecycleRuntime(self.store, data_dir=data_dir,
                                       policy=policy)
        self.runtime = runtime

    def _guard(self):
        """The runtime's lock when one is mounted (serializes requests
        against background flush/compaction/rotation), else a no-op."""
        return self.runtime.lock if self.runtime else contextlib.nullcontext()

    # the underlying indices, exposed for tests/benchmarks and the SDK
    @property
    def vindex(self):
        return self.store.vindex

    @property
    def bm25(self):
        return self.store.bm25

    # -- persistence -------------------------------------------------------
    @classmethod
    def restore(cls, path: str, embedder,
                extractor: Optional[Extractor] = None,
                use_kernel: bool = True,
                tokenizer: HashTokenizer | None = None,
                **service_kwargs) -> "MemoryService":
        """Rebuild a service from `snapshot(path)`: the restored service
        answers `retrieve_batch` identically to the one that wrote it.
        `quantize=`/`rescore=` in service_kwargs pick the restored
        index's device residency mode (snapshots are always f32)."""
        store = MemoryStore.restore(
            path, embedder, extractor=extractor, use_kernel=use_kernel,
            tokenizer=tokenizer,
            quantize=service_kwargs.pop("quantize", "none"),
            rescore=service_kwargs.pop("rescore", 4),
            shards=service_kwargs.pop("shards", 1),
            mesh=service_kwargs.pop("mesh", None))
        return cls(store=store, **service_kwargs)

    @classmethod
    def recover(cls, data_dir: str, embedder,
                extractor: Optional[Extractor] = None,
                policy: Optional[LifecyclePolicy] = None,
                use_kernel: bool = True, dim: int = 256,
                tokenizer: HashTokenizer | None = None,
                shards: Optional[int] = None, mesh=None,
                **service_kwargs) -> "MemoryService":
        """Rebuild a service from a lifecycle runtime's durable directory:
        newest restorable snapshot + ordered WAL replay.  The recovered
        service answers `retrieve_batch` bit-identically to the pre-crash
        one up to the last durable flush, and keeps journaling to the same
        directory.  `dim` matters only when the directory holds no
        snapshot yet (the fresh replay store must match the embedder).
        `shards=None` autodetects the sharded WAL layout on disk."""
        rt = LifecycleRuntime.recover(data_dir, embedder,
                                      extractor=extractor, policy=policy,
                                      use_kernel=use_kernel, dim=dim,
                                      tokenizer=tokenizer, shards=shards,
                                      mesh=mesh)
        return cls(runtime=rt, **service_kwargs)

    def snapshot(self, path: str) -> int:
        """Flush pending writes, then persist the whole store to an
        explicit path (manual escape hatch — a mounted runtime's rotation
        is `rotate()`).  Returns bytes written."""
        with self._guard():
            return self.store.snapshot(path)

    def rotate(self) -> dict:
        """Snapshot rotation through the mounted runtime: full snapshot,
        retention pruning, WAL truncation."""
        if self.runtime is None:
            raise RuntimeError("rotate() needs a mounted LifecycleRuntime")
        return self.runtime.rotate()

    def close(self, *, final_snapshot: bool = True) -> None:
        """Stop the mounted scheduler (drains queued requests) and the
        background runtime (final flush + snapshot when durable).  Safe to
        call on a scheduler-less / runtime-less service.  Idempotent."""
        if self.scheduler is not None:
            self.scheduler.close()
        if self.runtime is not None:
            self.runtime.close(final_snapshot=final_snapshot)

    def start_scheduler(self, **kwargs):
        """Mount a MemoryScheduler: from here on the sync read wrappers
        (`retrieve`, `retrieve_batch`) coalesce with every other client's
        concurrent requests into one device launch per tick.  Returns the
        scheduler (also available as `self.scheduler`; the constructor
        refuses to mount over a live one)."""
        from repro.core.scheduler import MemoryScheduler
        return MemoryScheduler(self, **kwargs)

    def __enter__(self) -> "MemoryService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- tenancy -----------------------------------------------------------
    def namespaces(self) -> List[str]:
        with self._guard():
            return self.store.namespaces()

    def namespace(self, name: str) -> "NamespaceView":
        return NamespaceView(self, name)

    # -- write path ----------------------------------------------------------
    def record(self, namespace: str, session_id: str,
               messages: Sequence[Message]) -> Tuple[List[Triple], Summary]:
        """Synchronous ingest of one session: enqueue + flush (one write
        path — anything else pending is drained in the same batch)."""
        t0 = time.perf_counter()
        with self._guard():
            if self.runtime is not None:
                if self.runtime.closed:
                    raise RuntimeError(
                        "service is closed: writes would bypass the "
                        "journal (recover/remount before writing again)")
                self.runtime.note_activity()
            out = self.store.ingest(namespace, session_id, messages)
        get_telemetry().observe(
            RECORD_LATENCY, time.perf_counter() - t0,
            help="synchronous record (enqueue + flush) latency")
        return out

    def enqueue(self, namespace: str, session_id: str,
                messages: Sequence[Message],
                conversation_id: Optional[str] = None) -> None:
        """Async ingest: queue the session for the next `flush()`.  No
        extraction or embedding happens here.  With a mounted runtime the
        queue is bounded and backpressured per policy (the background
        flusher drains it); `flush_every` additionally triggers a
        count-based flush."""
        if self.runtime is not None:
            self.runtime.enqueue(namespace, session_id, messages,
                                 conversation_id=conversation_id)
        else:
            self.store.enqueue(namespace, session_id, messages,
                               conversation_id=conversation_id)
        if self.flush_every and self.store.pending_count >= self.flush_every:
            self.flush()

    def flush(self) -> int:
        """Drain all pending sessions (all tenants) through one embed call
        and one bank append.  Returns the number of sessions ingested."""
        if self.runtime is not None:
            return self.runtime.flush()
        return len(self.store.flush())

    def compact(self) -> dict:
        """Reclaim tombstoned rows (see MemoryStore.compact)."""
        with self._guard():
            return self.store.compact()

    # -- read path -------------------------------------------------------------
    def retrieve(self, namespace: str, query: str,
                 top_k: Optional[int] = None, **options) -> RetrievedContext:
        """Single-tenant retrieve.  Extra keyword options (`dense_weight`,
        `sparse_weight`, `stages`) become per-request RetrieveRequest
        fields.  With a mounted scheduler this coalesces with every other
        client's concurrent request into one device launch."""
        req = RetrieveRequest(namespace=namespace, query=query, top_k=top_k,
                              **options)
        return self.retrieve_batch([req])[0]

    def retrieve_batch(self, requests: Sequence, top_k: Optional[int] = None,
                       plan: Optional[RetrievalPlan] = None) -> List[Any]:
        """Requests -> per-request payloads (RetrievedContext, or
        RawRetrieval for no-budget plans).  Each request is an
        (namespace, query) tuple or a `RetrieveRequest` carrying its own
        `top_k` / weights / stages; the legacy batch-global `top_k` kwarg
        is the per-request default (explicit per-request values win).

        With a mounted MemoryScheduler the batch is submitted to it, so it
        fuses with whatever other clients queued in the same tick;
        otherwise (or with an explicit `plan`) it executes directly.  Either
        way the results are identical to sequential retrieve() calls."""
        reqs = [as_retrieve_request(r, top_k) for r in requests]
        if not reqs:
            return []
        sched = self.scheduler
        if plan is None and sched is not None and sched.can_submit():
            try:
                futures = sched.submit_many(reqs)
            except AdmissionError:
                # a QoS rejection (rate limit / shed) must surface, not
                # sneak through the direct engine — falling back would let
                # every rate-limited caller bypass admission control
                raise
            except RuntimeError:
                # the scheduler closed between can_submit() and the
                # submission (service shutdown racing a reader) — the
                # direct engine still answers
                pass
            else:
                return [f.result().result() for f in futures]
        return self.execute(reqs, plan=plan)

    def execute(self, requests: Sequence[RetrieveRequest],
                plan: Optional[RetrievalPlan] = None) -> List[Any]:
        """The retrieval engine: run a batch of typed requests through the
        plan's stage pipeline in ONE set of device launches.

        The cross-tenant hot path: one embed_texts call for every pending
        query, one stable-shape masked topk_mips launch against the
        device-resident packed bank (cached row labels — no per-call bank
        upload, no label rebuild), one stacked BM25 scoring op for the
        sparse side, and ONE on-device `rrf_fuse_batch` that fuses every
        request at once; the (B, k) fused ranking crosses to the host in a
        single transfer.  Reads are read-your-writes: pending enqueued
        sessions are flushed first.  Per-request options are honored inside
        the shared launches: fusion runs at max(top_k) and each row is
        sliced to its own k; weights ride in as a (B, R) matrix; a request
        excluded from a stage has that ranking's ids masked to -1 (so a
        dense-only request in a mixed batch answers exactly like a
        dense-only batch).  Stages a WHOLE batch skips are never launched.

        Q-shape bucketing: the batch is padded to the next power-of-two
        size before it touches the device (padded queries carry a
        never-assigned namespace id, so they match no row on either side
        and fuse to all -1); a public endpoint serving ragged batch sizes
        therefore mints at most log2(max_B) executables per stage instead
        of one per distinct B."""
        if not requests:
            return []
        tel = get_telemetry()
        t_exec = time.perf_counter()
        plan = plan or self.plan
        reqs = list(requests)
        res = [self._resolve(r, plan) for r in reqs]
        # only the dense search consumes query vectors, so only the
        # requests whose stage set includes it get embedded (a sparse-only
        # batch never embeds at all; excluded rows ride as zero vectors —
        # their dense ranking is masked to -1 regardless).  The (possibly
        # slow, possibly remote) embed call stays OUTSIDE the runtime lock
        # so it never stalls the flusher or blocked enqueuers.
        dense_rows = [i for i, rr in enumerate(res) if rr.dense]
        with tel.span("plan.embed", batch=len(dense_rows), launches=1):
            qvecs = (self.embedder.embed_texts([reqs[i].query
                                                for i in dense_rows])
                     if dense_rows else None)
        with self._guard():
            if self.runtime is not None:
                self.runtime.note_activity()
            if self.store.pending_count:
                # through the runtime when mounted: the read-your-writes
                # drain counts as a flush and wakes blocked enqueuers
                self.flush()
            # reads never allocate tenant state: unknown namespaces stay
            # unknown (no leak from typo'd/adversarial queries, evict()
            # stays evicted)
            tenants = [self.store.get(r.namespace) for r in reqs]
            vindex = self.store.vindex
            tiers = self.store.tiers
            if tiers is not None:
                for t in tenants:
                    if t is not None:
                        tiers.note_retrieve(t.ns_id)
            # graceful degradation: a request whose owning placement shard
            # is down answers empty with degraded=True — BOTH its rankings
            # are masked below, so the surviving requests in the batch are
            # bit-identical to a batch that never contained it
            sharded = self.store.sharded
            if sharded is not None and sharded.down:
                downed = [t is not None
                          and sharded.shard_of(t.ns_id) in sharded.down
                          for t in tenants]
            else:
                downed = [False] * len(reqs)
            B = len(reqs)
            # fuse at the pow2 ceiling of the largest requested k: k is a
            # jit-static arg of the fusion, so bucketing it bounds the
            # executable count under mixed-k traffic (a scheduler tick's
            # max(k) is whatever clients happened to share it) exactly like
            # the Q-shape bucketing below; each row still slices to its own
            # k — the prefix of a wider fusion IS the narrower fusion
            k_fuse = next_pow2(max(r.k for r in res))
            if vindex.n:
                # unknown tenants get a never-assigned ns id (>= 0, so it
                # can't collide with the -1 tombstone label): they match no
                # bank row on the dense side and select no documents on the
                # sparse side.  Padded queries reuse the same id.
                unused = self.store.namespace_id_count()
                ns_ids = [t.ns_id if t else unused for t in tenants]
                Bp = next_pow2(B)
                ns_pad = ns_ids + [unused] * (Bp - B)
                q_ns = np.asarray(ns_pad, np.int32)
                rankings, weight_cols = [], []
                if dense_rows:
                    with tel.span("plan.dense", batch=Bp, pool=self.pool,
                                  launches=1,
                                  sharded=sharded is not None) as sp:
                        qv = np.asarray(qvecs, np.float32)
                        qmat = np.zeros((Bp, qv.shape[1]), np.float32)
                        qmat[dense_rows] = qv
                        if sharded is not None:
                            # shard-wise placement: one launch through the
                            # namespace-masked sharded_topk (local top-k per
                            # shard, gathered + re-ranked globally); ids come
                            # back already in global-row space
                            _, dense_ids = self.store.sharded_search(
                                qmat, q_ns, k=self.pool)
                        else:
                            _, dense_ids = vindex.search_batch(qmat, q_ns,
                                                               k=self.pool)
                        if tiers is not None:
                            # a demoted namespace's rows are absent from the
                            # device bank: answer those requests from the
                            # host-mirror masked search (exact, just not
                            # accelerated) and mark them for promotion — the
                            # next maintenance tick brings the rows back in
                            # one batched upload
                            fb = [i for i in dense_rows
                                  if tenants[i] is not None
                                  and tiers.is_demoted(tenants[i].ns_id)]
                            if fb:
                                sp.set(host_fallbacks=len(fb))
                                _, hi = vindex.search_host(
                                    qmat[fb], q_ns[fb], k=self.pool)
                                dense_ids = np.asarray(dense_ids).copy()
                                dense_ids[fb] = hi
                                for i in fb:
                                    tiers.note_host_fallback(tenants[i].ns_id)
                        dense_ids = self._mask_ranking(
                            dense_ids,
                            [r.dense and not d for r, d in zip(res, downed)],
                            Bp)
                    rankings.append(dense_ids)
                    weight_cols.append(
                        [r.dense_weight for r in res]
                        + [self.dense_weight] * (Bp - B))
                if any(r.sparse for r in res):
                    with tel.span("plan.sparse", batch=Bp, pool=self.pool,
                                  launches=1):
                        _, sparse_ids = self.store.bm25.topk_batch_dev(
                            [r.query for r in reqs] + [""] * (Bp - B),
                            k=self.pool, namespaces=ns_pad)
                        sparse_ids = self._mask_ranking(
                            sparse_ids,
                            [r.sparse and not d for r, d in zip(res, downed)],
                            Bp)
                    rankings.append(sparse_ids)
                    weight_cols.append(
                        [r.sparse_weight for r in res]
                        + [self.sparse_weight] * (Bp - B))
                # graph expansion: the dense/sparse rankings' top rows seed
                # a batched k-hop walk over the store's entity graph; the
                # expanded rows join the fusion as a third ranking with
                # their own weight column.  Requests that skip the stage
                # (or whose shard is down) get the expanded ranking masked
                # to -1 — their fusion is bit-identical to a graph-less
                # batch.  Hop depth is per-request (traced vector); the
                # unrolled depth compiles at the pow2 bucket of the batch
                # max, so mixed-hops traffic reuses one executable.
                graph_wants = [r.graph and not d
                               for r, d in zip(res, downed)]
                if any(graph_wants) and rankings:
                    g = self.store.graph
                    t_g = time.perf_counter()
                    hops_list = [rr.hops if w else 0
                                 for rr, w in zip(res, graph_wants)]
                    hops_arr = np.zeros((Bp,), np.int32)
                    hops_arr[:B] = hops_list
                    tw = np.zeros((Bp, 3), np.float32)
                    tw[:B] = [rr.edge_weights for rr in res]
                    max_hops = next_pow2(max(1, max(hops_list)))
                    with tel.span("plan.graph", batch=Bp, pool=self.pool,
                                  hops_compiled=max_hops,
                                  launches=1) as sp:
                        graph_ids, _, fsz, etc = g.expand(
                            rankings, q_ns,
                            self.store.row_namespaces_device(), tw,
                            hops_arr, k=self.pool, max_hops=max_hops,
                            seed_k=plan.graph_seed_k,
                            decay=plan.graph_decay)
                        graph_ids = self._mask_ranking(
                            graph_ids, graph_wants, Bp)
                        sp.set(frontier_sizes=fsz, edges_touched=etc,
                               nodes=g.n_nodes, edges=g.n_edges)
                    rankings.append(graph_ids)
                    weight_cols.append(
                        [r.graph_weight for r in res] + [0.0] * (Bp - B))
                    tel.inc("memori_graph_expansions", 1,
                            help="batched k-hop expansion launches")
                    tel.inc("memori_graph_requests",
                            sum(graph_wants),
                            help="requests whose plan ran the graph stage")
                    tel.observe(GRAPH_EXPAND_LATENCY,
                                time.perf_counter() - t_g,
                                help="graph k-hop expansion stage latency")
                with tel.span("plan.fuse", batch=Bp, k=k_fuse,
                              rankings=len(rankings), launches=1):
                    fused_ids, fused_scores = rrf_fuse_batch(
                        rankings,
                        weights=np.stack(
                            [np.asarray(c, np.float32) for c in weight_cols],
                            axis=1),
                        k=k_fuse)
                    fused_ids = np.asarray(fused_ids)[:B]
                    fused_scores = np.asarray(fused_scores)[:B]
            else:
                fused_ids = np.full((B, k_fuse), -1, np.int32)
                fused_scores = np.zeros((B, k_fuse), np.float32)
            # result assembly stays under the guard: the fused global row
            # ids are only valid until the next compaction remaps them
            out: List[Any] = []
            with tel.span("plan.budget", batch=B):
                for r, (rr, t) in enumerate(zip(res, tenants)):
                    # per-request top_k: the fused ranking is sorted
                    # best-first, so its k_r prefix IS the k=k_r fusion of
                    # the same inputs
                    ids = fused_ids[r][: rr.k]
                    scs = fused_scores[r][: rr.k]
                    if t is None:
                        if rr.budget:
                            text = MemoriMemory.render([], [])
                            out.append(RetrievedContext(
                                [], [], text, self.tokenizer.count(text)))
                        else:
                            out.append(RawRetrieval([], [], []))
                        continue
                    if rr.budget:
                        scored = [(t.triples.get(self.store.row_tid(int(g))),
                                   float(s))
                                  for g, s in zip(ids, scs) if g >= 0]
                        ctx = self.budgeter.select(scored, t.summaries)
                        text = MemoriMemory.render(ctx.triples, ctx.summaries)
                        out.append(RetrievedContext(
                            ctx.triples, ctx.summaries, text,
                            self.tokenizer.count(text), degraded=downed[r]))
                    else:
                        rows = [int(g) for g in ids if g >= 0]
                        out.append(RawRetrieval(
                            rows, [self.store.row_tid(g) for g in rows],
                            [float(s) for g, s in zip(ids, scs) if g >= 0],
                            degraded=downed[r]))
            n_down = sum(downed)
            if n_down:
                tel.inc("memori_degraded_responses", n_down,
                        help="requests answered empty because their "
                             "placement shard was down")
                tel.event("degraded_response", count=n_down,
                          shards=sorted(sharded.down) if sharded else [])
            tel.observe(RETRIEVE_LATENCY, time.perf_counter() - t_exec,
                        n=B, help="end-to-end execute() latency per request")
            return out

    def _resolve(self, req: RetrieveRequest, plan: RetrievalPlan) -> _Resolved:
        """Fold request -> plan -> service option defaults."""
        stages = req.stages if req.stages is not None else plan.stages
        dw = (req.dense_weight if req.dense_weight is not None
              else plan.dense_weight if plan.dense_weight is not None
              else self.dense_weight)
        sw = (req.sparse_weight if req.sparse_weight is not None
              else plan.sparse_weight if plan.sparse_weight is not None
              else self.sparse_weight)
        ew = (req.edge_weights if req.edge_weights is not None
              else plan.edge_weights if plan.edge_weights is not None
              else _GRAPH_EDGE_WEIGHTS)
        gw = (req.graph_weight if req.graph_weight is not None
              else plan.graph_weight if plan.graph_weight is not None
              else _GRAPH_WEIGHT)
        return _Resolved(
            k=req.top_k or plan.top_k or self.top_k,
            dense_weight=float(dw), sparse_weight=float(sw),
            dense="dense" in stages, sparse="sparse" in stages,
            graph="graph" in stages,
            budget="budget" in stages,
            hops=int(req.hops or plan.hops or _GRAPH_HOPS),
            edge_weights=tuple(float(w) for w in ew),
            graph_weight=float(gw))

    @staticmethod
    def _mask_ranking(ids, wants: List[bool], Bp: int):
        """Drop a ranking for the requests that excluded its stage: their
        rows become all -1 (fusion padding), so a dense-only request inside
        a mixed batch fuses exactly like a dense-only batch.  The all-True
        common case is launch-free."""
        if all(wants):
            return ids
        mask = np.ones((Bp,), bool)
        mask[: len(wants)] = wants
        return jnp.where(jnp.asarray(mask)[:, None], ids, -1)

    def answer_prompt(self, namespace: str, question: str
                      ) -> Tuple[str, RetrievedContext]:
        ctx = self.retrieve(namespace, question)
        return ANSWER_PROMPT.format(memories=ctx.text,
                                    question=question), ctx

    # -- eviction ----------------------------------------------------------------
    def evict(self, namespace: str) -> int:
        """Drop a whole tenant: tombstone its bank rows + BM25 docs, free its
        stores.  Returns the number of rows evicted."""
        with self._guard():
            return self.store.evict_namespace(namespace)

    def evict_superseded(self, namespace: str) -> int:
        """Physically evict triples superseded under conflict resolution
        (triples.latest_for_key keeps the newest version of every
        (subject, predicate) key; the older versions leave the indices)."""
        with self._guard():
            return self.store.evict_superseded(namespace)

    # -- shard lifecycle ---------------------------------------------------
    def set_shard_down(self, shard: int) -> None:
        """Mark one placement shard unavailable: its device label slab goes
        to -1 (its rows stop matching any query) and requests owned by it
        answer empty with `degraded=True` while the rest of the batch
        answers normally — the batch never fails wholesale."""
        with self._guard():
            self.store.shard_down(shard)
        get_telemetry().event("shard_down", shard=int(shard))

    def set_shard_up(self, shard: int) -> None:
        """Bring a recovered shard back: restore its device labels from the
        host mirror and stop degrading its tenants' responses."""
        with self._guard():
            self.store.shard_up(shard)
        get_telemetry().event("shard_up", shard=int(shard))

    def attach_follower(self, sink, mode: str = "sync"):
        """Stream every sealed WAL segment to `sink` (a directory path or
        any object with put/has/list — see checkpoint/replication.py), so
        recovery survives losing this host's disk.  Returns the shipper."""
        if self.runtime is None:
            raise RuntimeError("attach_follower needs a lifecycle runtime "
                               "(construct the service with data_dir/runtime)")
        return self.runtime.attach_follower(sink, mode=mode)

    # -- stats ----------------------------------------------------------------------
    def stats(self) -> dict:
        """Store counters plus the operator's runtime view: `pending_depth`
        (buffered sessions), `wal_segments` (un-truncated log segments on
        disk) and `last_snapshot_age_s` (None until a snapshot exists)."""
        with self._guard():
            st = self.store.stats()
            if self.runtime is not None:
                st.update(self.runtime.stats())
            else:
                st.update({"pending_depth": st["pending"],
                           "wal_segments": 0,
                           "last_snapshot_age_s": None})
            return st

    def namespace_stats(self, namespace: str) -> dict:
        """Public per-namespace counters (no reaching into tenant state)."""
        with self._guard():
            t = self.store.get(namespace)
            if t is None:
                return {"triples": 0, "summaries": 0, "evicted": 0}
            return {"triples": len(t.triples),
                    "summaries": len(t.summaries),
                    "evicted": len(t.evicted)}


class NamespaceView:
    """MemoriMemory-compatible facade over one namespace of a MemoryService:
    MemoriClient (and anything else written against MemoriMemory's surface)
    runs on the shared service unchanged.  The namespace key IS the
    conversation scope, so record_session's conversation_id is subsumed by
    it (kept in the signature for drop-in compatibility)."""

    def __init__(self, service: MemoryService, namespace: str):
        self.service = service
        self.namespace = namespace
        self._seen_conversation_id: Optional[str] = None

    def record_session(self, conversation_id: str, session_id: str,
                       messages: Sequence[Message]):
        # the namespace key IS the scope, so conversation_id is otherwise
        # ignored — warn a drop-in caller who reuses one view across several
        # conversation_ids, since those scopes silently merge here
        if self._seen_conversation_id is None:
            self._seen_conversation_id = conversation_id
        elif conversation_id != self._seen_conversation_id:
            warnings.warn(
                f"NamespaceView({self.namespace!r}) saw conversation_id="
                f"{conversation_id!r} after {self._seen_conversation_id!r}: "
                "both record into the same namespace scope — use "
                f"service.namespace({conversation_id!r}) for a separate "
                "scope.", stacklevel=2)
        runtime = self.service.runtime
        if self.service.flush_every or (
                runtime is not None
                and runtime.policy.flush_interval_s is not None):
            # async batched ingestion: buffer until the count-based or
            # time-based flusher drains the queue (reads still see the
            # buffered sessions — retrieve flushes first).  No extraction
            # happens yet, so there is no per-session result.
            return self.service.enqueue(self.namespace, session_id, messages)
        return self.service.record(self.namespace, session_id, messages)

    def retrieve(self, query: str,
                 top_k: Optional[int] = None) -> RetrievedContext:
        return self.service.retrieve(self.namespace, query, top_k=top_k)

    def answer_prompt(self, question: str) -> Tuple[str, RetrievedContext]:
        return self.service.answer_prompt(self.namespace, question)

    def stats(self) -> dict:
        return self.service.namespace_stats(self.namespace)

    def close(self) -> None:
        """Shut the backing service's lifecycle runtime down (final flush +
        snapshot).  Idempotent and shared: the first closing view wins, so
        any client of a shared service may call it on exit."""
        self.service.close()
