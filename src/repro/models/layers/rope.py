"""Rotary position embeddings (GPT-NeoX half-split layout), with partial
rotary support (stablelm rotates only the first 25% of head_dim)."""
from __future__ import annotations

import jax.numpy as jnp


def _freqs(rot_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(x, positions, *, theta: float = 10000.0, pct: float = 1.0):
    """x: (..., S, H, Dh) or (..., S, Dh);  positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    rot = int(head_dim * pct)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    inv = _freqs(rot, theta)                       # (rot/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv   # (..., S, rot/2)
    # broadcast over the heads dim if present
    extra = x.ndim - ang.ndim
    for _ in range(extra):
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    rotated = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([rotated, xp], axis=-1) if rot < head_dim else rotated


def sinusoidal_positions(seq_len: int, dim: int, dtype=jnp.float32):
    """Whisper-style sinusoidal embeddings (adapted for both enc and dec so
    decode positions are unbounded — see DESIGN.md hardware adaptation)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000.0 ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = pos * inv
    emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return emb[:, :dim].astype(dtype)
