"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048 (expert width)
vocab=129280.  MLA latent attention, 1 shared + 256 routed experts top-8,
MTP [arXiv:2412.19437].  long_500k runs with FULL attention: the MLA latent
cache is (512+64) floats/token, so a 500k-token cache is ~600 MB — MLA is
precisely the long-context enabler here (DESIGN.md §4)."""
from repro.models.config import MLAConfig, MoEConfig, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        d_ff=2048,
        vocab_size=129280,
        source="[arXiv:2412.19437]",
        use_mla=True,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        use_moe=True,
        first_k_dense=3,
        moe=MoEConfig(num_experts=256, experts_per_token=8,
                      num_shared_experts=1, d_ff_expert=2048,
                      capacity_factor=1.25),
        mtp_depth=1,
        mtp_loss_weight=0.3,
        long_context_window=0,        # MLA latent cache: full attention is cheap
    )
