"""Device-resident retrieval engine (core/vector_index.py + core/hybrid.py):
device-vs-host-mirror parity under interleaved mutation, zero-recompile /
zero-upload steady-state guarantees, and the batched on-device RRF against
its scalar oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.utils import count_compiles
from repro.core import vector_index as vi_mod
from repro.core.embedder import HashEmbedder
from repro.core.extraction import Message
from repro.core.hybrid import rrf_fuse, rrf_fuse_batch
from repro.core.service import MemoryService
from repro.core.vector_index import VectorIndex
from repro.kernels import ref as kref

RNG = np.random.default_rng(7)


def _oracle(vi: VectorIndex, q, q_ns, k):
    """Recompute search_batch from the HOST mirrors only."""
    if vi.n == 0 or vi.n_alive == 0:
        return np.full((q.shape[0], k), -1, np.int64)
    eff = np.where(vi.alive(), vi.row_namespaces(), -1)
    _, i = kref.topk_mips_masked_ref(
        jnp.asarray(q), jnp.asarray(vi.bank), jnp.asarray(q_ns, jnp.int32),
        jnp.asarray(eff, jnp.int32), k=min(k, vi.n))
    i = np.asarray(i, np.int64)
    if i.shape[1] < k:
        i = np.pad(i, ((0, 0), (0, k - i.shape[1])), constant_values=-1)
    return i


# -- device buffers == host mirror under interleaved mutation -----------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_device_vs_host_mirror_parity_interleaved(use_kernel):
    """add / delete / compact / load_rows(snapshot-restore) interleaved with
    searches: the incrementally-updated device buffers must answer exactly
    like an oracle recomputed from the host mirror after every step."""
    dim, k = 16, 6
    vi = VectorIndex(dim=dim, capacity=64, use_kernel=use_kernel)
    q = RNG.standard_normal((4, dim)).astype(np.float32)
    q_ns = np.asarray([0, 1, 2, 9], np.int32)       # ns 9 never populated

    def check():
        _, i = vi.search_batch(q, q_ns, k=k)
        np.testing.assert_array_equal(np.asarray(i, np.int64),
                                      _oracle(vi, q, q_ns, k))

    vi.add(RNG.standard_normal((10, dim)).astype(np.float32),
           ns=np.arange(10) % 3)
    check()
    vi.delete([0, 4, 7])
    check()
    vi.add(RNG.standard_normal((30, dim)).astype(np.float32),
           ns=np.arange(30) % 3)                    # stays inside capacity
    check()
    vi.delete(np.arange(10, 25))
    check()
    vi.compact()                                    # device rebuild
    check()
    vi.add(RNG.standard_normal((100, dim)).astype(np.float32),
           ns=np.arange(100) % 3)                   # crosses a capacity boundary
    check()
    # snapshot-restore round trip through load_rows
    bank, alive, ns = vi.bank.copy(), vi.alive(), vi.row_namespaces()
    vi2 = VectorIndex(dim=dim, capacity=64, use_kernel=use_kernel)
    vi2.load_rows(bank, alive, ns=ns)
    _, i1 = vi.search_batch(q, q_ns, k=k)
    _, i2 = vi2.search_batch(q, q_ns, k=k)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    vi2.delete([1, 2])
    _, i = vi2.search_batch(q, q_ns, k=k)
    np.testing.assert_array_equal(np.asarray(i, np.int64),
                                  _oracle(vi2, q, q_ns, k))


def test_search_and_search_masked_agree_with_search_batch():
    """The three read APIs are one engine: uniform-ns search == masked
    search with zero labels; caller-supplied labels == cached labels."""
    dim = 8
    vi = VectorIndex(dim=dim, capacity=64, use_kernel=False)
    vi.add(RNG.standard_normal((20, dim)).astype(np.float32))   # default ns 0
    vi.delete([3, 8])
    q = RNG.standard_normal((3, dim)).astype(np.float32)
    s0, i0 = vi.search(q, k=5)
    _, i1 = vi.search_batch(q, np.zeros((3,), np.int32), k=5)
    s2, i2 = vi.search_masked(q, np.zeros((3,), np.int32),
                              np.zeros((20,), np.int32), k=5)
    np.testing.assert_array_equal(i0, np.asarray(i1, np.int64))
    np.testing.assert_array_equal(i0, i2)
    np.testing.assert_array_equal(s0, s2)


# -- steady state: no recompiles, no bank uploads -----------------------------

def test_no_recompile_and_no_bank_upload_within_capacity_bucket(monkeypatch):
    """The acceptance contract of the device-resident engine: while the bank
    grows WITHIN a power-of-two capacity bucket, steady-state searches reuse
    one executable (zero compiles) and never re-upload the bank (zero
    capacity-sized jnp.asarray calls in the index module)."""
    dim, cap = 32, 1024
    vi = VectorIndex(dim=dim, capacity=cap, use_kernel=False)
    vi.add(RNG.standard_normal((100, dim)).astype(np.float32),
           ns=np.arange(100) % 4)
    q = RNG.standard_normal((8, dim)).astype(np.float32)
    q_ns = np.asarray([0, 1, 2, 3, 0, 1, 2, 3], np.int32)
    # warmup: one search and one single-row append compile the executables
    np.asarray(vi.search_batch(q, q_ns, k=16)[1])
    vi.add(RNG.standard_normal((1, dim)).astype(np.float32), ns=[0])
    np.asarray(vi.search_batch(q, q_ns, k=16)[1])

    uploads = []
    real_asarray = vi_mod.jnp.asarray

    def spy_asarray(x, *a, **kw):
        if getattr(x, "nbytes", 0) >= cap * dim * 4:
            uploads.append(np.shape(x))
        return real_asarray(x, *a, **kw)

    monkeypatch.setattr(vi_mod.jnp, "asarray", spy_asarray)
    with count_compiles() as cc:
        for _ in range(40):
            vi.add(RNG.standard_normal((1, dim)).astype(np.float32), ns=[1])
            _, i = vi.search_batch(q, q_ns, k=16)
        np.asarray(i)
    assert cc.count == 0, f"recompiled {cc.count}x: {cc.msgs[:3]}"
    assert uploads == [], f"bank-sized host->device transfers: {uploads}"
    assert vi.n == 141


def test_crossing_capacity_boundary_recompiles_once_then_stabilizes():
    dim = 16
    vi = VectorIndex(dim=dim, capacity=64, use_kernel=False)
    vi.add(RNG.standard_normal((60, dim)).astype(np.float32), ns=[0] * 60)
    q = RNG.standard_normal((2, dim)).astype(np.float32)
    q_ns = np.zeros((2,), np.int32)
    np.asarray(vi.search_batch(q, q_ns, k=4)[1])
    # positive control: crossing the boundary changes the padded shapes, so
    # the counter MUST observe compiles here — this is what keeps the
    # zero-compile assertions below from passing vacuously if a jax upgrade
    # ever changes the log_compiles message format
    with count_compiles() as cc_cross:
        vi.add(RNG.standard_normal((10, dim)).astype(np.float32), ns=[0] * 10)
        np.asarray(vi.search_batch(q, q_ns, k=4)[1])
    assert vi.capacity == 128
    assert cc_cross.count >= 1, \
        "compile counter failed to observe the capacity-boundary recompile"
    # warmup in the new bucket: the 1-row append compiles once
    vi.add(RNG.standard_normal((1, dim)).astype(np.float32), ns=[0])
    np.asarray(vi.search_batch(q, q_ns, k=4)[1])
    with count_compiles() as cc:
        for _ in range(10):
            vi.add(RNG.standard_normal((1, dim)).astype(np.float32), ns=[0])
            _, i = vi.search_batch(q, q_ns, k=4)
        np.asarray(i)
    assert cc.count == 0, cc.msgs[:3]


# -- batched on-device RRF == scalar oracle -----------------------------------

def test_rrf_fuse_batch_matches_scalar_oracle():
    """Property (seeded fuzz): every row of the on-device fusion equals the
    scalar `rrf_fuse` — same ids, same order, same float32 scores —
    including duplicate ids (within and across rankings) and -1 padding.
    The narrow id range [-1, 12) makes duplicates and cross-ranking
    collisions the common case, not the exception."""
    rng = np.random.default_rng(11)
    for trial in range(150):
        B = int(rng.integers(1, 6))
        P1, P2 = (int(x) for x in rng.integers(0, 9, size=2))
        k = int(rng.integers(1, 12))
        w = [float(rng.uniform(0.1, 2.0)), float(rng.uniform(0.1, 2.0))]
        d = rng.integers(-1, 12, size=(B, P1)).astype(np.int32)
        s = rng.integers(-1, 12, size=(B, P2)).astype(np.int32)
        fi, fs = rrf_fuse_batch([d, s], weights=w, k=k)
        fi, fs = np.asarray(fi), np.asarray(fs)
        assert fi.shape == fs.shape == (B, k)
        for b in range(B):
            want = rrf_fuse([d[b].tolist(), s[b].tolist()], weights=w)[:k]
            got = [(int(i), float(x)) for i, x in zip(fi[b], fs[b])
                   if i >= 0]
            assert got == want, (trial, b, got, want)
            # -1 slots trail the live ones and carry zero scores
            tail = fi[b][len(got):]
            assert (tail == -1).all() and (fs[b][len(got):] == 0).all()


def test_rrf_fuse_batch_duplicate_ids_do_not_accumulate():
    d = np.asarray([[5, 7, 5, 5]], np.int32)
    s = np.asarray([[7, 7, -1]], np.int32)
    fi, fs = rrf_fuse_batch([d, s], k=4)
    want = rrf_fuse([[5, 7], [7]])
    got = [(int(i), float(x)) for i, x in zip(fi[0], fs[0]) if i >= 0]
    assert got == want


def test_rrf_fuse_batch_empty_inputs():
    fi, fs = rrf_fuse_batch([np.zeros((0, 3), np.int32),
                             np.zeros((0, 2), np.int32)], k=5)
    assert fi.shape == (0, 5)
    fi, fs = rrf_fuse_batch([np.full((2, 0), -1, np.int32),
                             np.full((2, 0), -1, np.int32)], k=3)
    assert (np.asarray(fi) == -1).all() and (np.asarray(fs) == 0).all()
    fi, fs = rrf_fuse_batch([np.full((1, 2), -1, np.int32),
                             np.asarray([[4, -1]], np.int32)], k=5)
    assert np.asarray(fi)[0, 0] == 4 and (np.asarray(fi)[0, 1:] == -1).all()


# -- service level: the full read path under interleaved mutation -------------

def _session(texts, speaker="u"):
    return [Message(speaker, t, 1700000000.0) for t in texts]


def test_ragged_batch_sizes_bucket_to_bounded_executables():
    """Q-shape bucketing: after warming the power-of-two buckets, ragged
    public-facing batch sizes reuse them — zero new executables for any
    B <= the largest warmed bucket, across the whole read path (masked
    top-k, stacked BM25, on-device RRF)."""
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800)
    for u in range(4):
        svc.record(f"u{u}/c0", "s0", _session(
            [f"I live in City{u}.", f"I adopted a pet named P{u}."]))
    q = "Which city does the user live in?"

    def batch(n):
        return [(f"u{i % 4}/c0", q) for i in range(n)]

    for n in (1, 2, 4, 8):                       # warm each pow2 bucket
        svc.retrieve_batch(batch(n))
    with count_compiles() as cc:
        for n in (3, 5, 6, 7, 1, 2, 4, 8, 5, 3, 6):
            got = svc.retrieve_batch(batch(n))
            assert len(got) == n
    assert cc.count == 0, \
        f"ragged batch sizes minted executables: {cc.msgs[:5]}"


def test_padded_batch_equals_unpadded_results():
    """Bucket padding is invisible: every ragged batch answers exactly like
    per-request retrieves (the padded queries match nothing)."""
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800)
    for u in range(3):
        svc.record(f"u{u}/c0", "s0", _session(
            [f"I live in City{u}.", f"I work as a welder."]))
    reqs = [(f"u{i % 3}/c0", t) for i, t in enumerate(
        ["Which city does the user live in?", "What is the user's job?",
         "Which city does the user live in?", "anything?",
         "What is the user's job?"])]            # B=5 -> pads to 8
    batched = svc.retrieve_batch(reqs)
    for got, (ns, q) in zip(batched, reqs):
        want = svc.retrieve(ns, q)
        assert got.text == want.text


def test_service_batched_equals_sequential_under_interleaved_ops(tmp_path):
    """retrieve_batch == per-request retrieves (different jit shapes, same
    engine) after every kind of store mutation: record, evict_superseded,
    evict, compact, snapshot/restore."""
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800)
    queries = [("a/c0", "Which city does the user live in?"),
               ("b/c0", "What pet was adopted?"),
               ("ghost/c0", "anything?"),
               ("a/c0", "What is the user's job?")]

    def check(s):
        batched = s.retrieve_batch(queries)
        for got, (ns, q) in zip(batched, queries):
            want = s.retrieve(ns, q)
            assert got.text == want.text
            assert [t.text() for t in got.triples] == \
                [t.text() for t in want.triples]

    svc.record("a/c0", "s0", _session(["I live in Tallinn.",
                                       "I work as a botanist."]))
    svc.record("b/c0", "s0", _session(["I adopted a parrot named Olive.",
                                       "I live in Porto."]))
    check(svc)
    svc.record("a/c0", "s1", _session(["I work as a welder."]))
    svc.evict_superseded("a/c0")          # tombstones the botanist triple
    check(svc)
    svc.record("c/c0", "s0", _session(["I collect stamps."]))
    svc.evict("b/c0")
    check(svc)
    svc.compact()
    check(svc)
    path = str(tmp_path / "snap.msgpack")
    svc.snapshot(path)
    restored = MemoryService.restore(path, HashEmbedder(), use_kernel=False,
                                     budget=800)
    check(restored)
    batched = svc.retrieve_batch(queries)
    rbatched = restored.retrieve_batch(queries)
    for got, want in zip(rbatched, batched):
        assert got.text == want.text
