"""Hot/warm tiered residency for the device bank (ROADMAP: "tiered bank
for millions of tenants").

The device bank is the capacity bottleneck: every resident row costs HBM
(1 byte/dim + 4 bytes/row quantized, 4 bytes/dim f32) and bank-scan
bandwidth on every search.  A production deployment holds orders of
magnitude more tenants than are active in any window, so the TierManager
bounds the HOT set by *policy* instead of bank size:

* every retrieve/record bumps the owning namespace's **EWMA activity
  score** (exponential decay with a configurable halflife — long-idle
  tenants decay toward zero no matter how busy they once were);
* when the resident row count exceeds ``max_hot_rows``, ``tick()``
  (driven by ``LifecycleRuntime.run_maintenance_once``) **demotes** the
  coldest namespaces' rows out of the device bank
  (``VectorIndex.demote_rows``: device slots zeroed/label -1, the
  full-precision host mirror untouched — the warm tier; snapshots, WAL
  and compaction never notice);
* a retrieve that hits a demoted namespace transparently falls back to
  the host-side masked search (``VectorIndex.search_host`` — exact, just
  not device-accelerated) and **marks the namespace for promotion**; the
  next tick brings its rows back in ONE batched pow2-padded device
  scatter (``promote_rows``), so a tenant waking from the warm tier pays
  one host-search round-trip, not a stampede of uploads.

The manager is deliberately storage-agnostic: it only talks to the
store's public surface (``row_namespaces``/``alive``/``resident_mask``
scans happen at tick time, never on the retrieve hot path) and all its
own bookkeeping is O(#active namespaces).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Optional, Set

import numpy as np

from repro.obs.telemetry import get_telemetry


@dataclasses.dataclass(frozen=True)
class TierPolicy:
    """Knobs of the hot/warm tier manager (see docs/OPERATIONS.md).

    ``max_hot_rows`` is the device-residency budget: ``tick()`` demotes
    the coldest namespaces until at most this many live rows are
    device-resident.  ``halflife_s`` controls how fast activity evidence
    ages (a namespace idle for one halflife keeps half its score);
    ``retrieve_weight``/``record_weight`` weigh the two activity
    signals."""
    max_hot_rows: int = 1 << 20
    halflife_s: float = 300.0
    retrieve_weight: float = 1.0
    record_weight: float = 1.0

    def __post_init__(self):
        if self.max_hot_rows < 1:
            raise ValueError("max_hot_rows must be >= 1")
        if self.halflife_s <= 0:
            raise ValueError("halflife_s must be > 0")


class TierManager:
    """Per-namespace EWMA activity tracking + policy-driven demotion and
    promotion against one VectorIndex.  Not thread-safe by itself — the
    lifecycle runtime calls every method under its lock, matching how the
    rest of maintenance serializes against the read path."""

    def __init__(self, vindex, policy: Optional[TierPolicy] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.vindex = vindex
        self.policy = policy or TierPolicy()
        self._clock = clock
        # ns_id -> (score at _stamp, stamp); decay is applied lazily on
        # touch/compare so idle namespaces cost nothing per tick
        self._score: Dict[int, float] = {}
        self._stamp: Dict[int, float] = {}
        self._demoted: Set[int] = set()
        self._promote_pending: Set[int] = set()
        self.counters = {"promotions": 0, "demotions": 0,
                         "promoted_rows": 0, "demoted_rows": 0,
                         "host_fallbacks": 0, "ticks": 0}

    # -- activity signals (hot path: O(1) dict math, no index access) -------
    def _bump(self, ns_id: int, weight: float) -> None:
        now = self._clock()
        self._score[ns_id] = self.score(ns_id, now=now) + weight
        self._stamp[ns_id] = now

    def score(self, ns_id: int, now: Optional[float] = None) -> float:
        """Decayed EWMA activity score (0.0 for a never-seen namespace)."""
        s = self._score.get(ns_id)
        if s is None:
            return 0.0
        if now is None:
            now = self._clock()
        dt = max(0.0, now - self._stamp[ns_id])
        return s * math.pow(2.0, -dt / self.policy.halflife_s)

    def note_retrieve(self, ns_id: int) -> None:
        self._bump(int(ns_id), self.policy.retrieve_weight)

    def note_record(self, ns_id: int) -> None:
        self._bump(int(ns_id), self.policy.record_weight)

    def note_host_fallback(self, ns_id: int) -> None:
        """A retrieve hit this demoted namespace: count the fallback and
        queue the namespace for promotion on the next maintenance tick."""
        self.counters["host_fallbacks"] += 1
        self.mark_for_promotion(ns_id)

    # -- tier state ---------------------------------------------------------
    def is_demoted(self, ns_id: int) -> bool:
        return int(ns_id) in self._demoted

    def demoted_namespaces(self) -> Set[int]:
        return set(self._demoted)

    def mark_for_promotion(self, ns_id: int) -> None:
        ns_id = int(ns_id)
        if ns_id in self._demoted:
            self._promote_pending.add(ns_id)

    # -- the maintenance body ------------------------------------------------
    def tick(self) -> dict:
        """One maintenance pass: (1) promote every namespace marked since
        the last tick (batched device scatter per namespace), then (2) if
        the resident row count exceeds the policy budget, demote the
        coldest namespaces until it fits.  Returns what happened."""
        self.counters["ticks"] += 1
        did = {"promoted_ns": 0, "demoted_ns": 0,
               "promoted_rows": 0, "demoted_rows": 0}
        vi = self.vindex
        shielded: Set[int] = set()
        for ns_id in sorted(self._promote_pending):
            rows = vi.rows_in_namespace(ns_id)
            n = vi.promote_rows(rows)
            self._demoted.discard(ns_id)
            shielded.add(ns_id)           # never re-demote in the same tick
            did["promoted_ns"] += 1
            did["promoted_rows"] += n
        self._promote_pending.clear()
        over = vi.n_resident - self.policy.max_hot_rows
        if over > 0:
            did_d, rows_d = self._demote_coldest(over, shielded)
            did["demoted_ns"] = did_d
            did["demoted_rows"] = rows_d
        self.counters["promotions"] += did["promoted_ns"]
        self.counters["demotions"] += did["demoted_ns"]
        self.counters["promoted_rows"] += did["promoted_rows"]
        self.counters["demoted_rows"] += did["demoted_rows"]
        tel = get_telemetry()
        if did["promoted_ns"]:
            tel.inc("memori_tier_promotions", did["promoted_ns"],
                    help="namespaces promoted back to the device bank")
        if did["demoted_ns"]:
            tel.inc("memori_tier_demotions", did["demoted_ns"],
                    help="namespaces demoted off the device bank")
        if did["promoted_ns"] or did["demoted_ns"]:
            tel.event("tier_tick", **did)
        return did

    def _demote_coldest(self, over: int, shielded: Set[int]):
        """Demote whole namespaces, coldest (lowest decayed score) first,
        until `over` resident rows have left the device.  One O(n) host
        scan builds the per-namespace resident row lists — tick-time cost,
        never on the retrieve path."""
        vi = self.vindex
        m = vi.n
        if m == 0:
            return 0, 0
        ns = vi.row_namespaces()
        live = vi.alive() & vi.resident_mask()
        rows_by_ns: Dict[int, np.ndarray] = {}
        for ns_id in np.unique(ns[live]):
            rows_by_ns[int(ns_id)] = np.where(live & (ns == ns_id))[0]
        now = self._clock()
        order = sorted(
            (nid for nid in rows_by_ns
             if nid not in shielded and nid not in self._demoted),
            key=lambda nid: (self.score(nid, now=now), -len(rows_by_ns[nid])))
        n_ns = n_rows = 0
        for nid in order:
            if over <= 0:
                break
            n = vi.demote_rows(rows_by_ns[nid])
            self._demoted.add(nid)
            n_ns += 1
            n_rows += n
            over -= n
        return n_ns, n_rows

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        return {
            "hot_rows": self.vindex.n_resident,
            "warm_rows": self.vindex.n_warm,
            "max_hot_rows": self.policy.max_hot_rows,
            "demoted_namespaces": len(self._demoted),
            "promote_pending": len(self._promote_pending),
            **self.counters,
        }
