"""Model facade: one API over the whole zoo.

  model = Model(cfg)
  params = model.init_params(key)
  loss, metrics = model.train_loss(params, batch)
  logits, caches = model.prefill(params, batch)
  logits, caches = model.decode_step(params, tokens, caches, pos)

Batches are dicts:
  tokens  (B, S) int32                      — always
  images  (B, P, vision_dim)                — vlm (stub SigLIP patch embeds)
  audio   (B, F, d_model)                   — audio (stub conv/mel frames)
  loss_mask (B, S) f32                      — optional

Cross-entropy is computed in sequence chunks (lax.map) so (B, S, vocab)
logits are never materialised — required for 129k-vocab training at 4k seq.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common import partitioning
from repro.common.module import ParamSpec, abstract, materialize, shardings_of, spec_tree_to_pspecs
from repro.models import blocks, transformer
from repro.models.config import InputShape, ModelConfig
from repro.models.layers import embedding, norms, rope as rope_lib

PyTree = Any


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, num_layers=cfg.encoder_layers, arch_type="dense", use_moe=False,
        use_mla=False, hybrid_period=0, first_k_dense=0, mtp_depth=0,
        sliding_window=0, is_encoder_decoder=False)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- specs / init --------------------------------------------------------
    def param_specs(self) -> PyTree:
        cfg = self.cfg
        s = {"embed": embedding.specs(cfg),
             **transformer.decoder_specs(cfg, cross=cfg.is_encoder_decoder)}
        if cfg.is_encoder_decoder:
            s["encoder"] = transformer.decoder_specs(encoder_cfg(cfg))
        if cfg.num_image_tokens:
            s["img_proj"] = {
                "w": ParamSpec((cfg_vision_dim(cfg), cfg.d_model), (None, "embed"),
                               init="scaled_normal", scale=1.0),
                "b": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
            }
        if cfg.mtp_depth:
            s["mtp"] = {
                "proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                  ("embed", None), init="scaled_normal", scale=1.0),
                "norm_h": norms.specs(cfg),
                "norm_e": norms.specs(cfg),
                "block": blocks.block_specs(cfg, ("attn", "mlp")),
                "final_norm": norms.specs(cfg),
            }
        return s

    def init_params(self, key) -> PyTree:
        return materialize(key, self.param_specs(), self.cfg.pdtype)

    def abstract_params(self) -> PyTree:
        return abstract(self.param_specs(), self.cfg.pdtype)

    def param_pspecs(self, rules) -> PyTree:
        return spec_tree_to_pspecs(self.param_specs(), rules)

    def param_shardings(self, rules) -> PyTree:
        return shardings_of(self.param_specs(), rules)

    # -- embedding front-ends ------------------------------------------------
    def _embed_inputs(self, params, batch, *, positions_offset: int = 0):
        """Returns (x (B,S,d), positions (B,S), prefix_len, enc_out, enc_pos)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B = tokens.shape[0]
        x = embedding.embed(params["embed"], cfg, tokens)
        prefix_len = None
        enc_out = enc_pos = None

        if cfg.num_image_tokens and "images" in batch:
            img = batch["images"].astype(cfg.cdtype)
            img = jnp.einsum("bpv,vd->bpd", img, params["img_proj"]["w"].astype(cfg.cdtype))
            img = img + params["img_proj"]["b"].astype(cfg.cdtype)
            x = jnp.concatenate([img, x], axis=1)
            prefix_len = cfg.num_image_tokens

        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S)) + positions_offset

        if cfg.is_encoder_decoder and "audio" in batch:
            enc_out, enc_pos = self.encode(params, batch["audio"])
            # whisper-style decoder: sinusoidal absolute positions, no rope
            x = x + rope_lib.sinusoidal_positions(S, cfg.d_model, cfg.cdtype)[None]
        return x, positions, prefix_len, enc_out, enc_pos

    def encode(self, params, audio_frames):
        cfg = self.cfg
        ec = encoder_cfg(cfg)
        B, F, _ = audio_frames.shape
        x = audio_frames.astype(cfg.cdtype)
        x = x + rope_lib.sinusoidal_positions(F, cfg.d_model, cfg.cdtype)[None]
        pos = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
        h, _, _ = transformer.decoder_apply(
            params["encoder"], ec, x, mode="train", positions=pos,
            mask_kind="bidir", use_rope=False, remat=False)
        return h, pos

    # -- training ------------------------------------------------------------
    def train_loss(self, params, batch, *, rules=None):
        cfg = self.cfg
        x, positions, prefix_len, enc_out, enc_pos = self._embed_inputs(params, batch)
        mask_kind = "prefix" if prefix_len is not None else "causal"
        h, _, aux = transformer.decoder_apply(
            params, cfg, x, mode="train", positions=positions,
            mask_kind=mask_kind, prefix_len=prefix_len, enc_out=enc_out,
            enc_positions=enc_pos, rules=rules,
            use_rope=not cfg.is_encoder_decoder, remat=True)

        tokens = batch["tokens"]
        P = prefix_len or 0
        h_text = h[:, P:]                       # (B, S_text, d)
        loss_mask = batch.get("loss_mask")
        ce, acc = _chunked_xent(params, cfg, h_text[:, :-1], tokens[:, 1:],
                                loss_mask[:, 1:] if loss_mask is not None else None)
        total = ce + aux["moe_load_balance"] + aux["moe_router_z"]
        metrics = {"ce": ce, "accuracy": acc, **aux}

        if cfg.mtp_depth:
            mtp_loss = self._mtp_loss(params, cfg, h_text, tokens, positions[:, P:])
            total = total + cfg.mtp_loss_weight * mtp_loss
            metrics["mtp_ce"] = mtp_loss
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params, cfg, h, tokens, positions):
        """DeepSeek-V3 MTP (depth 1): from h_t and emb(token_{t+1}) predict
        token_{t+2} through one extra transformer block."""
        emb_next = embedding.embed(params["embed"], cfg, tokens[:, 1:])
        hin = jnp.concatenate(
            [norms.apply(params["mtp"]["norm_h"], cfg, h[:, :-1]),
             norms.apply(params["mtp"]["norm_e"], cfg, emb_next)], axis=-1)
        hin = jnp.einsum("bsd,de->bse", hin, params["mtp"]["proj"].astype(hin.dtype))
        pos = positions[:, :-1]
        hb, _, _ = blocks.apply(params["mtp"]["block"], cfg, hin, ("attn", "mlp"),
                                mode="train", positions=pos)
        hb = norms.apply(params["mtp"]["final_norm"], cfg, hb)
        ce, _ = _chunked_xent(params, cfg, hb[:, :-1], tokens[:, 2:], None)
        return ce

    # -- serving -------------------------------------------------------------
    def prefill(self, params, batch, *, rules=None, window_override=None):
        cfg = self.cfg
        x, positions, prefix_len, enc_out, enc_pos = self._embed_inputs(params, batch)
        mask_kind = "prefix" if prefix_len is not None else "causal"
        h, caches, _ = transformer.decoder_apply(
            params, cfg, x, mode="prefill", positions=positions,
            mask_kind=mask_kind, prefix_len=prefix_len, enc_out=enc_out,
            enc_positions=enc_pos, rules=rules, window_override=window_override,
            return_cache=True, use_rope=not cfg.is_encoder_decoder, remat=False)
        logits = embedding.logits(params["embed"], cfg, h[:, -1:])
        return logits, caches

    def decode_step(self, params, tokens, caches, cache_pos, *, rules=None,
                    window_override=None):
        """tokens: (B, 1); caches from prefill/init_caches; cache_pos is a
        scalar or a per-slot (B,) vector (continuous batching)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = embedding.embed(params["embed"], cfg, tokens)
        pos = jnp.asarray(cache_pos)
        if pos.ndim == 0:
            pos = jnp.broadcast_to(pos, (B,))
        if cfg.is_encoder_decoder:
            # absolute sinusoidal position = cache_pos (per row)
            inv = 1.0 / (10000.0 ** (jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32) / cfg.d_model))
            ang = pos.astype(jnp.float32)[:, None] * inv[None, :]   # (B, d/2)
            pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, : cfg.d_model]
            x = x + pe.astype(cfg.cdtype)[:, None]
        positions = pos[:, None].astype(jnp.int32)
        h, caches, _ = transformer.decoder_apply(
            params, cfg, x, mode="decode", positions=positions, caches=caches,
            cache_pos=cache_pos, rules=rules, window_override=window_override,
            use_rope=not cfg.is_encoder_decoder, remat=False)
        logits = embedding.logits(params["embed"], cfg, h)
        return logits, caches

    def prepare_decode_caches(self, caches, prefill_len, max_len, *,
                              window_override=None):
        return transformer.prepare_decode_caches(
            self.cfg, caches, prefill_len, max_len,
            window_override=window_override)

    # -- cache helpers ---------------------------------------------------------
    def init_caches(self, batch, max_len, *, window_override=None):
        cfg = self.cfg
        return transformer.init_caches(
            cfg, batch, max_len, cfg.cdtype, cross=cfg.is_encoder_decoder,
            enc_len=cfg.encoder_seq_len, window_override=window_override)

    def abstract_caches(self, batch, max_len, *, window_override=None):
        cfg = self.cfg
        return transformer.abstract_caches(
            cfg, batch, max_len, cfg.cdtype, cross=cfg.is_encoder_decoder,
            enc_len=cfg.encoder_seq_len, window_override=window_override)

    def cache_pspecs(self, batch, max_len, rules, *, window_override=None):
        cfg = self.cfg
        return transformer.cache_pspecs(
            cfg, batch, max_len, cfg.cdtype, rules, cross=cfg.is_encoder_decoder,
            enc_len=cfg.encoder_seq_len, window_override=window_override)

    # -- abstract inputs for AOT lowering -------------------------------------
    def input_specs(self, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        cfg = self.cfg
        B = shape.global_batch
        if shape.kind == "train":
            S_text = shape.seq_len - (cfg.num_image_tokens or 0)
            out = {"tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32)}
        elif shape.kind == "prefill":
            S_text = shape.seq_len - (cfg.num_image_tokens or 0)
            out = {"tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32)}
        else:  # decode
            out = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        if cfg.num_image_tokens and shape.kind != "decode":
            out["images"] = jax.ShapeDtypeStruct(
                (B, cfg.num_image_tokens, cfg_vision_dim(cfg)), jnp.float32)
        if cfg.is_encoder_decoder and shape.kind != "decode":
            out["audio"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        return out


def cfg_vision_dim(cfg) -> int:
    return 1152  # SigLIP-so400m patch embedding width (stub frontend)


def _chunked_xent(params, cfg, h, targets, loss_mask, chunk: int = 256):
    """Cross-entropy via lax.map over sequence chunks; returns (mean_ce, acc).
    h: (B, S, d), targets: (B, S)."""
    B, S, d = h.shape
    Sp = -(-S // chunk) * chunk
    hp = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
    tp = jnp.pad(targets, ((0, 0), (0, Sp - S)))
    mp = jnp.ones((B, S), jnp.float32) if loss_mask is None else loss_mask.astype(jnp.float32)
    mp = jnp.pad(mp, ((0, 0), (0, Sp - S)))
    nc = Sp // chunk
    hc = hp.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = tp.reshape(B, nc, chunk).transpose(1, 0, 2)
    mc = mp.reshape(B, nc, chunk).transpose(1, 0, 2)

    def one(args):
        hh, tt, mm = args
        logits = embedding.logits(params["embed"], cfg, hh)      # (B,c,V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        ce = (logz - gold) * mm
        correct = (logits.argmax(-1) == tt) * mm
        return ce.sum(), correct.sum(), mm.sum()

    if cfg.force_unroll:   # probe mode: count every chunk in HLO cost analysis
        parts = [one((hc[i], tc[i], mc[i])) for i in range(nc)]
        ces = jnp.stack([p[0] for p in parts])
        cors = jnp.stack([p[1] for p in parts])
        cnts = jnp.stack([p[2] for p in parts])
    else:
        ces, cors, cnts = jax.lax.map(one, (hc, tc, mc))
    denom = jnp.maximum(cnts.sum(), 1.0)
    return ces.sum() / denom, cors.sum() / denom
