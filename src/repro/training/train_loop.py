"""Training loop: jit'd (or pjit'd, via launch/train.py) train step with
gradient accumulation and metrics."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.model_api import Model
from repro.training import optimizer as opt

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    grad_accum: int = 1
    opt: opt.OptimizerConfig = opt.OptimizerConfig()


def make_train_step(model: Model, cfg: TrainConfig, rules=None):
    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch, rules=rules)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if cfg.grad_accum > 1:
            def micro(carry, mb):
                g_acc, m_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = {k: m_acc.get(k, 0.0) + v for k, v in metrics.items()}
                return (g_acc, m_acc), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, metrics), _ = jax.lax.scan(
                micro, (zeros, {}), batch)     # batch: stacked microbatches
            grads = jax.tree.map(lambda g: g / cfg.grad_accum, grads)
            metrics = {k: v / cfg.grad_accum for k, v in metrics.items()}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        params2, opt_state2, om = opt.update(cfg.opt, params, grads, opt_state)
        metrics.update(om)
        return params2, opt_state2, metrics

    return train_step


def train(model: Model, params, data_iter: Iterator[Dict], cfg: TrainConfig,
          log_fn: Optional[Callable[[int, Dict], None]] = None):
    """Single-host training; returns (params, history)."""
    opt_state = opt.init(cfg.opt, params)
    step_fn = jax.jit(make_train_step(model, cfg))
    history = []
    t0 = time.time()
    for step in range(cfg.steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % cfg.log_every == 0 or step == cfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall"] = time.time() - t0
            history.append(m)
            if log_fn:
                log_fn(step, m)
    return params, history
