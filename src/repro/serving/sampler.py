"""Token samplers: greedy / temperature / top-k, all jit-safe."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => no truncation


def sample(logits, key, cfg: SamplerConfig):
    """logits: (B, 1, V) or (B, V) -> (B,) int32."""
    if logits.ndim == 3:
        logits = logits[:, -1]
    if cfg.temperature <= 0.0:
        return logits.argmax(-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)
