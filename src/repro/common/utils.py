"""Small shared helpers: pytree sizes, dtype plumbing, deterministic RNG."""
from __future__ import annotations

import dataclasses
import logging
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_num_params(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_num_bytes(tree: PyTree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} EiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


class count_compiles:
    """Context manager counting XLA compilations inside the `with` block by
    capturing jax's `jax_log_compiles` log records.  The handle exposes
    `.count` and `.msgs`.  Used by the retrieval-engine tests and the
    steady-state benchmark to assert the device-resident search never
    recompiles while the bank grows within one capacity bucket — keep the
    'Compiling' message match in sync with the pinned jax version (the
    tests include a positive control so silent breakage is caught)."""

    class _Handler(logging.Handler):
        def __init__(self):
            super().__init__(level=logging.DEBUG)
            self.count, self.msgs = 0, []

        def emit(self, record):
            msg = record.getMessage()
            if "Compiling" in msg:
                self.count += 1
                self.msgs.append(msg[:120])

    def __enter__(self):
        self.handler = self._Handler()
        self.logger = logging.getLogger("jax")
        self.prev_level = self.logger.level
        self.logger.addHandler(self.handler)
        self.logger.setLevel(logging.DEBUG)
        jax.config.update("jax_log_compiles", True)
        return self.handler

    def __exit__(self, *exc):
        jax.config.update("jax_log_compiles", False)
        self.logger.removeHandler(self.handler)
        self.logger.setLevel(self.prev_level)
        return False


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


def fold_key(key: jax.Array, *names: str) -> jax.Array:
    """Derive a named sub-key deterministically from string names."""
    for name in names:
        h = int.from_bytes(name.encode("utf-8")[:8].ljust(8, b"\0"), "little")
        key = jax.random.fold_in(key, h % (2**31 - 1))
    return key


def asdict_shallow(dc) -> dict:
    return {f.name: getattr(dc, f.name) for f in dataclasses.fields(dc)}


def stable_hash(text: str, mod: int) -> int:
    """Deterministic (cross-run, cross-process) string hash -> [0, mod)."""
    h = 2166136261
    for b in text.encode("utf-8"):
        h = ((h ^ b) * 16777619) & 0xFFFFFFFF
    return h % mod


def log_bucket(x: float, buckets: int = 64) -> int:
    if x <= 0:
        return 0
    return min(buckets - 1, int(math.log2(x + 1)))
