"""BM25 keyword index, TPU-adapted (DESIGN.md §3).

Classic BM25 walks inverted lists — pointer-chasing the TPU hates.  Here
terms hash into a fixed id space and documents are fixed-width padded id
rows, so scoring a query against the whole bank is a dense vectorised
comparison:  tf(t, d) = sum_j [doc_ids[d, j] == t].  Ranking semantics match
textbook BM25 up to hash collisions (property-tested against a dict-based
oracle in tests/).

Multi-tenant extension: documents may carry a namespace tag, and scoring can
be scoped to one namespace — df, N, and avg_len are then computed over that
namespace's live documents only, so a scoped query ranks exactly as it would
against an isolated per-tenant index.  `remove(ids)` tombstones documents
(ids keep their slots — the tid==doc-id alignment with the triple store and
vector bank survives — but dead docs never score or surface again).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import HashTokenizer, default_tokenizer


class BM25Index:
    def __init__(self, k1: float = 1.5, b: float = 0.75, max_doc_len: int = 32,
                 tokenizer: HashTokenizer | None = None):
        self.k1 = k1
        self.b = b
        self.max_doc_len = max_doc_len
        self.tokenizer = tokenizer or default_tokenizer()
        self._doc_rows: List[np.ndarray] = []
        self._doc_lens: List[int] = []
        self._doc_ns: List[int] = []          # -1 == untagged/default
        self._alive: List[bool] = []
        self._dirty = True
        self._docs_arr = None
        self._lens_arr = None

    def add(self, texts: Sequence[str],
            namespace: Optional[int] = None) -> List[int]:
        ns = -1 if namespace is None else int(namespace)
        ids = []
        for t in texts:
            tok = self.tokenizer.encode(t)[: self.max_doc_len]
            row = np.full((self.max_doc_len,), -1, np.int32)
            row[: len(tok)] = tok
            self._doc_rows.append(row)
            self._doc_lens.append(max(1, len(tok)))
            self._doc_ns.append(ns)
            self._alive.append(True)
            ids.append(len(self._doc_rows) - 1)
        self._dirty = True
        return ids

    def remove(self, ids: Sequence[int]) -> int:
        """Tombstone documents by id.  Returns #newly removed."""
        n = 0
        for i in ids:
            i = int(i)
            if 0 <= i < len(self._doc_rows) and self._alive[i]:
                self._alive[i] = False
                n += 1
        return n

    def __len__(self):
        return len(self._doc_rows)

    @property
    def alive_count(self) -> int:
        return int(sum(self._alive))

    def _arrays(self):
        if self._dirty:
            self._docs_arr = jnp.asarray(np.stack(self._doc_rows)) \
                if self._doc_rows else jnp.zeros((0, self.max_doc_len), jnp.int32)
            self._lens_arr = jnp.asarray(np.asarray(self._doc_lens, np.float32)) \
                if self._doc_lens else jnp.zeros((0,), jnp.float32)
            self._dirty = False
        return self._docs_arr, self._lens_arr

    def _selection(self, namespace: Optional[int]) -> np.ndarray:
        """(N,) bool: live docs, restricted to `namespace` when given."""
        sel = np.asarray(self._alive, bool)
        if namespace is not None:
            sel = sel & (np.asarray(self._doc_ns, np.int32) == int(namespace))
        return sel

    def scores(self, query: str, namespace: Optional[int] = None) -> jnp.ndarray:
        """BM25 scores over all docs -> (N,) f32 (empty -> (0,)).  Docs
        outside the selection (dead, or other namespaces when `namespace` is
        given) score 0; corpus statistics (N, df, avg_len) come from the
        selection only, so scoped scores equal an isolated index's."""
        return self._scores_sel(query, self._selection(namespace))

    def _scores_sel(self, query: str, sel_np: np.ndarray) -> jnp.ndarray:
        docs, lens = self._arrays()
        N = docs.shape[0]
        if N == 0:
            return jnp.zeros((0,), jnp.float32)
        n_sel = int(sel_np.sum())
        terms = list(dict.fromkeys(self.tokenizer.encode(query)))
        if n_sel == 0 or not terms:
            return jnp.zeros((N,), jnp.float32)
        lens_np = np.asarray(self._doc_lens, np.float32)
        avg_len = float(lens_np[sel_np].mean())
        sel = jnp.asarray(sel_np)
        norm = self.k1 * (1.0 - self.b + self.b * lens / avg_len)
        # per-term tf columns dispatch lazily (no host sync); stacking to
        # (N, T) keeps peak memory at N*T instead of an N*L*T broadcast,
        # and the df pull below is the single device sync per query
        tf = jnp.stack([(docs == t).sum(axis=1).astype(jnp.float32)
                        for t in terms], axis=1)                    # (N, T)
        df = np.asarray(((tf > 0) & sel[:, None]).sum(axis=0),
                        np.float32)                                 # (T,)
        idf = np.where(df > 0,
                       np.log(1.0 + (n_sel - df + 0.5) / (df + 0.5)), 0.0)
        out = (jnp.asarray(idf)[None, :] * tf * (self.k1 + 1.0)
               / (tf + norm[:, None])).sum(axis=1)
        return jnp.where(sel, out, 0.0)

    def topk(self, query: str, k: int, namespace: Optional[int] = None):
        """Top-k (scores, global doc ids), restricted to the selection."""
        sel = self._selection(namespace) if len(self._doc_rows) else \
            np.zeros((0,), bool)
        cand = np.where(sel)[0]
        if cand.size == 0:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        s = np.asarray(self._scores_sel(query, sel))[cand]
        k = min(k, cand.size)
        order = np.argsort(-s, kind="stable")[:k]
        return s[order], cand[order]
