"""Typed request API (core/api.py) + cross-client micro-batching scheduler
(core/scheduler.py): N concurrent single-request clients resolve
bit-identically to sequential retrieve() through ONE batched dense/sparse/
fuse launch per tick, per-request options (top_k / weights / stages) are
honored inside the shared launches, writes keep read-your-writes and WAL
ordering through the lifecycle runtime, and multi-writer ticks group-commit
into one fsync'd segment."""
import threading

import numpy as np
import pytest

from repro.core import (CompactRequest, EvictRequest, MemoryResponse,
                        MemoryScheduler, MemoryService, Message, RawRetrieval,
                        RecordRequest, RetrievalPlan, RetrieveRequest)
from repro.core import service as svc_mod
from repro.core.bm25 import BM25Index
from repro.core.embedder import HashEmbedder
from repro.core.hybrid import rrf_fuse, rrf_fuse_batch
from repro.core.vector_index import VectorIndex

EMB = HashEmbedder()


def _svc(**kw):
    kw.setdefault("use_kernel", False)
    kw.setdefault("budget", 800)
    return MemoryService(EMB, **kw)


def _session(texts, speaker="U", ts=1700000000.0):
    return [Message(speaker, t, ts) for t in texts]


def _fill(svc, users=4):
    for u in range(users):
        svc.record(f"u{u}/c0", "s0", _session(
            [f"I live in City{u}.", f"I work as a welder.",
             f"I adopted a pet named P{u}."]))
    return svc


def _ctx_equal(got, want):
    assert got.text == want.text
    assert [t.text() for t in got.triples] == [t.text() for t in want.triples]
    assert got.token_count == want.token_count


QUERY = "Which city does the user live in?"


# -- typed requests: validation ------------------------------------------------

def test_request_and_plan_validation():
    with pytest.raises(ValueError, match="top_k"):
        RetrieveRequest("a/c0", "q", top_k=0)
    with pytest.raises(TypeError, match="query"):
        RetrieveRequest("a/c0", None)
    with pytest.raises(ValueError, match="unknown retrieval stages"):
        RetrieveRequest("a/c0", "q", stages=("dense", "bm42"))
    with pytest.raises(ValueError, match="at least one"):
        RetrievalPlan(stages=("fuse", "budget"))
    with pytest.raises(ValueError, match="message"):
        RecordRequest("a/c0", "s0", [])
    # fuse is implied, stages dedupe
    p = RetrievalPlan(stages=("dense", "dense", "budget"))
    assert p.stages == ("dense", "budget", "fuse")
    assert p.wants_dense and not p.wants_sparse and p.wants_budget
    assert RetrievalPlan.raw().wants_budget is False


def test_scheduler_rejects_untyped_submissions():
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, start=False)
    with pytest.raises(TypeError, match="typed requests"):
        sched.submit(("u0/c0", QUERY))
    sched.close()


# -- the acceptance contract: N clients == sequential, one launch per tick -----

def test_concurrent_single_clients_match_sequential_with_one_launch_per_tick(
        monkeypatch):
    """8 threads each submit ONE RetrieveRequest; the tick answers all of
    them bit-identically to sequential retrieve() calls through exactly one
    batched masked search + one stacked BM25 + one fused RRF launch."""
    svc = _fill(_svc())
    queries = [(f"u{i % 4}/c0",
                QUERY if i % 2 == 0 else "What pet was adopted?")
               for i in range(8)]
    want = [svc.retrieve(ns, q) for ns, q in queries]   # before mounting

    calls = {"dense": 0, "sparse": 0, "fuse": 0}
    real_dense = VectorIndex.search_batch
    real_sparse = BM25Index.topk_batch_dev
    real_fuse = svc_mod.rrf_fuse_batch

    def spy_dense(self, *a, **kw):
        calls["dense"] += 1
        return real_dense(self, *a, **kw)

    def spy_sparse(self, *a, **kw):
        calls["sparse"] += 1
        return real_sparse(self, *a, **kw)

    def spy_fuse(*a, **kw):
        calls["fuse"] += 1
        return real_fuse(*a, **kw)

    monkeypatch.setattr(VectorIndex, "search_batch", spy_dense)
    monkeypatch.setattr(BM25Index, "topk_batch_dev", spy_sparse)
    monkeypatch.setattr(svc_mod, "rrf_fuse_batch", spy_fuse)

    sched = MemoryScheduler(svc, start=False)   # manual ticks: deterministic
    futs = [None] * len(queries)
    barrier = threading.Barrier(len(queries))

    def client(i, ns, q):
        barrier.wait()
        futs[i] = sched.submit(RetrieveRequest(ns, q))

    threads = [threading.Thread(target=client, args=(i, ns, q))
               for i, (ns, q) in enumerate(queries)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tick = sched.run_tick_once()
    assert tick == {"requests": 8, "retrieve_launches": 1}
    assert calls == {"dense": 1, "sparse": 1, "fuse": 1}, \
        "a tick of single-request clients must share ONE launch per stage"
    # futures resolved in submission order with the envelope filled in
    for i, fut in enumerate(futs):
        resp = fut.result(timeout=5)
        assert isinstance(resp, MemoryResponse) and resp.ok
        assert resp.op == "retrieve" and resp.batch_size == 8
        assert resp.queued_s >= 0.0 and resp.service_s > 0.0
        assert resp.token_count == resp.payload.token_count
    # ... and bit-identically to the sequential oracle (futs[i] belongs to
    # queries[i] by construction of client(i, ...), whatever order the
    # racing threads enqueued in)
    for f, w in zip(futs, want):
        _ctx_equal(f.result().payload, w)
    sched.close()


def test_daemon_scheduler_threads_resolve_identically():
    """The same contract through the real daemon: clients block on
    .result() while the tick window collects them."""
    svc = _fill(_svc())
    queries = [(f"u{i % 4}/c0", QUERY) for i in range(6)]
    want = [svc.retrieve(ns, q) for ns, q in queries]
    sched = MemoryScheduler(svc, tick_interval_s=0.02, max_batch=8)
    got = [None] * len(queries)

    def client(i, ns, q):
        # the mounted scheduler re-routes the sync wrapper itself
        got[i] = svc.retrieve(ns, q)

    threads = [threading.Thread(target=client, args=(i, ns, q))
               for i, (ns, q) in enumerate(queries)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for g, w in zip(got, want):
        _ctx_equal(g, w)
    st = sched.stats()
    assert st["retrieves"] == 6
    assert st["retrieve_launches"] >= 1
    sched.close()
    # unmounted after close: the wrapper goes back to the direct path
    assert svc.scheduler is None
    _ctx_equal(svc.retrieve(*queries[0]), want[0])


# -- per-request options in one shared launch ----------------------------------

def test_per_request_top_k_is_per_request():
    """The old batch-global k silently shared one k across mixed-k clients;
    the typed API slices each request to its own k from the max-k fusion."""
    svc = _fill(_svc())
    reqs = [RetrieveRequest("u0/c0", QUERY, top_k=1),
            RetrieveRequest("u1/c0", QUERY, top_k=3),
            RetrieveRequest("u2/c0", QUERY)]           # service default (10)
    batched = svc.retrieve_batch(reqs)
    for req, got in zip(reqs, batched):
        want = svc.execute([req])[0]
        _ctx_equal(got, want)
    # and the legacy kwarg still works as the per-request default
    legacy = svc.retrieve_batch([("u0/c0", QUERY), ("u1/c0", QUERY)], top_k=2)
    for got, ns in zip(legacy, ["u0/c0", "u1/c0"]):
        _ctx_equal(got, svc.retrieve(ns, QUERY, top_k=2))
    # explicit per-request top_k beats the batch-global kwarg
    mixed = svc.retrieve_batch([RetrieveRequest("u0/c0", QUERY, top_k=1)],
                               top_k=7)
    _ctx_equal(mixed[0], svc.retrieve("u0/c0", QUERY, top_k=1))


def test_mixed_top_k_reuses_bounded_fusion_executables():
    """top_k is a jit-static arg of the fusion, so it buckets to pow2 like
    the Q shape: once the k buckets are warm, mixed-k traffic (the
    scheduler's max-over-a-tick) mints zero new executables."""
    from repro.common.utils import count_compiles
    svc = _fill(_svc())
    reqs = [("u0/c0", QUERY), ("u1/c0", QUERY)]
    for k in (4, 8, 16):                       # warm the pow2 k buckets
        svc.retrieve_batch(reqs, top_k=k)
    with count_compiles() as cc:
        for k in (3, 5, 6, 8, 10, 12, 16):
            got = svc.retrieve_batch(reqs, top_k=k)
            assert len(got) == 2
    assert cc.count == 0, \
        f"mixed top_k minted executables: {cc.msgs[:5]}"


def test_per_request_weights_and_stage_variants_in_mixed_batch():
    """dense-only / sparse-only / custom-weight requests inside one batch
    answer exactly like the same request executed alone."""
    svc = _fill(_svc())
    reqs = [RetrieveRequest("u0/c0", QUERY, stages=("dense", "budget")),
            RetrieveRequest("u1/c0", QUERY, stages=("sparse", "budget")),
            RetrieveRequest("u2/c0", QUERY, dense_weight=0.2,
                            sparse_weight=1.5),
            RetrieveRequest("u3/c0", QUERY)]
    batched = svc.retrieve_batch(reqs)
    for req, got in zip(reqs, batched):
        _ctx_equal(got, svc.execute([req])[0])
    # plan-level variants drive whole batches too
    dense_batch = svc.retrieve_batch([("u0/c0", QUERY), ("u1/c0", QUERY)],
                                     plan=RetrievalPlan.dense_only())
    for got, ns in zip(dense_batch, ["u0/c0", "u1/c0"]):
        _ctx_equal(got, svc.execute(
            [RetrieveRequest(ns, QUERY, stages=("dense", "budget"))])[0])


def test_raw_plan_returns_fused_ids_consistent_with_budget_path():
    svc = _fill(_svc())
    [raw] = svc.retrieve_batch([("u0/c0", QUERY)], plan=RetrievalPlan.raw())
    assert isinstance(raw, RawRetrieval)
    assert raw.row_ids and len(raw.row_ids) == len(raw.scores) \
        == len(raw.triple_ids)
    assert raw.scores == sorted(raw.scores, reverse=True)
    # the budget path ranks the same triples in the same order (before
    # token budgeting truncates)
    ctx = svc.retrieve("u0/c0", QUERY)
    t = svc.store.get("u0/c0")
    raw_texts = [t.triples.get(tid).text() for tid in raw.triple_ids]
    ctx_texts = [tr.text() for tr in ctx.triples]
    assert raw_texts[: len(ctx_texts)] == ctx_texts
    # unknown namespace -> empty raw payload, no tenant allocated
    [ghost] = svc.retrieve_batch([("ghost/c0", QUERY)],
                                 plan=RetrievalPlan.raw())
    assert ghost.row_ids == [] and "ghost/c0" not in svc.namespaces()


def test_rrf_fuse_batch_per_row_weights_match_scalar_oracle():
    rng = np.random.default_rng(3)
    for _ in range(40):
        B = int(rng.integers(1, 5))
        d = rng.integers(-1, 10, size=(B, 6)).astype(np.int32)
        s = rng.integers(-1, 10, size=(B, 5)).astype(np.int32)
        w = rng.uniform(0.1, 2.0, size=(B, 2)).astype(np.float32)
        fi, fs = rrf_fuse_batch([d, s], weights=w, k=8)
        fi, fs = np.asarray(fi), np.asarray(fs)
        for b in range(B):
            want = rrf_fuse([d[b].tolist(), s[b].tolist()],
                            weights=[float(w[b, 0]), float(w[b, 1])])[:8]
            got = [(int(i), float(x)) for i, x in zip(fi[b], fs[b])
                   if i >= 0]
            assert got == want
    with pytest.raises(ValueError, match="weights shape"):
        rrf_fuse_batch([d, s], weights=np.ones((B + 1, 2), np.float32))


# -- writes through the scheduler ----------------------------------------------

def test_write_then_read_in_one_tick_is_read_your_writes():
    svc = _svc()
    sched = MemoryScheduler(svc, start=False)
    f_rec = sched.submit(RecordRequest("w/c0", "s0",
                                       _session(["I live in Quito."])))
    f_ret = sched.submit(RetrieveRequest("w/c0", QUERY))
    sched.run_tick_once()
    rec = f_rec.result(timeout=5)
    assert rec.ok and rec.payload["queued"]
    ctx = f_ret.result(timeout=5).result()
    assert any(t.object == "quito" for t in ctx.triples), \
        "a write submitted before a read must be visible to it"
    # the tick's flush drained everything: nothing pending afterwards
    assert svc.stats()["pending_depth"] == 0
    sched.close()


def test_scheduler_writes_preserve_backpressure_and_evict_compact(tmp_path):
    from repro.core import LifecyclePolicy
    policy = LifecyclePolicy(max_pending=1, backpressure="block")
    svc = MemoryService(EMB, use_kernel=False, budget=800, policy=policy,
                        data_dir=str(tmp_path / "d"))
    sched = MemoryScheduler(svc, start=False)
    futs = [sched.submit(RecordRequest(f"t{i}/c0", "s0",
                                       _session([f"I live in City{i}."])))
            for i in range(3)]
    futs.append(sched.submit(EvictRequest("t0/c0")))
    futs.append(sched.submit(CompactRequest()))
    sched.run_tick_once()
    resps = [f.result(timeout=5) for f in futs]
    assert all(r.ok for r in resps), [r.error for r in resps]
    assert resps[0].payload["durable"] is True
    assert resps[3].op == "evict" and resps[3].payload == 1
    assert resps[4].op == "compact" and resps[4].payload["dropped"] == 1
    assert svc.retrieve("t1/c0", QUERY).triples
    svc.close()


def test_scheduler_honors_reject_backpressure(tmp_path):
    """`backpressure="reject"` must shed scheduler-routed writes exactly
    like direct callers' — the future carries the BackpressureError, the
    queue is not silently drained."""
    from repro.core import BackpressureError, LifecyclePolicy
    policy = LifecyclePolicy(max_pending=1, backpressure="reject")
    svc = MemoryService(EMB, use_kernel=False, budget=800, policy=policy,
                        data_dir=str(tmp_path / "d"))
    svc.enqueue("a/c0", "s0", _session(["I live in Oslo."]))  # queue full
    sched = MemoryScheduler(svc, start=False)
    fut = sched.submit(RecordRequest("b/c0", "s0",
                                     _session(["I live in Quito."])))
    sched.run_tick_once()
    resp = fut.result(timeout=5)
    assert resp.status == "error"
    with pytest.raises(BackpressureError):
        resp.result()
    assert svc.stats()["pending_depth"] == 1, \
        "reject mode must not drain the queue behind the policy's back"
    sched.close()
    svc.close(final_snapshot=False)


def test_multi_writer_tick_group_commits_one_segment_and_recovers(tmp_path):
    svc = MemoryService(EMB, use_kernel=False, budget=800,
                        data_dir=str(tmp_path / "d"))
    svc.record("a/c0", "s0", _session(["I live in Oslo."]))
    segs0 = svc.stats()["wal_segments"]
    sched = MemoryScheduler(svc, start=False)
    sched.submit(RecordRequest("b/c0", "s0", _session(["I live in Quito."])))
    sched.submit(RecordRequest("c/c0", "s0", _session(["I live in Hanoi."])))
    sched.submit(EvictRequest("a/c0"))
    sched.run_tick_once()
    assert svc.stats()["wal_segments"] == segs0 + 1, \
        "a multi-writer tick must coalesce into ONE fsync'd segment"
    assert sched.counters["group_commits"] == 1
    queries = [("a/c0", QUERY), ("b/c0", QUERY), ("c/c0", QUERY)]
    want = [c.text for c in svc.retrieve_batch(queries)]
    sched.close()
    svc.close(final_snapshot=False)
    restored = MemoryService.recover(str(tmp_path / "d"), HashEmbedder(),
                                     use_kernel=False, budget=800)
    assert [c.text for c in restored.retrieve_batch(queries)] == want


def test_errors_resolve_futures_instead_of_killing_the_tick(monkeypatch):
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, start=False)

    def boom(texts):
        raise RuntimeError("embedder down")

    f_bad = sched.submit(RetrieveRequest("u0/c0", QUERY))
    monkeypatch.setattr(svc.embedder, "embed_texts", boom, raising=False)
    sched.run_tick_once()
    monkeypatch.undo()
    resp = f_bad.result(timeout=5)
    assert resp.status == "error" and "embedder down" in resp.error
    with pytest.raises(RuntimeError, match="embedder down"):
        resp.result()
    # the scheduler survives: the next tick answers fine
    f_ok = sched.submit(RetrieveRequest("u0/c0", QUERY))
    sched.run_tick_once()
    assert f_ok.result(timeout=5).ok
    sched.close()
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit(RetrieveRequest("u0/c0", QUERY))


def test_sparse_only_batch_never_embeds(monkeypatch):
    """A batch with no dense stage must skip the embed call entirely (it
    would be pure waste — only the dense search consumes query vectors)."""
    svc = _fill(_svc())
    calls = []
    real = svc.embedder.embed_texts
    monkeypatch.setattr(svc.embedder, "embed_texts",
                        lambda texts: (calls.append(len(texts)),
                                       real(texts))[1], raising=False)
    got = svc.retrieve_batch([("u0/c0", QUERY), ("u1/c0", QUERY)],
                             plan=RetrievalPlan.sparse_only())
    assert calls == [], "sparse-only retrieval must not embed queries"
    assert got[0].triples
    # in a mixed batch, only the dense-stage queries embed (one call)
    svc.retrieve_batch([RetrieveRequest("u0/c0", QUERY),
                        RetrieveRequest("u1/c0", QUERY,
                                        stages=("sparse", "budget"))])
    assert calls == [1]


def test_closed_scheduler_race_falls_back_to_direct(monkeypatch):
    """If the scheduler closes between can_submit() and the submission
    (shutdown racing a reader), the sync wrapper falls back to the direct
    engine instead of surfacing the closed-scheduler error."""
    svc = _fill(_svc())
    want = svc.retrieve("u0/c0", QUERY)
    sched = MemoryScheduler(svc, start=True)
    sched.close()
    svc.scheduler = sched                          # re-create the race
    monkeypatch.setattr(sched, "can_submit", lambda: True)
    try:
        _ctx_equal(svc.retrieve("u0/c0", QUERY), want)
    finally:
        svc.scheduler = None


def test_close_drains_queued_requests():
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, start=False)
    futs = [sched.submit(RetrieveRequest("u0/c0", QUERY)) for _ in range(3)]
    sched.close()                        # no tick ever ran
    for f in futs:
        assert f.result(timeout=5).ok, "close() must not strand futures"
