"""Distributed retrieval parity: sharded_topk on a CPU mesh of fake host
devices must return exactly the single-device topk_mips / topk_mips_ref
results, including the k > shard_rows edge and the namespace-masked
multi-tenant path (local Pallas kernel per shard → all_gather → re-rank).
Runs in a subprocess so the main pytest process keeps its single CPU device
(same pattern as test_distribution.py)."""
import subprocess
import sys
import textwrap

import pytest


def _run_parity(code: str):
    # JAX_PLATFORMS=cpu keeps the child off the libtpu plugin probe: its
    # /tmp/libtpu_lockfile serializes against other jax processes (the
    # pytest parent / earlier subprocess tests) and can stall the child
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "PARITY_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_sharded_topk_parity_cpu_mesh():
    _run_parity(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.vector_index import sharded_topk
        from repro.kernels import ops, ref

        mesh = jax.make_mesh((4, 2), ("data", "model"))   # 8 shards
        q = jax.random.normal(jax.random.PRNGKey(0), (5, 32))
        bank = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        # shard_rows = 64/8 = 8: k=6 fits in one shard, k=12 exceeds it;
        # the local top-k routes through the Pallas kernel (interpret mode)
        for k in (6, 12):
            for use_kernel in (True, False):
                with mesh:
                    s, i = sharded_topk(q, bank, k=k, mesh=mesh,
                                        use_kernel=use_kernel)
                sr, ir = ref.topk_mips_ref(q, bank, k=k)
                np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
                np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                                           rtol=1e-5)
            sk, ik = ops.topk_mips(q, bank, k=k, block_q=8, block_n=16)
            np.testing.assert_array_equal(np.asarray(i), np.asarray(ik))
            np.testing.assert_allclose(np.asarray(s), np.asarray(sk),
                                       rtol=1e-4)
        print("PARITY_OK")
    """))


@pytest.mark.slow
def test_sharded_topk_masked_parity_cpu_mesh():
    """Namespace-masked sharded search == the single-device masked oracle,
    tombstones included, even when a tenant owns fewer than k rows and when
    k exceeds the per-shard row count."""
    _run_parity(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.vector_index import sharded_topk
        from repro.kernels import ops, ref

        mesh = jax.make_mesh((4, 2), ("data", "model"))   # 8 shards of 8 rows
        q = jax.random.normal(jax.random.PRNGKey(0), (6, 32))
        bank = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        # ns 0/1/2 interleaved, ns 7 owns exactly 2 rows, ns 9 owns none,
        # and every 7th row is a tombstone
        bank_ns = np.arange(64) % 3
        bank_ns[[5, 33]] = 7
        bank_ns[::7] = -1
        bank_ns = jnp.asarray(bank_ns, jnp.int32)
        q_ns = jnp.asarray([0, 1, 2, 7, 9, 0], jnp.int32)
        for k in (6, 12):                 # 12 > shard_rows = 8
            for use_kernel in (True, False):
                with mesh:
                    s, i = sharded_topk(q, bank, k=k, mesh=mesh,
                                        q_ns=q_ns, bank_ns=bank_ns,
                                        use_kernel=use_kernel)
                sr, ir = ref.topk_mips_masked_ref(q, bank, q_ns, bank_ns, k=k)
                np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
                live = np.asarray(ir) >= 0
                np.testing.assert_allclose(np.asarray(s)[live],
                                           np.asarray(sr)[live], rtol=1e-5)
            sk, ik = ops.topk_mips_masked(q, bank, q_ns, bank_ns, k=k,
                                          block_q=8, block_n=16)
            np.testing.assert_array_equal(np.asarray(i), np.asarray(ik))
        print("PARITY_OK")
    """))
