"""Multi-tenant retrieval throughput: batched vs sequential (the tentpole
metric of the MemoryService).  N tenants each hold a few ingested sessions
in one packed bank; a batch of per-tenant queries is answered either as N
sequential `retrieve` calls (N embed calls + N top-k launches) or as ONE
`retrieve_batch` (one embed call + one namespace-masked topk_mips launch).

Wall-clock here is CPU (kernel off by default — Pallas interpret mode would
time the emulator, not the algorithm); on TPU the batched path additionally
amortizes kernel launch + HBM bank streaming across the whole batch.

    PYTHONPATH=src python benchmarks/service_throughput.py [--kernel]
"""
from __future__ import annotations

import time

from repro.core.extraction import Message
from repro.core.service import MemoryService
from repro.core.embedder import HashEmbedder

BATCH_SIZES = (1, 8, 32)
N_TENANTS = 32
SESSIONS_PER_TENANT = 3

FACTS = [
    "I work as a {job} and I live in {city}.",
    "I adopted a {pet} named {name}.",
    "My favorite color is {color}.",
]
JOBS = ["botanist", "welder", "pilot", "baker", "cartographer", "luthier"]
CITIES = ["tallinn", "porto", "cusco", "sapporo", "tromso", "windhoek"]
PETS = ["hedgehog", "parrot", "gecko", "ferret", "axolotl", "magpie"]
NAMES = ["biscuit", "olive", "comet", "pickle", "juniper", "maple"]
COLORS = ["indigo", "ochre", "teal", "crimson", "sage", "amber"]


def _build_service(use_kernel: bool) -> MemoryService:
    svc = MemoryService(HashEmbedder(), budget=800, use_kernel=use_kernel)
    for u in range(N_TENANTS):
        ns = f"user{u}/c0"
        for s in range(SESSIONS_PER_TENANT):
            texts = [f.format(job=JOBS[(u + s) % len(JOBS)],
                              city=CITIES[(u + s) % len(CITIES)],
                              pet=PETS[(u + s) % len(PETS)],
                              name=NAMES[(u + s) % len(NAMES)],
                              color=COLORS[(u + s) % len(COLORS)])
                     for f in FACTS]
            msgs = [Message(f"user{u}", t, 1700000000.0 + s) for t in texts]
            svc.record(ns, f"s{s}", msgs)
    return svc


def _time(fn, iters: int = 5) -> float:
    fn()                       # warmup (jit caches, lazy arrays)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run(csv_rows, use_kernel: bool = False):
    print("\n# MemoryService throughput — batched vs sequential retrieval"
          + (" [pallas kernel]" if use_kernel else " [jnp ref path]"))
    svc = _build_service(use_kernel)
    queries = [(f"user{u}/c0", f"Which city does user{u} live in?")
               for u in range(N_TENANTS)]
    for B in BATCH_SIZES:
        batch = queries[:B]
        t_seq = _time(lambda: [svc.retrieve(ns, q) for ns, q in batch])
        t_bat = _time(lambda: svc.retrieve_batch(batch))
        speedup = t_seq / t_bat
        qps_seq = B / t_seq
        qps_bat = B / t_bat
        print(f"batch {B:3d}: sequential {t_seq*1e3:8.1f}ms ({qps_seq:7.1f} q/s)"
              f" | batched {t_bat*1e3:8.1f}ms ({qps_bat:7.1f} q/s)"
              f" | speedup {speedup:5.2f}x")
        csv_rows.append((f"service/batch{B}", t_bat * 1e6,
                         f"{speedup:.2f}x vs sequential"))
    return csv_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", action="store_true",
                    help="route dense search through the Pallas kernel "
                         "(interpret mode off-TPU: slow, for parity checks)")
    args = ap.parse_args()
    run([], use_kernel=args.kernel)
