"""Memory-augmented agent serving: the full Memori stack end-to-end.

    PYTHONPATH=src python examples/agent_serve.py

A small LM is served with continuous batching behind the MemoriClient SDK;
every chat turn retrieves structured memory, injects it into the prompt, and
records the exchange back through Advanced Augmentation.  The LM is
random-init (this box trains ~minutes, not the hours a useful chat model
needs) — the demo shows the *system*: interception, retrieval, token
accounting, batched decode.
"""
import time

import jax

from repro.configs import get_config
from repro.core import MemoriClient, MemoriMemory, Message
from repro.core.embedder import HashEmbedder
from repro.data.tokenizer import HashTokenizer
from repro.models.model_api import Model
from repro.serving.engine import Engine
from repro.serving.sampler import SamplerConfig


def main():
    cfg = get_config("memori-agent").reduced(layers=2, d_model=128)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashTokenizer(cfg.vocab_size)
    engine = Engine(model, params, max_len=192, slots=2,
                    sampler=SamplerConfig(temperature=0.9, top_k=50),
                    tokenizer=tok)

    def llm(prompt: str) -> str:
        return engine.generate([prompt[-600:]], max_new_tokens=16)[0]

    memory = MemoriMemory(HashEmbedder(), budget=800, use_kernel=False)
    client = MemoriClient(llm, memory, user_name="Priya")

    turns = [
        "Hi there! I am Priya.",
        "I work as a botanist and I live in Tallinn.",
        "My favorite color is indigo.",
        "I adopted a hedgehog named Biscuit.",
    ]
    for t in turns:
        reply = client.chat(t, timestamp=time.time())
        print(f"Priya: {t}\n  agent: {reply[:60]}")
    client.end_session()

    print("\nmemory after session:", memory.stats())
    for q in ["What is the name of Priya's hedgehog?",
              "Which city does Priya live in?"]:
        ctx = memory.retrieve(q)
        print(f"\nQ: {q}  ({ctx.token_count} tokens injected)")
        for t in ctx.triples[:3]:
            print(f"   {t.render()}")
        print(f"   engine stats: {engine.stats}")


if __name__ == "__main__":
    main()
