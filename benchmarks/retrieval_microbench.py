"""Retrieval hot-path microbenchmark.

Two modes:

* quick (default; what `benchmarks/run.py` invokes): the original
  kernel-vs-oracle wall-clock rows on growing bank sizes plus the v5e
  roofline terms (CPU wall-clock is indicative only — EXPERIMENTS.md
  §Roofline has the TPU numbers).

* steady (`--steady`): the device-resident engine acceptance benchmark.
  A bank of `--rows` rows is grown one append at a time while a batch of
  tenant queries is answered after every append — the serving pattern.
  Two implementations of the same read path are timed (warmup first, then
  `block_until_ready` timing):

    - host-roundtrip: the pre-engine code path, faithfully preserved —
      host numpy bank, per-call `jnp.asarray(bank)` upload, per-call
      row-namespace rebuild from a Python list, eager masked-oracle
      scoring;
    - device-resident: `VectorIndex.search_batch` — capacity-padded device
      buffers updated in place, cached device labels, one stable-shape
      jitted launch with the live-row count as a traced scalar.

  A compile counter (jax_log_compiles capture) runs over the growth window
  and the benchmark ASSERTS zero recompiles for the device path while the
  bank grows within one power-of-two capacity bucket.

    PYTHONPATH=src python benchmarks/retrieval_microbench.py --steady
        [--rows 65000] [--batch 8] [--iters 5] [--json BENCH_retrieval.json]

* quantized (`--quantized`): the int8-bank acceptance benchmark.  The same
  >= 64k-row steady-state serving pattern is timed twice — f32 residency
  vs int8 codes + per-row scales with the exact-f32 rescore — and the
  benchmark reports (a) steady-state latency for both, (b) the bank bytes
  READ per search (the scan is bandwidth-bound, so this is the term the
  quantized kernel shrinks; ASSERTED >= 2x lower including the rescore
  gather), and (c) measured recall@k of the quantized index against the
  exact f32 oracle (`--assert-recall 0.95` gates it in CI).

    PYTHONPATH=src python benchmarks/retrieval_microbench.py --quantized
        [--rows 65000] [--k 10] [--assert-recall 0.95]
        [--json BENCH_quantized.json]
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import count_compiles
from repro.core.vector_index import VectorIndex
from repro.kernels import ops, ref as kref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

D = 256


class HostRoundtripIndex:
    """The pre-engine read path, kept verbatim for comparison: the bank
    lives in host numpy, every search re-uploads it (`jnp.asarray`) and
    rebuilds the row->namespace array from a Python list, and the masked
    oracle runs eagerly (the use_kernel=False service configuration)."""

    def __init__(self, dim: int, capacity: int = 1024):
        self.dim, self.n = dim, 0
        self._bank = np.zeros((capacity, dim), np.float32)
        self._row_ns: list = []

    def add(self, vecs, ns):
        m = vecs.shape[0]
        while self.n + m > self._bank.shape[0]:
            self._bank = np.concatenate(
                [self._bank, np.zeros_like(self._bank)], axis=0)
        self._bank[self.n: self.n + m] = vecs
        self._row_ns.extend(int(x) for x in np.broadcast_to(ns, (m,)))
        self.n += m

    def search(self, queries, q_ns, k: int):
        bank = jnp.asarray(self._bank[: self.n])          # per-call upload
        row_ns = np.asarray(self._row_ns, np.int32)       # per-call rebuild
        s, i = kref.topk_mips_masked_ref(
            jnp.asarray(queries), bank, jnp.asarray(q_ns, jnp.int32),
            jnp.asarray(row_ns), k=k)
        return s, i


def _grow_and_search_loop(add_fn, search_fn, rows_per_iter: int, iters: int,
                          warmup: int = 2):
    """The serving pattern: append, then answer a query batch.  Returns
    seconds/iteration (device work fenced by block_until_ready)."""
    for _ in range(warmup):
        add_fn()
        search_fn()[1].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        add_fn()
        out = search_fn()
    out[1].block_until_ready()
    return (time.perf_counter() - t0) / iters


def run_steady(csv_rows, rows: int = 65000, batch: int = 8, iters: int = 5,
               k: int = 64, n_tenants: int = 32, json_out=None):
    print(f"\n# Retrieval steady state — device-resident engine vs "
          f"host-roundtrip path (N={rows}, B={batch}, k={k}, D={D}, CPU)")
    rng = np.random.default_rng(0)
    base = rng.standard_normal((rows, D)).astype(np.float32)
    base_ns = (np.arange(rows) % n_tenants).astype(np.int32)
    q = rng.standard_normal((batch, D)).astype(np.float32)
    q_ns = (np.arange(batch) % n_tenants).astype(np.int32)
    new_row = rng.standard_normal((1, D)).astype(np.float32)

    legacy = HostRoundtripIndex(D)
    legacy.add(base, base_ns)
    t_host = _grow_and_search_loop(
        lambda: legacy.add(new_row, [0]),
        lambda: legacy.search(q, q_ns, k), 1, iters)

    vi = VectorIndex(dim=D, use_kernel=False)
    vi.add(base, ns=base_ns)
    cap = vi.capacity
    assert vi.n + iters + 8 <= cap, \
        f"growth window {iters + 8} would cross the {cap} capacity bucket"
    t_dev = _grow_and_search_loop(
        lambda: vi.add(new_row, ns=[0]),
        lambda: vi.search_batch(q, q_ns, k=k), 1, iters)

    # zero-recompile assertion across further growth within the bucket
    with count_compiles() as cc:
        for _ in range(4):
            vi.add(new_row, ns=[0])
            _, i = vi.search_batch(q, q_ns, k=k)
        i.block_until_ready()
    if cc.count:
        raise AssertionError(
            f"device-resident search recompiled {cc.count}x while the bank "
            f"grew inside the {cap}-row capacity bucket: {cc.msgs[:3]}")

    speedup = t_host / t_dev
    print(f"rows {rows:7d} (capacity {cap}): host-roundtrip "
          f"{t_host*1e3:8.1f}ms/iter | device-resident {t_dev*1e3:8.1f}ms/iter"
          f" | speedup {speedup:5.2f}x | recompiles during growth: 0")
    csv_rows.append((f"retrieval/steady_N{rows}", t_dev * 1e6,
                     f"{speedup:.2f}x vs host-roundtrip"))
    if json_out is not None:
        json_out.append({
            "rows": rows, "capacity": cap, "batch": batch, "k": k,
            "t_host_roundtrip_ms": t_host * 1e3,
            "t_device_resident_ms": t_dev * 1e3,
            "speedup": speedup,
            "grow_steps_checked": 4, "recompiles": cc.count,
        })
    return csv_rows


def run_quantized(csv_rows, rows: int = 65000, batch: int = 8,
                  iters: int = 5, k: int = 10, n_tenants: int = 32,
                  assert_recall=None, json_out=None):
    """f32 vs int8 residency on the same steady-state serving pattern.

    `bank_bytes_read` is the per-search device traffic over the bank scan
    (the whole capacity-padded bank is streamed once per launch — the
    kernel is bandwidth-bound at serving batch sizes) plus, for the
    quantized path, the candidate-gather bytes of the exact rescore.
    Wall-clock on CPU is indicative; the bytes ratio is the claim."""
    print(f"\n# Quantized bank — f32 vs int8 + exact rescore "
          f"(N={rows}, B={batch}, k={k}, D={D}, CPU)")
    rng = np.random.default_rng(7)
    base = rng.standard_normal((rows, D)).astype(np.float32)
    base_ns = (np.arange(rows) % n_tenants).astype(np.int32)
    q = rng.standard_normal((batch, D)).astype(np.float32)
    q_ns = (np.arange(batch) % n_tenants).astype(np.int32)
    new_row = rng.standard_normal((1, D)).astype(np.float32)

    vi_f = VectorIndex(dim=D, use_kernel=False)
    vi_f.add(base, ns=base_ns)
    t_f32 = _grow_and_search_loop(
        lambda: vi_f.add(new_row, ns=[0]),
        lambda: vi_f.search_batch(q, q_ns, k=k), 1, iters)

    vi_q = VectorIndex(dim=D, use_kernel=False, quantize="int8", rescore=4)
    vi_q.add(base, ns=base_ns)
    t_int8 = _grow_and_search_loop(
        lambda: vi_q.add(new_row, ns=[0]),
        lambda: vi_q.search_batch(q, q_ns, k=k), 1, iters)

    # recall@k of the quantized index vs the exact f32 oracle (host mirror)
    s_q, i_q = vi_q.search_batch(q, q_ns, k=k)
    i_q = np.asarray(i_q)
    scores = q @ vi_q.bank[: vi_q.n].T
    mask = vi_q.alive() & (vi_q.row_namespaces()[None, :] == q_ns[:, None])
    scores = np.where(mask, scores, -np.inf)
    i_true = np.argsort(-scores, axis=1, kind="stable")[:, :k]
    recall = float(np.mean([
        len(set(i_q[r][i_q[r] >= 0]) & set(i_true[r])) / k
        for r in range(batch)]))
    hit_rate = (vi_q.counters["rescore_hits"]
                / max(1, vi_q.counters["rescore_rows"]))

    cap = vi_q.capacity
    kc = min(cap, 1 << (int(np.ceil(np.log2(max(1, k * vi_q.rescore))))))
    bytes_f32 = cap * D * 4
    bytes_int8 = cap * D * 1 + cap * 4 + batch * kc * D * 4  # codes+scales+gather
    ratio = bytes_f32 / bytes_int8
    print(f"rows {rows:7d} (capacity {cap}): f32 {t_f32*1e3:8.1f}ms/iter | "
          f"int8+rescore {t_int8*1e3:8.1f}ms/iter")
    print(f"bank bytes read/search: f32 {bytes_f32/2**20:7.1f}MiB | "
          f"int8 {bytes_int8/2**20:7.1f}MiB | ratio {ratio:5.2f}x")
    print(f"recall@{k} vs f32 oracle: {recall:.3f} | "
          f"rescore hit rate: {hit_rate:.3f}")
    if ratio < 2.0:
        raise AssertionError(
            f"quantized bank reads only {ratio:.2f}x fewer bytes (< 2x)")
    if assert_recall is not None and recall < assert_recall:
        raise AssertionError(
            f"quantized recall@{k} {recall:.3f} < required {assert_recall}")
    csv_rows.append((f"retrieval/quantized_N{rows}", t_int8 * 1e6,
                     f"{ratio:.2f}x fewer bank bytes, recall {recall:.3f}"))
    if json_out is not None:
        json_out.append({
            "rows": rows, "capacity": cap, "batch": batch, "k": k,
            "rescore": vi_q.rescore, "candidates_per_query": kc,
            "t_f32_ms": t_f32 * 1e3, "t_int8_ms": t_int8 * 1e3,
            "bank_bytes_read_f32": bytes_f32,
            "bank_bytes_read_int8": bytes_int8,
            "bytes_ratio": ratio,
            "recall_at_k": recall, "recall_required": assert_recall,
            "rescore_hit_rate": hit_rate,
        })
    return csv_rows


def run_quick(csv_rows):
    print("\n# Retrieval microbench — fused topk_mips vs jnp oracle")
    key = jax.random.PRNGKey(0)
    K = 32
    for N in (1024, 8192, 32768):
        q = jax.random.normal(key, (64, D))
        bank = jax.random.normal(jax.random.fold_in(key, 1), (N, D))
        t_ref = _time(lambda a, b: kref.topk_mips_ref(a, b, k=K), q, bank)
        flops = 2 * 64 * N * D
        bytes_ = (64 * D + N * D) * 4
        # v5e roofline for this op (exact MIPS is bandwidth-bound at Q=64)
        t_compute = flops / PEAK_FLOPS_BF16
        t_mem = bytes_ / HBM_BW
        print(f"N={N:6d}: jnp_ref {t_ref*1e6:9.0f}us/call | v5e roofline "
              f"compute {t_compute*1e6:6.2f}us, memory {t_mem*1e6:6.2f}us "
              f"(bound: {'memory' if t_mem > t_compute else 'compute'})")
        csv_rows.append((f"retrieval/topk_N{N}", t_ref * 1e6,
                         f"{t_mem*1e6:.2f}"))
    return csv_rows


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out[0].block_until_ready()
    return (time.time() - t0) / iters


def run(csv_rows, steady: bool = False, quantized: bool = False,
        rows: int = 65000, batch: int = 8, iters: int = 5, k: int = 10,
        assert_recall=None, json_path=None):
    report = {"steady_state": [], "quantized": []}
    if steady:
        run_steady(csv_rows, rows=rows, batch=batch, iters=iters,
                   json_out=report["steady_state"])
    if quantized:
        run_quantized(csv_rows, rows=rows, batch=batch, iters=iters, k=k,
                      assert_recall=assert_recall,
                      json_out=report["quantized"])
    if not steady and not quantized:
        run_quick(csv_rows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"\nwrote {json_path}")
    return csv_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steady", action="store_true",
                    help="steady-state device-resident vs host-roundtrip "
                         "comparison + zero-recompile assertion")
    ap.add_argument("--quantized", action="store_true",
                    help="f32 vs int8 residency: latency, bank-bytes-read "
                         "ratio (asserted >= 2x) and recall@k vs the oracle")
    ap.add_argument("--rows", type=int, default=65000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--k", type=int, default=10,
                    help="top-k for the quantized recall measurement")
    ap.add_argument("--assert-recall", type=float, default=None,
                    metavar="R", help="fail if quantized recall@k < R")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_retrieval.json artifact")
    args = ap.parse_args()
    run([], steady=args.steady, quantized=args.quantized, rows=args.rows,
        batch=args.batch, iters=args.iters, k=args.k,
        assert_recall=args.assert_recall, json_path=args.json)
