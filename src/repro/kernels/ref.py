"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def topk_mips_ref(queries, bank, k: int = 32, n_valid=None):
    """queries (Q,D), bank (N,D) -> (scores (Q,k) f32, indices (Q,k) i32).
    With `n_valid` (traced i32 scalar), rows >= n_valid are padding: they
    score NEG_INF and report index -1 — matching the kernel's stable-shape
    contract over capacity-padded banks."""
    s = jnp.einsum("qd,nd->qn", queries.astype(jnp.float32),
                   bank.astype(jnp.float32))
    if n_valid is not None:
        col = jnp.arange(bank.shape[0], dtype=jnp.int32)[None, :]
        s = jnp.where(col < n_valid, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    if n_valid is not None:
        idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def quantize_rows_ref(bank):
    """Symmetric per-row int8 quantization (the contract the quantized
    kernels score against): scale = max|row| / 127, q = round(row / scale)
    clipped to [-127, 127]; an all-zero row gets scale 0 and zero codes.
    Returns (codes int8 (N, D), scales f32 (N,)).  Shared by the
    VectorIndex quantizer and the oracle tests — per-element dequant error
    is bounded by scale/2."""
    bank = jnp.asarray(bank, jnp.float32)
    amax = jnp.max(jnp.abs(bank), axis=1)
    scale = amax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    codes = jnp.clip(jnp.round(bank * inv[:, None]), -127, 127)
    return codes.astype(jnp.int8), scale


def _quant_scores(queries, bank_i8, scales):
    """(Q, N) f32 scores in the fused kernel's exact operation order:
    contract the int8 codes in f32, THEN multiply by the row scale —
    `(q · row_i8) * scale`, not `q · (scale * row_i8)` — so oracle and
    kernel agree to the same rounding and index comparisons stay exact."""
    s = jnp.einsum("qd,nd->qn", jnp.asarray(queries, jnp.float32),
                   jnp.asarray(bank_i8).astype(jnp.float32))
    return s * jnp.asarray(scales, jnp.float32)[None, :]


def topk_mips_quant_ref(queries, bank_i8, scales, k: int = 32, n_valid=None):
    """Quantized-MIPS oracle: top-k over the fused dequant scores."""
    s = _quant_scores(queries, bank_i8, scales)
    if n_valid is not None:
        col = jnp.arange(bank_i8.shape[0], dtype=jnp.int32)[None, :]
        s = jnp.where(col < n_valid, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    if n_valid is not None:
        idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def topk_mips_quant_masked_ref(queries, bank_i8, scales, q_ns, bank_ns,
                               k: int = 32, n_valid=None):
    """Namespace-masked quantized-MIPS oracle (see topk_mips_quant_ref)."""
    s = _quant_scores(queries, bank_i8, scales)
    ok = jnp.asarray(q_ns, jnp.int32)[:, None] == \
        jnp.asarray(bank_ns, jnp.int32)[None, :]
    if n_valid is not None:
        col = jnp.arange(bank_i8.shape[0], dtype=jnp.int32)[None, :]
        ok = ok & (col < n_valid)
    s = jnp.where(ok, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def topk_mips_masked_ref(queries, bank, q_ns, bank_ns, k: int = 32,
                         n_valid=None):
    """Namespace-masked MIPS oracle: cross-namespace scores become NEG_INF
    and their indices -1 (matching the kernel, whose running top-k never
    admits a masked column).  q_ns (Q,) i32 >= 0; bank_ns (N,) i32 with -1
    marking tombstoned rows.  `n_valid` bounds the live bank prefix of a
    capacity-padded bank, as in topk_mips_ref."""
    s = jnp.einsum("qd,nd->qn", queries.astype(jnp.float32),
                   bank.astype(jnp.float32))
    ok = jnp.asarray(q_ns, jnp.int32)[:, None] == \
        jnp.asarray(bank_ns, jnp.int32)[None, :]
    if n_valid is not None:
        col = jnp.arange(bank.shape[0], dtype=jnp.int32)[None, :]
        ok = ok & (col < n_valid)
    s = jnp.where(ok, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None):
    """q: (B,K,G,S,D); k,v: (B,K,T,D) -> (B,K,G,S,D)."""
    B, K, G, S, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bkgsd,bktd->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window > 0:
        ok = ok & (k_pos > q_pos - window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len, *, scale=None, window: int = 0):
    """q: (B,K,G,D); k,v: (B,K,T,D); kv_len (B,) -> (B,K,G,D)."""
    B, K, G, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(T)[None, None, None, :]
    kl = kv_len[:, None, None, None]
    ok = pos < kl
    if window > 0:
        ok = ok & (pos > kl - 1 - window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def graph_expand_ref(edge_src, edge_dst, edge_type, edge_w, node_ns,
                     row_sub, row_obj, row_labels, rankings, q_ns, type_w,
                     hops_b, *, hops: int, k: int, seed_k: int,
                     decay: float):
    """Scalar BFS oracle for core/graph._expand_device — the parity
    contract for the batched k-hop expansion.  Per-request max-product
    relaxation over the edge list with the SAME float32 operation order as
    the device kernel:

        we = type_w[b, etype] * edge_w          # f32 * f32
        c  = F[src] * we
        c  = c * decay
        c  = c / out_degree(src)

    combined by max, so accumulation order cannot matter and scores match
    the device scatter-max bit-exactly.  Inputs are the HOST mirrors (tight
    or padded — only the first n entries of each lane are read, as passed);
    `rankings` a sequence of (B, P_i) int arrays (-1-padded best-first),
    `row_labels` (n_rows_total,) effective labels (-1 = dead), `type_w`
    (B, 3) f32, `hops_b` (B,) per-request hop counts.  Returns (ids (B, k)
    i32 -1-padded, scores (B, k) f32) ordered by (-score, row id)."""
    import numpy as np
    edge_src = np.asarray(edge_src, np.int32)
    edge_dst = np.asarray(edge_dst, np.int32)
    edge_type = np.asarray(edge_type, np.int32)
    edge_w = np.asarray(edge_w, np.float32)
    node_ns = np.asarray(node_ns, np.int32)
    row_sub = np.asarray(row_sub, np.int32)
    row_obj = np.asarray(row_obj, np.int32)
    row_labels = np.asarray(row_labels, np.int32)
    q_ns = np.asarray(q_ns, np.int32)
    type_w = np.asarray(type_w, np.float32)
    hops_b = np.asarray(hops_b, np.int32)
    decay32 = np.float32(decay)
    B = q_ns.shape[0]
    n_nodes = node_ns.shape[0]
    n_rows = row_sub.shape[0]
    deg = np.bincount(edge_src, minlength=max(1, n_nodes)).astype(np.int64)
    out_ids = np.full((B, k), -1, np.int32)
    out_scores = np.zeros((B, k), np.float32)
    for b in range(B):
        ns = int(q_ns[b])
        seeds = {}                                # node -> f32 activation
        for r in rankings:
            for row in np.asarray(r[b][:seed_k], np.int64):
                row = int(row)
                if row < 0 or row >= n_rows or row >= row_labels.shape[0]:
                    continue
                if int(row_labels[row]) != ns:
                    continue
                for node in (int(row_sub[row]), int(row_obj[row])):
                    if node >= 0 and int(node_ns[node]) == ns:
                        seeds[node] = np.float32(1.0)
        frontier = dict(seeds)
        # seed nodes never score rows — neither their hop-0 activation nor
        # any hop>=1 re-activation (the device kernel masks them the same
        # way) — `act` holds newly discovered nodes only
        act = {}
        for h in range(int(min(hops_b[b], hops))):
            nxt = {}
            for e in range(edge_src.shape[0]):
                s, d = int(edge_src[e]), int(edge_dst[e])
                f = frontier.get(s)
                if f is None or int(node_ns[d]) != ns:
                    continue
                we = type_w[b, int(edge_type[e])] * edge_w[e]
                c = f * we
                c = c * decay32
                c = c / np.float32(max(int(deg[s]), 1))
                if c > nxt.get(d, np.float32(0.0)):
                    nxt[d] = c
            for node, sc in nxt.items():
                if sc > act.get(node, np.float32(0.0)):
                    act[node] = sc
            frontier = nxt
            if not frontier:
                break
        for node in seeds:
            act.pop(node, None)
        scored = []
        for row in range(n_rows):
            if row >= row_labels.shape[0] or int(row_labels[row]) != ns:
                continue
            sc = np.float32(0.0)
            for node in (int(row_sub[row]), int(row_obj[row])):
                if node >= 0:
                    sc = max(sc, act.get(node, np.float32(0.0)))
            if sc > 0:
                scored.append((-sc, row))
        scored.sort()
        for i, (negsc, row) in enumerate(scored[:k]):
            out_ids[b, i] = row
            out_scores[b, i] = -negsc
    return out_ids, out_scores
