"""Extractors: raw dialogue -> semantic triples + session summary.

Two interchangeable backends behind one protocol (DESIGN.md §3):

* RuleExtractor — deterministic pattern extraction.  Used by tests and the
  synthetic LoCoMo-like benchmark so that evaluation isolates *memory
  structuring and retrieval quality* (the paper: "accuracy ... serves as a
  direct reflection of how well the Advanced Augmentation pipeline
  structured, preserved, and surfaced the relevant facts").
* LMExtractor — prompts any model served by this framework (the paper uses
  GPT-4.1-mini); parses "(subject; predicate; object)" lines.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, List, Protocol, Sequence, Tuple

from repro.core.summaries import Summary
from repro.core.triples import Triple


@dataclasses.dataclass(frozen=True)
class Message:
    speaker: str
    text: str
    timestamp: float = 0.0


class Extractor(Protocol):
    def extract(self, conversation_id: str, session_id: str,
                messages: Sequence[Message]) -> Tuple[List[Triple], Summary]:
        ...


# ---------------------------------------------------------------------------
# Rule-based extraction
# ---------------------------------------------------------------------------

# (regex, subject_fn, predicate, object_group) — subject is the speaker
# unless the pattern binds its own.  Patterns are ordered; first match per
# clause wins.
_P = [
    (re.compile(r"\bmy favorite (\w+(?: \w+)?) is (?:the |a |an )?([\w' -]+)", re.I),
     "favorite {1}", 2),
    (re.compile(r"\bi (?:really )?(?:love|adore) ([\w' -]+?)(?:[.,!]|$| and )", re.I),
     "loves", 1),
    (re.compile(r"\bi (?:really )?(?:like|enjoy) ([\w' -]+?)(?:[.,!]|$| and )", re.I),
     "likes", 1),
    (re.compile(r"\bi prefer ([\w' -]+?)(?: over [\w' -]+)?(?:[.,!]|$| and )", re.I),
     "prefers", 1),
    (re.compile(r"\bi (?:work|works) as (?:a |an )?([\w' -]+?)(?:[.,!]|$| and )", re.I),
     "works as", 1),
    (re.compile(r"\bi(?: now)? live in ([\w' -]+?)(?:[.,!]|$| and )", re.I),
     "lives in", 1),
    (re.compile(r"\bi moved to ([\w' -]+?)(?: last [\w]+| in [\w ]+)?(?:[.,!]|$| and )", re.I),
     "lives in", 1),
    (re.compile(r"\bi adopted (?:a |an )?([\w' -]+?)(?: named ([\w' -]+))?(?:[.,!]|$| and )", re.I),
     "adopted", 1),
    (re.compile(r"\bi bought (?:a |an |some )?([\w' -]+?)(?: last [\w]+| yesterday| in [\w ]+)?(?:[.,!]|$| and )", re.I),
     "bought", 1),
    (re.compile(r"\bi (?:went|travell?ed) to ([\w' -]+?)(?: last [\w]+| in [\w ]+| yesterday)?(?:[.,!]|$| and )", re.I),
     "visited", 1),
    (re.compile(r"\bi(?:'m| am) (?:learning|studying) ([\w' -]+?)(?:[.,!]|$| and )", re.I),
     "is learning", 1),
    (re.compile(r"\bi started (?:learning |studying )?([\w' -]+?)(?: classes| lessons)?(?: last [\w]+| in [\w ]+)?(?:[.,!]|$| and )", re.I),
     "started", 1),
    (re.compile(r"\bi(?:'m| am) allergic to ([\w' -]+?)(?:[.,!]|$| and )", re.I),
     "is allergic to", 1),
    (re.compile(r"\bi(?:'m| am) (?:a |an )([\w' -]+?) by trade(?:[.,!]|$| and )", re.I),
     "works as", 1),
    (re.compile(r"\bmy ([\w]+)(?:'s name)? is (?:called )?([\w' -]+?)(?:[.,!]|$| and )", re.I),
     "{1} is", 2),
]

_USED_TO = re.compile(
    r"\bi used to (?:work as|be) (?:a |an )?([\w' -]+?),? but (?:now i(?:'m| am)|i became) (?:a |an )?([\w' -]+?)(?:[.,!]|$| and )",
    re.I)

# third-person allergy: "Muffin is allergic to peanuts" — the one pattern
# whose subject is the named entity, not the speaker (case-sensitive on the
# capitalized name so "he is allergic to ..." stays a non-match)
_THIRD_ALLERGIC = re.compile(
    r"\b([A-Z][\w'-]+) is allergic to ([\w' -]+?)(?:[.,!]|$| and )")

_NOISE_WORDS = {"it", "that", "this", "them", "those", "there"}


def _clean(s: str) -> str:
    return re.sub(r"\s+", " ", s).strip(" .,!?'").lower()


class RuleExtractor:
    """Deterministic cognitive filter: scans each message for concrete facts,
    preferences, constraints and evolving attributes (paper §2.1)."""

    def extract(self, conversation_id: str, session_id: str,
                messages: Sequence[Message]) -> Tuple[List[Triple], Summary]:
        triples: List[Triple] = []
        seen = set()
        last_ts = 0.0
        for msg in messages:
            last_ts = max(last_ts, msg.timestamp)
            for clause in re.split(r"(?<=[.!?])\s+", msg.text):
                m = _USED_TO.search(clause)
                if m:
                    for obj, pred in ((m.group(1), "used to work as"),
                                      (m.group(2), "works as")):
                        o = _clean(obj)
                        key = (msg.speaker, pred, o)
                        if o and o not in _NOISE_WORDS and key not in seen:
                            seen.add(key)
                            triples.append(Triple(
                                subject=msg.speaker, predicate=pred, object=o,
                                conversation_id=conversation_id,
                                session_id=session_id, timestamp=msg.timestamp,
                                source_text=clause.strip()))
                    continue
                m = _THIRD_ALLERGIC.search(clause)
                if m and m.group(1).lower() != "i":
                    subj = m.group(1)
                    obj = _clean(m.group(2))
                    key = (subj.lower(), "is allergic to", obj)
                    if obj and obj not in _NOISE_WORDS and key not in seen:
                        seen.add(key)
                        triples.append(Triple(
                            subject=subj, predicate="is allergic to",
                            object=obj, conversation_id=conversation_id,
                            session_id=session_id, timestamp=msg.timestamp,
                            source_text=clause.strip()))
                    continue
                for rx, pred_tpl, obj_g in _P:
                    m = rx.search(clause)
                    if not m:
                        continue
                    pred = pred_tpl.format(*([None] + [
                        _clean(g or "") for g in m.groups()]))
                    obj = _clean(m.group(obj_g) or "")
                    if not obj or obj in _NOISE_WORDS:
                        continue
                    key = (msg.speaker, pred, obj)
                    if key in seen:
                        continue
                    seen.add(key)
                    triples.append(Triple(
                        subject=msg.speaker, predicate=pred, object=obj,
                        conversation_id=conversation_id,
                        session_id=session_id, timestamp=msg.timestamp,
                        source_text=clause.strip()))
                    # secondary fact: "adopted a <pet> named <name>"
                    if pred == "adopted" and m.lastindex and m.lastindex >= 2 \
                            and m.group(2):
                        name = _clean(m.group(2))
                        if name and (obj, "is named", name) not in seen:
                            seen.add((obj, "is named", name))
                            triples.append(Triple(
                                subject=obj, predicate="is named", object=name,
                                conversation_id=conversation_id,
                                session_id=session_id, timestamp=msg.timestamp,
                                source_text=clause.strip()))
        summary = self._summarize(conversation_id, session_id, messages,
                                  triples, last_ts)
        return triples, summary

    @staticmethod
    def _summarize(conversation_id, session_id, messages, triples, ts) -> Summary:
        speakers = sorted({m.speaker for m in messages})
        topics = []
        for t in triples:
            frag = f"{t.subject} {t.predicate} {t.object}"
            if frag not in topics:
                topics.append(frag)
        head = " and ".join(speakers) if speakers else "the participants"
        body = "; ".join(topics[:12]) if topics else "small talk"
        text = (f"{head} caught up over {len(messages)} messages. "
                f"Key developments: {body}.")
        return Summary(conversation_id=conversation_id, session_id=session_id,
                       timestamp=ts, text=text)


# ---------------------------------------------------------------------------
# LM-backed extraction
# ---------------------------------------------------------------------------

EXTRACTION_PROMPT = """You are a memory extraction engine. Read the conversation
below and output one line per atomic fact in the exact form
(subject; predicate; object). Then output one line starting with
SUMMARY: followed by a 2-3 sentence summary of the conversation.

{conversation}

FACTS:
"""

_TRIPLE_LINE = re.compile(r"\(([^;()]+);([^;()]+);([^;()]+)\)")


class LMExtractor:
    """Uses a served LM (a `generate(prompt) -> str` callable from
    repro.serving) as the extraction model."""

    def __init__(self, generate_fn: Callable[[str], str]):
        self.generate = generate_fn

    def extract(self, conversation_id: str, session_id: str,
                messages: Sequence[Message]) -> Tuple[List[Triple], Summary]:
        convo = "\n".join(f"{m.speaker}: {m.text}" for m in messages)
        out = self.generate(EXTRACTION_PROMPT.format(conversation=convo))
        last_ts = max((m.timestamp for m in messages), default=0.0)
        triples = []
        summary_text = ""
        for line in out.splitlines():
            if line.strip().upper().startswith("SUMMARY:"):
                summary_text = line.split(":", 1)[1].strip()
                continue
            m = _TRIPLE_LINE.search(line)
            if m:
                triples.append(Triple(
                    subject=_clean(m.group(1)), predicate=_clean(m.group(2)),
                    object=_clean(m.group(3)),
                    conversation_id=conversation_id, session_id=session_id,
                    timestamp=last_ts, source_text=line.strip()))
        summary = Summary(conversation_id=conversation_id,
                          session_id=session_id, timestamp=last_ts,
                          text=summary_text or "(no summary produced)")
        return triples, summary
