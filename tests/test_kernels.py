"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp ref.py oracles
(interpret mode on CPU — the kernel bodies execute exactly as written)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def k(i):
    return jax.random.fold_in(KEY, i)


# ---------------------------------------------------------------------------
# topk_mips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q_n,bank_n,dim,kk", [
    (1, 16, 8, 4),
    (7, 100, 32, 8),
    (33, 1000, 64, 16),
    (128, 513, 128, 32),     # non-divisible bank vs block
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_topk_mips_matches_oracle(q_n, bank_n, dim, kk, dtype):
    q = jax.random.normal(k(1), (q_n, dim)).astype(dtype)
    bank = jax.random.normal(k(2), (bank_n, dim)).astype(dtype)
    s, i = ops.topk_mips(q, bank, k=kk, block_q=32, block_n=64)
    sr, ir = ref.topk_mips_ref(q, bank, k=kk)
    assert i.shape == (q_n, kk) and s.shape == (q_n, kk)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("q_n,bank_n,dim,kk,n_ns", [
    (1, 16, 8, 4, 1),
    (7, 100, 32, 8, 3),
    (33, 513, 64, 16, 5),     # non-divisible bank vs block
    (9, 300, 16, 8, 40),      # multi-block bank, every ns owns < kk rows
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_topk_mips_masked_matches_oracle(q_n, bank_n, dim, kk, n_ns, dtype):
    q = jax.random.normal(k(21), (q_n, dim)).astype(dtype)
    bank = jax.random.normal(k(22), (bank_n, dim)).astype(dtype)
    q_ns = jnp.asarray(np.arange(q_n) % n_ns, jnp.int32)
    bank_ns = np.arange(bank_n) % n_ns
    bank_ns[::7] = -1                       # sprinkle tombstones
    bank_ns = jnp.asarray(bank_ns, jnp.int32)
    s, i = ops.topk_mips_masked(q, bank, q_ns, bank_ns, k=kk,
                                block_q=32, block_n=64)
    sr, ir = ref.topk_mips_masked_ref(q, bank, q_ns, bank_ns, k=kk)
    assert i.shape == (q_n, kk) and s.shape == (q_n, kk)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-3, atol=1e-3)
    # every returned hit stays inside its query's namespace
    bn = np.asarray(bank_ns)
    for r in range(q_n):
        for idx in np.asarray(i)[r]:
            if idx >= 0:
                assert bn[idx] == int(q_ns[r])


def test_topk_mips_masked_uniform_ns_equals_unmasked():
    """With every row in one namespace the mask is a no-op: the masked
    kernel must reproduce the unmasked kernel exactly."""
    q = jax.random.normal(k(23), (9, 16))
    bank = jax.random.normal(k(24), (77, 16))
    s0, i0 = ops.topk_mips(q, bank, k=8, block_q=8, block_n=16)
    s1, i1 = ops.topk_mips_masked(q, bank, jnp.zeros((9,), jnp.int32),
                                  jnp.zeros((77,), jnp.int32), k=8,
                                  block_q=8, block_n=16)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_topk_mips_masked_small_tenant_multiblock_emits_sentinels(dtype):
    """Regression: a tenant owning 0 < rows < k in a bank spanning several
    bank blocks must pad with -1 sentinels.  The old merge argmax'd over an
    all-NEG_INF row once in-namespace candidates ran out, re-emitting the
    index parked in running slot 0 at grid steps nb > 0 — ghost duplicates
    that pass downstream `i >= 0` filters and inflate RRF scores."""
    bank_n, kk = 1100, 8
    q = jax.random.normal(k(27), (4, 8)).astype(dtype)
    bank = jax.random.normal(k(28), (bank_n, 8)).astype(dtype)
    bank_ns = np.zeros((bank_n,), np.int32)
    bank_ns[[0, 40, 700]] = 1             # tenant 1 owns 3 of 1100 rows
    bank_ns = jnp.asarray(bank_ns)
    q_ns = jnp.asarray([1, 0, 1, 0], jnp.int32)
    # default block_n=512: three sequential bank blocks
    s, i = ops.topk_mips_masked(q, bank, q_ns, bank_ns, k=kk)
    sr, ir = ref.topk_mips_masked_ref(q, bank, q_ns, bank_ns, k=kk)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-3, atol=1e-3)
    i = np.asarray(i)
    for r in (0, 2):                      # tenant-1 queries: 3 hits then -1
        assert sorted(i[r][:3].tolist()) == [0, 40, 700]
        assert (i[r][3:] == -1).all()


def test_topk_mips_masked_empty_namespace_returns_sentinels():
    q = jax.random.normal(k(25), (2, 8))
    bank = jax.random.normal(k(26), (20, 8))
    q_ns = jnp.asarray([9, 0], jnp.int32)    # ns 9 owns no rows
    bank_ns = jnp.zeros((20,), jnp.int32)
    s, i = ops.topk_mips_masked(q, bank, q_ns, bank_ns, k=4,
                                block_q=8, block_n=8)
    assert (np.asarray(i)[0] == -1).all()
    assert (np.asarray(i)[1] >= 0).all()


@pytest.mark.parametrize("masked", [False, True])
def test_topk_mips_traced_n_valid_matches_truncated_oracle(masked):
    """Stable-shape contract: a capacity-padded bank + traced n_valid must
    answer exactly like the oracle on the truncated bank — for several
    n_valid values through ONE jitted executable (shapes never change)."""
    D, N_pad, kk = 16, 96, 6
    q = jax.random.normal(k(31), (5, D))
    bank = jax.random.normal(k(32), (N_pad, D))
    q_ns = jnp.asarray([0, 1, 2, 0, 1], jnp.int32)
    bank_ns = jnp.asarray(np.arange(N_pad) % 3, jnp.int32)
    for n_valid in (3, 17, 50, 96):
        if masked:
            s, i = ops.topk_mips_masked(q, bank, q_ns, bank_ns, k=kk,
                                        n_valid=n_valid,
                                        block_q=8, block_n=32)
            sr, ir = ref.topk_mips_masked_ref(q, bank[:n_valid], q_ns,
                                              bank_ns[:n_valid], k=kk) \
                if n_valid >= kk else ref.topk_mips_masked_ref(
                    q, bank, q_ns, bank_ns, k=kk, n_valid=n_valid)
        else:
            s, i = ops.topk_mips(q, bank, k=kk, n_valid=n_valid,
                                 block_q=8, block_n=32)
            sr, ir = ref.topk_mips_ref(q, bank, k=kk, n_valid=n_valid)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
        mask = np.asarray(ir) >= 0
        np.testing.assert_allclose(np.asarray(s)[mask], np.asarray(sr)[mask],
                                   rtol=1e-5)
        # returned hits always come from the live prefix
        ii = np.asarray(i)
        assert ((ii < n_valid) | (ii == -1)).all()


def test_topk_mips_n_valid_zero_returns_all_sentinels():
    q = jax.random.normal(k(33), (2, 8))
    bank = jax.random.normal(k(34), (32, 8))
    s, i = ops.topk_mips(q, bank, k=4, n_valid=0, block_q=8, block_n=8)
    assert (np.asarray(i) == -1).all()


def test_topk_scores_sorted_and_indices_valid():
    q = jax.random.normal(k(3), (9, 16))
    bank = jax.random.normal(k(4), (77, 16))
    s, i = ops.topk_mips(q, bank, k=8, block_q=8, block_n=16)
    s = np.asarray(s)
    assert (np.diff(s, axis=1) <= 1e-6).all(), "scores must be descending"
    assert ((np.asarray(i) >= 0) & (np.asarray(i) < 77)).all()


# ---------------------------------------------------------------------------
# topk_mips — quantized (int8 bank + per-row scales, fused dequant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q_n,bank_n,dim,kk", [
    (1, 16, 8, 4),
    (7, 100, 32, 8),
    (33, 513, 64, 16),       # non-divisible bank vs block
])
def test_topk_mips_quant_matches_oracle(q_n, bank_n, dim, kk):
    q = jax.random.normal(k(41), (q_n, dim))
    bank = jax.random.normal(k(42), (bank_n, dim))
    codes, scales = ref.quantize_rows_ref(bank)
    s, i = ops.topk_mips_quant(q, codes, scales, k=kk,
                               block_q=32, block_n=64)
    sr, ir = ref.topk_mips_quant_ref(q, codes, scales, k=kk)
    assert i.shape == (q_n, kk) and s.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q_n,bank_n,dim,kk,n_ns", [
    (7, 100, 32, 8, 3),
    (9, 300, 16, 8, 40),     # multi-block bank, every ns owns < kk rows
])
def test_topk_mips_quant_masked_matches_oracle(q_n, bank_n, dim, kk, n_ns):
    q = jax.random.normal(k(43), (q_n, dim))
    bank = jax.random.normal(k(44), (bank_n, dim))
    codes, scales = ref.quantize_rows_ref(bank)
    q_ns = jnp.asarray(np.arange(q_n) % n_ns, jnp.int32)
    bank_ns = np.arange(bank_n) % n_ns
    bank_ns[::7] = -1                       # sprinkle tombstones
    bank_ns = jnp.asarray(bank_ns, jnp.int32)
    s, i = ops.topk_mips_quant_masked(q, codes, scales, q_ns, bank_ns,
                                      k=kk, block_q=32, block_n=64)
    sr, ir = ref.topk_mips_quant_masked_ref(q, codes, scales, q_ns,
                                            bank_ns, k=kk)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)
    bn = np.asarray(bank_ns)
    for r in range(q_n):
        for idx in np.asarray(i)[r]:
            if idx >= 0:
                assert bn[idx] == int(q_ns[r])


def test_topk_mips_quant_approximates_f32_search():
    """The fused dequant scan must track the f32 oracle: exact-match
    recall@k stays high and every dequantized score lands within the
    per-row quantization error bound of its true score."""
    D, N, kk = 32, 400, 10
    q = jax.random.normal(k(45), (6, D))
    bank = jax.random.normal(k(46), (N, D))
    codes, scales = ref.quantize_rows_ref(bank)
    _, i_f = ref.topk_mips_ref(q, bank, k=kk)
    s_q, i_q = ops.topk_mips_quant(q, codes, scales, k=kk,
                                   block_q=8, block_n=64)
    i_f, i_q, s_q = np.asarray(i_f), np.asarray(i_q), np.asarray(s_q)
    recall = np.mean([len(set(i_f[r]) & set(i_q[r])) / kk
                      for r in range(6)])
    assert recall >= 0.9, recall
    # |q·(scale*codes) - q·row| <= |q|_1 * scale/2 per row
    qn = np.abs(np.asarray(q)).sum(axis=1)
    sc = np.asarray(scales)
    true = np.asarray(q) @ np.asarray(bank).T
    for r in range(6):
        for j in range(kk):
            idx = i_q[r, j]
            bound = qn[r] * sc[idx] / 2 + 1e-4
            assert abs(s_q[r, j] - true[r, idx]) <= bound


def test_topk_mips_quant_traced_n_valid():
    """Quantized search keeps the stable-shape contract: several n_valid
    values through one executable, padded rows never surface."""
    D, N_pad, kk = 16, 96, 6
    q = jax.random.normal(k(47), (5, D))
    bank = jax.random.normal(k(48), (N_pad, D))
    codes, scales = ref.quantize_rows_ref(bank)
    for n_valid in (3, 17, 50, 96):
        s, i = ops.topk_mips_quant(q, codes, scales, k=kk, n_valid=n_valid,
                                   block_q=8, block_n=32)
        sr, ir = ref.topk_mips_quant_ref(q, codes, scales, k=kk,
                                         n_valid=n_valid)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
        ii = np.asarray(i)
        assert ((ii < n_valid) | (ii == -1)).all()


def test_topk_mips_quant_rejects_f32_bank():
    q = jax.random.normal(k(49), (2, 8))
    bank = jax.random.normal(k(50), (16, 8))
    scales = jnp.ones((16,), jnp.float32)
    with pytest.raises(TypeError, match="int8"):
        ops.topk_mips_quant(q, bank, scales, k=4)


@pytest.mark.parametrize("variant", ["plain", "masked", "quant",
                                     "quant_masked"])
def test_topk_mips_empty_bank_n_valid_zero_all_sentinels(variant):
    """n_valid=0 (an index before its first append, or fully demoted):
    every variant must return all -1 indices, never garbage rows."""
    D, N, kk = 8, 32, 4
    q = jax.random.normal(k(51), (3, D))
    bank = jax.random.normal(k(52), (N, D))
    codes, scales = ref.quantize_rows_ref(bank)
    q_ns = jnp.zeros((3,), jnp.int32)
    bank_ns = jnp.zeros((N,), jnp.int32)
    if variant == "plain":
        s, i = ops.topk_mips(q, bank, k=kk, n_valid=0, block_q=8, block_n=8)
    elif variant == "masked":
        s, i = ops.topk_mips_masked(q, bank, q_ns, bank_ns, k=kk, n_valid=0,
                                    block_q=8, block_n=8)
    elif variant == "quant":
        s, i = ops.topk_mips_quant(q, codes, scales, k=kk, n_valid=0,
                                   block_q=8, block_n=8)
    else:
        s, i = ops.topk_mips_quant_masked(q, codes, scales, q_ns, bank_ns,
                                          k=kk, n_valid=0,
                                          block_q=8, block_n=8)
    assert (np.asarray(i) == -1).all()


def test_quantize_rows_ref_roundtrip_error_bound():
    """Per-element dequant error is bounded by scale/2; zero rows get
    scale 0 and reconstruct exactly."""
    rng = np.random.default_rng(0)
    bank = rng.standard_normal((64, 32)).astype(np.float32)
    bank[5] = 0.0
    bank[9] *= 1e-6                         # tiny-norm row
    bank[11] *= 1e4                         # huge-norm row
    codes, scales = ref.quantize_rows_ref(bank)
    codes, scales = np.asarray(codes), np.asarray(scales)
    assert codes.dtype == np.int8
    assert (np.abs(codes) <= 127).all()
    recon = codes.astype(np.float32) * scales[:, None]
    err = np.abs(recon - bank)
    assert (err <= scales[:, None] / 2 + 1e-7).all()
    assert scales[5] == 0.0 and (codes[5] == 0).all()
    assert (recon[5] == 0).all()


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,K,G,S,D,bq,bk", [
    (1, 1, 1, 32, 16, 8, 8),
    (2, 2, 4, 64, 32, 16, 32),
    (1, 3, 2, 70, 32, 32, 16),    # ragged vs blocks
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(B, K, G, S, D, bq, bk, dtype, causal):
    q = jax.random.normal(k(5), (B, K, G, S, D)).astype(dtype)
    kk = jax.random.normal(k(6), (B, K, S, D)).astype(dtype)
    vv = jax.random.normal(k(7), (B, K, S, D)).astype(dtype)
    out = ops.flash_attention(q, kk, vv, causal=causal, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, kk, vv, causal=causal)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_sliding_window():
    B, K, G, S, D = 1, 2, 2, 96, 16
    q = jax.random.normal(k(8), (B, K, G, S, D))
    kk = jax.random.normal(k(9), (B, K, S, D))
    vv = jax.random.normal(k(10), (B, K, S, D))
    out = ops.flash_attention(q, kk, vv, causal=True, window=16,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, kk, vv, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,K,G,T,D,bt", [
    (1, 1, 1, 64, 16, 16),
    (3, 2, 4, 200, 32, 64),
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_decode_attention_matches_oracle(B, K, G, T, D, bt, dtype):
    q = jax.random.normal(k(11), (B, K, G, D)).astype(dtype)
    kk = jax.random.normal(k(12), (B, K, T, D)).astype(dtype)
    vv = jax.random.normal(k(13), (B, K, T, D)).astype(dtype)
    kv_len = jnp.asarray([T - 3 - 7 * b for b in range(B)], jnp.int32)
    out = ops.decode_attention(q, kk, vv, kv_len, block_t=bt)
    want = ref.decode_attention_ref(q, kk, vv, kv_len)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_decode_attention_ragged_lengths_ignore_tail():
    """Cache contents past kv_len must not affect the output."""
    B, K, G, T, D = 2, 1, 2, 128, 16
    q = jax.random.normal(k(14), (B, K, G, D))
    kk = jax.random.normal(k(15), (B, K, T, D))
    vv = jax.random.normal(k(16), (B, K, T, D))
    kv_len = jnp.asarray([40, 90], jnp.int32)
    out1 = ops.decode_attention(q, kk, vv, kv_len, block_t=32)
    kk2 = kk.at[:, :, 100:].set(999.0)
    vv2 = vv.at[:, :, 100:].set(-999.0)
    out2 = ops.decode_attention(q, kk2, vv2, kv_len, block_t=32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)
