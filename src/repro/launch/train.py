"""Production training launcher: pjit'd train step on a real mesh.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --shape train_4k [--multipod] [--steps 50] [--host-demo]

On TPU hardware this runs the full sharded step; `--host-demo` runs a reduced
config on a small host-device mesh (CI-checkable on this CPU container).
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--host-demo", action="store_true")
    args = ap.parse_args()

    if args.host_demo:
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                                   + os.environ.get("XLA_FLAGS", ""))

    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch import mesh as mesh_lib
    from repro.launch.sharding import build_train_step
    from repro.models.config import INPUT_SHAPES
    from repro.common.module import materialize
    from repro.models.model_api import Model
    from repro.training import optimizer as opt

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    if args.host_demo:
        cfg = cfg.reduced()
        shape = dataclasses.replace(shape, global_batch=4, seq_len=64)
        mesh = mesh_lib.make_host_mesh(2, 2)
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multipod)

    model = Model(cfg)
    with mesh:
        bundle = build_train_step(cfg, shape, mesh)
        rules = bundle.rules
        params = jax.jit(
            lambda k: materialize(k, model.param_specs(), cfg.pdtype),
            out_shardings=model.param_shardings(rules),
        )(jax.random.PRNGKey(0))
        ocfg = opt.OptimizerConfig(
            state_dtype=bundle.meta["opt_dtype"], total_steps=args.steps)
        opt_state = opt.init(ocfg, params)

        key = jax.random.PRNGKey(1)
        for step in range(args.steps):
            key = jax.random.fold_in(key, step)
            B = shape.global_batch
            S = shape.seq_len - (cfg.num_image_tokens or 0)
            batch = {"tokens": jax.random.randint(key, (B, S), 4,
                                                  cfg.vocab_size)}
            if cfg.num_image_tokens:
                batch["images"] = jax.random.normal(
                    key, (B, cfg.num_image_tokens, 1152))
            if cfg.is_encoder_decoder:
                batch["audio"] = jax.random.normal(
                    key, (B, cfg.encoder_seq_len, cfg.d_model))
            params, opt_state, metrics = bundle.fn(params, opt_state, batch)
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
    print("done")


if __name__ == "__main__":
    main()
