"""whisper-small [audio] — 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  Enc-dec; the mel/conv frontend is a STUB per the assignment —
input_specs provides (B, 1500, 768) frame embeddings.  Decoder positions are
adapted to sinusoidal so decode_32k lowers (DESIGN.md §3); long_500k is
skipped (full-attention enc-dec, DESIGN.md §9).  [arXiv:2212.04356]"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        arch_type="audio",
        num_layers=12,                 # decoder layers
        encoder_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        source="[arXiv:2212.04356]",
        is_encoder_decoder=True,
        encoder_seq_len=1500,
        norm="layernorm",
        act="gelu",
        mlp_gated=False,
        qkv_bias=True,
        rope_pct=0.0,                  # sinusoidal absolute positions
        supports_long_context=False,   # long_500k skipped (DESIGN.md §9)
        long_context_window=0,
    )
