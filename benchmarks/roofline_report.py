"""§Roofline report: reads the dry-run artifacts (artifacts/dryrun/*.json)
and prints the per-(arch × shape) three-term roofline table for the
single-pod mesh, plus the multi-pod lowering status."""
from __future__ import annotations

import glob
import json
import os
import time


def load(out_dir="artifacts/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run(csv_rows, out_dir="artifacts/dryrun"):
    t0 = time.time()
    recs = load(out_dir)
    variants = [r for r in recs
                if r.get("variant", "baseline") != "baseline"]
    recs = [r for r in recs if r.get("variant", "baseline") == "baseline"]
    single = [r for r in recs if r.get("mesh") == "16x16"]
    multi = [r for r in recs if r.get("mesh") == "2x16x16"]
    print("\n# Roofline — single-pod (16x16 = 256 chips, TPU v5e terms)")
    print(f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'dominant':>12s} {'useful':>7s}")
    for r in single:
        if r["status"] != "ok" or "roofline" not in r:
            tag = r.get("skip_reason", r.get("error", ""))[:40]
            print(f"{r['arch']:22s} {r['shape']:12s} [{r['status']}] {tag}")
            continue
        rf = r["roofline"]
        print(f"{r['arch']:22s} {r['shape']:12s} {rf['compute_s']:10.4f} "
              f"{rf['memory_s']:10.4f} {rf['collective_s']:10.4f} "
              f"{rf['dominant']:>12s} {r['useful_flops_ratio']:7.3f}")
        csv_rows.append((f"roofline/{r['arch']}/{r['shape']}",
                         rf["bound_s"] * 1e6, rf["dominant"]))
    ok_m = sum(1 for r in multi if r["status"] == "ok")
    sk_m = sum(1 for r in multi if r["status"] == "skipped")
    print(f"\nmulti-pod 2x16x16: {ok_m} lowered+compiled, {sk_m} skipped, "
          f"{len(multi) - ok_m - sk_m} errors of {len(multi)}")

    if variants:
        print("\n# §Perf variants (hillclimb — see EXPERIMENTS.md §Perf)")
        for r in variants:
            if r["status"] != "ok" or "roofline" not in r:
                continue
            rf = r["roofline"]
            print(f"{r['arch']:22s} {r['shape']:12s} {r['variant']:22s} "
                  f"c={rf['compute_s']:9.4f} m={rf['memory_s']:9.4f} "
                  f"x={rf['collective_s']:9.4f} bound={rf['bound_s']:9.4f} "
                  f"({rf['dominant']})")
            csv_rows.append((f"perf/{r['arch']}/{r['shape']}/{r['variant']}",
                             rf["bound_s"] * 1e6, rf["dominant"]))
    csv_rows.append(("roofline/report", (time.time() - t0) * 1e6,
                     f"{len(single)}pairs"))
    return csv_rows


if __name__ == "__main__":
    run([])
