"""Shared benchmark plumbing: builds the memory systems, runs the synthetic
LoCoMo evaluation, and aggregates per-category / token statistics."""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List

from repro.core.baselines import FullContextMemory, RagChunkMemory
from repro.core.embedder import HashEmbedder
from repro.core.memory import MemoriMemory
from repro.data.locomo_synth import (CATEGORIES, LOCOMO_WEIGHTS,
                                     generate_conversation, judge, oracle_read)

EMB = HashEmbedder()


@dataclasses.dataclass
class EvalResult:
    name: str
    per_category: Dict[str, float]
    overall: float                 # LoCoMo-weighted (paper Table 1 footnote)
    unweighted: float
    mean_tokens: float
    n_questions: int


def build_system(name: str, **kw):
    if name == "memori":
        return MemoriMemory(EMB, budget=kw.get("budget", 1300),
                            use_kernel=False)
    if name == "memori-triples-only":
        m = MemoriMemory(EMB, budget=kw.get("budget", 1300), use_kernel=False)
        m.budgeter.include_summaries = False
        return m
    if name == "memori-dense-only":
        return MemoriMemory(EMB, budget=kw.get("budget", 1300),
                            use_kernel=False, sparse_weight=0.0)
    if name == "memori-bm25-only":
        return MemoriMemory(EMB, budget=kw.get("budget", 1300),
                            use_kernel=False, dense_weight=0.0)
    if name == "rag":
        return RagChunkMemory(EMB, use_kernel=False)
    if name == "full-context":
        return FullContextMemory()
    raise KeyError(name)


def evaluate(system_name: str, *, seeds=(0, 1), n_sessions=10,
             noise_turns=120, budget=1300,
             conversations_per_store: int = 5) -> EvalResult:
    """One persistent store per seed holds `conversations_per_store`
    conversations with disjoint speaker pairs — Memori's actual deployment
    shape (cross-conversation persistent memory), and what makes retrieval
    non-trivial: the bank holds hundreds of triples, most of them
    distractors for any given question."""
    from repro.data.locomo_synth import NAMES
    cat_hits = collections.Counter()
    cat_total = collections.Counter()
    tokens: List[int] = []
    for seed in seeds:
        mem = build_system(system_name, budget=budget)
        convs = []
        for c in range(conversations_per_store):
            pair = (NAMES[(2 * c) % len(NAMES)],
                    NAMES[(2 * c + 1) % len(NAMES)])
            conv = generate_conversation(
                seed=1000 * seed + c, n_sessions=n_sessions,
                noise_turns=noise_turns, name_pair=pair)
            convs.append(conv)
            for sid, msgs in conv.sessions:
                mem.record_session(conv.conversation_id, sid, msgs)
        for conv in convs:
            for q in conv.questions:
                ctx = mem.retrieve(q.question)
                tokens.append(ctx.token_count)
                ok = judge(q, oracle_read(q, ctx.text, salt=system_name))
                cat_hits[q.category] += ok
                cat_total[q.category] += 1
    per_cat = {c: cat_hits[c] / max(1, cat_total[c]) for c in CATEGORIES}
    wsum = sum(LOCOMO_WEIGHTS.values())
    overall = sum(per_cat[c] * LOCOMO_WEIGHTS[c] for c in CATEGORIES) / wsum
    unweighted = sum(cat_hits.values()) / max(1, sum(cat_total.values()))
    return EvalResult(system_name, per_cat, overall, unweighted,
                      sum(tokens) / len(tokens), sum(cat_total.values()))
