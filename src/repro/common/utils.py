"""Small shared helpers: pytree sizes, dtype plumbing, deterministic RNG."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_num_params(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_num_bytes(tree: PyTree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} EiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


def fold_key(key: jax.Array, *names: str) -> jax.Array:
    """Derive a named sub-key deterministically from string names."""
    for name in names:
        h = int.from_bytes(name.encode("utf-8")[:8].ljust(8, b"\0"), "little")
        key = jax.random.fold_in(key, h % (2**31 - 1))
    return key


def asdict_shallow(dc) -> dict:
    return {f.name: getattr(dc, f.name) for f in dataclasses.fields(dc)}


def stable_hash(text: str, mod: int) -> int:
    """Deterministic (cross-run, cross-process) string hash -> [0, mod)."""
    h = 2166136261
    for b in text.encode("utf-8"):
        h = ((h ^ b) * 16777619) & 0xFFFFFFFF
    return h % mod


def log_bucket(x: float, buckets: int = 64) -> int:
    if x <= 0:
        return 0
    return min(buckets - 1, int(math.log2(x + 1)))
