"""Training + checkpointing integration tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs import get_config
from repro.data.pipeline import batches
from repro.models.model_api import Model
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, train

KEY = jax.random.PRNGKey(0)


def test_tiny_lm_loss_decreases():
    cfg = get_config("memori-agent").reduced(layers=2, d_model=128)
    model = Model(cfg)
    params = model.init_params(KEY)
    tc = TrainConfig(steps=25, log_every=5,
                     opt=opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=5,
                                             total_steps=25))
    params, hist = train(model, params,
                         batches(4, 64, vocab_size=cfg.vocab_size), tc)
    assert hist[-1]["ce"] < hist[0]["ce"] - 0.2
    assert np.isfinite(hist[-1]["grad_norm"])


def test_grad_accumulation_matches_large_batch():
    cfg = get_config("memori-agent").reduced(layers=2, d_model=64)
    model = Model(cfg)
    params = model.init_params(KEY)
    data = next(batches(4, 32, vocab_size=cfg.vocab_size, microbatches=2))
    big = {k: v.reshape(-1, *v.shape[2:]) for k, v in data.items()}

    loss_big, _ = model.train_loss(params, big)
    l0, _ = model.train_loss(params, {k: v[0] for k, v in data.items()})
    l1, _ = model.train_loss(params, {k: v[1] for k, v in data.items()})
    # equal-sized microbatches with near-equal token counts: mean of means
    np.testing.assert_allclose(float((l0 + l1) / 2), float(loss_big), rtol=2e-2)


def test_checkpoint_roundtrip():
    cfg = get_config("memori-agent").reduced(layers=2, d_model=64)
    model = Model(cfg)
    params = model.init_params(KEY)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        n = ckpt.save(path, params)
        assert n > 0
        zeros = jax.tree.map(lambda x: jnp.zeros_like(x), params)
        loaded = ckpt.load(path, zeros)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_schedule_warmup_and_decay():
    cfg = opt.OptimizerConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                              total_steps=100)
    lrs = [float(opt.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == 0.5
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6
