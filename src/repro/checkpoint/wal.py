"""Segmented write-ahead log for the memory store's lifecycle runtime.

One directory holds the full durable state of a `MemoryStore`:

    <dir>/
      MANIFEST.msgpack            advisory index (retained generations)
      snapshot-00000007.msgpack   full-store snapshot, name encodes the WAL
                                  seq it covers ("everything through seq 7")
      wal-00000008.msgpack        one segment per durable mutation after it
      wal-00000009.msgpack

Every append and every snapshot is written **atomically**: the bytes go to a
`*.tmp` sibling, are fsync'd, and are `os.replace`d into the final name (the
directory is fsync'd after the rename), so a crash at any instant leaves
either the complete file or no file — never a torn segment under its real
name.  Each segment is self-describing (version + seq + CRC32 of the
payload), so recovery validates what it reads instead of trusting it.

Recovery = newest restorable snapshot + ordered replay of the segments with
seq greater than the snapshot's coverage.  Rotation writes a fresh snapshot,
re-points the manifest, prunes snapshot generations beyond the retention
count, and only then truncates WAL segments — and only those at or below the
coverage of the *oldest retained* snapshot, so every retained generation can
still be brought fully up to date from the segments that remain.

The log stores opaque msgpack records; what they mean is the store's
business (`MemoryStore.wal_record types`, replayed by `MemoryStore.
apply_wal`).  See docs/OPERATIONS.md for the operator view and
docs/STORAGE.md for the record format.
"""
from __future__ import annotations

import os
import re
import warnings
import zlib
from typing import Iterator, List, Optional, Tuple

import msgpack

SEGMENT_VERSION = 1
MANIFEST_NAME = "MANIFEST.msgpack"
_SEG_RE = re.compile(r"^wal-(\d{8})\.msgpack$")
_SNAP_RE = re.compile(r"^snapshot-(\d{8})\.msgpack$")


def fsync_dir(path: str) -> None:
    """Flush a directory entry table (the rename durability point)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, blob: bytes) -> None:
    """tmp + fsync + rename + dir-fsync: the file exists completely or not
    at all, and survives power loss once this returns."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)))


class CorruptSegmentError(RuntimeError):
    """A WAL segment failed validation (bad version, seq, or checksum)."""


class WriteAheadLog:
    def __init__(self, dirpath: str):
        self.dir = os.path.abspath(dirpath)
        os.makedirs(self.dir, exist_ok=True)
        # seq numbering continues past everything ever named on disk —
        # including snapshots' coverage, so a post-recovery append can never
        # collide with a truncated-away segment's seq
        tail = max(self.segment_seqs(), default=0)
        snaps = max((s for s, _ in self.snapshots()), default=0)
        self._next_seq = max(tail, snaps) + 1

    # -- paths -------------------------------------------------------------
    def _seg_path(self, seq: int) -> str:
        return os.path.join(self.dir, f"wal-{seq:08d}.msgpack")

    def snapshot_path(self, wal_through: int) -> str:
        """The snapshot file covering every segment with seq <=
        `wal_through` (the coverage is encoded in the name, so recovery
        needs no manifest to pair snapshots with segments)."""
        return os.path.join(self.dir, f"snapshot-{wal_through:08d}.msgpack")

    # -- scan --------------------------------------------------------------
    def segment_seqs(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _SEG_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def snapshots(self) -> List[Tuple[int, str]]:
        """[(wal_through, path)] sorted oldest -> newest."""
        out = []
        for name in os.listdir(self.dir):
            m = _SNAP_RE.match(name)
            if m:
                out.append((int(m.group(1)), os.path.join(self.dir, name)))
        return sorted(out)

    def latest_snapshot(self) -> Optional[Tuple[int, str]]:
        snaps = self.snapshots()
        return snaps[-1] if snaps else None

    @property
    def last_seq(self) -> int:
        """Seq of the most recently appended segment (0 if none ever)."""
        return self._next_seq - 1

    # -- append ------------------------------------------------------------
    def append(self, record: dict) -> int:
        """Durably append one record as its own segment.  Returns the seq.
        When this returns, the record survives kill -9 / power loss."""
        seq = self._next_seq
        payload = msgpack.packb(record, use_bin_type=True)
        envelope = msgpack.packb({
            "version": SEGMENT_VERSION,
            "seq": seq,
            "crc": zlib.crc32(payload),
            "payload": payload,
        }, use_bin_type=True)
        atomic_write_bytes(self._seg_path(seq), envelope)
        self._next_seq = seq + 1
        return seq

    # -- read / replay -----------------------------------------------------
    def read_segment(self, seq: int) -> dict:
        """Decode + validate one segment; raises CorruptSegmentError."""
        with open(self._seg_path(seq), "rb") as f:
            raw = f.read()
        try:
            env = msgpack.unpackb(raw, raw=False)
            version, crc = env["version"], env["crc"]
            payload = env["payload"]
        except Exception as e:
            raise CorruptSegmentError(f"segment {seq}: undecodable ({e})")
        if version != SEGMENT_VERSION:
            raise CorruptSegmentError(
                f"segment {seq}: version {version} != {SEGMENT_VERSION}")
        if env.get("seq") != seq:
            raise CorruptSegmentError(
                f"segment file {seq} claims seq {env.get('seq')}")
        if zlib.crc32(payload) != crc:
            raise CorruptSegmentError(f"segment {seq}: checksum mismatch")
        return msgpack.unpackb(payload, raw=False)

    def replay_records(self, after_seq: int = 0
                       ) -> Iterator[Tuple[int, dict]]:
        """Yield (seq, record) in order for every valid segment with
        seq > after_seq.  Replay stops at the first invalid segment (with a
        warning): everything after an undecodable record has unknown
        provenance and must not be applied."""
        for seq in self.segment_seqs():
            if seq <= after_seq:
                continue
            try:
                rec = self.read_segment(seq)
            except CorruptSegmentError as e:
                warnings.warn(f"WAL replay stopped: {e}", stacklevel=2)
                return
            yield seq, rec

    # -- rotation ----------------------------------------------------------
    def commit_snapshot(self, wal_through: int, retain: int = 2) -> dict:
        """Called after the snapshot file for `wal_through` is atomically in
        place: re-point the manifest, prune generations beyond `retain`, and
        truncate segments no retained generation still needs.  Returns a
        summary dict (snapshots kept, segments dropped)."""
        snaps = self.snapshots()
        if wal_through not in [s for s, _ in snaps]:
            raise FileNotFoundError(
                f"no snapshot file for wal_through={wal_through}")
        keep = snaps[-retain:] if retain else snaps
        self.write_manifest(keep)
        dropped_snaps = 0
        for through, path in snaps[:-retain] if retain else []:
            os.unlink(path)
            dropped_snaps += 1
        # only segments every retained snapshot already covers may go
        oldest_covered = min(s for s, _ in keep)
        dropped_segs = 0
        for seq in self.segment_seqs():
            if seq <= oldest_covered:
                os.unlink(self._seg_path(seq))
                dropped_segs += 1
        fsync_dir(self.dir)
        return {"retained_snapshots": len(keep),
                "dropped_snapshots": dropped_snaps,
                "truncated_segments": dropped_segs}

    # -- manifest (advisory: recovery trusts the directory scan) -----------
    def write_manifest(self, snaps: List[Tuple[int, str]]) -> None:
        atomic_write_bytes(os.path.join(self.dir, MANIFEST_NAME),
                           msgpack.packb({
                               "version": SEGMENT_VERSION,
                               "snapshots": [
                                   {"wal_through": s,
                                    "name": os.path.basename(p)}
                                   for s, p in snaps],
                           }, use_bin_type=True))

    def read_manifest(self) -> Optional[dict]:
        path = os.path.join(self.dir, MANIFEST_NAME)
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return msgpack.unpackb(f.read(), raw=False)
