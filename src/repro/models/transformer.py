"""Decoder stack with scanned layer segments.

Layers are grouped by `plan_segments` into (period_kinds, repeats) segments;
segments with repeats > 1 are executed with jax.lax.scan over stacked params
(one layer body in the HLO — tractable AOT compiles for 61-layer configs and
the standard production pattern).  Heterogeneous patterns (recurrentgemma's
rglru/rglru/attn, deepseek's 3-dense prefix) become multiple segments.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.common.module import ParamSpec, stack
from repro.models import blocks
from repro.models.config import ModelConfig, plan_segments
from repro.models.layers import embedding, norms

PyTree = Any


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def decoder_specs(cfg: ModelConfig, *, cross: bool = False):
    segments = []
    for period, repeats in plan_segments(cfg.layer_kinds()):
        blks = tuple(blocks.block_specs(cfg, kind, cross=cross) for kind in period)
        segments.append(stack(blks, repeats) if repeats > 1 else blks)
    return {"segments": tuple(segments), "final_norm": norms.specs(cfg)}


def lm_specs(cfg: ModelConfig, *, cross: bool = False):
    s = {"embed": embedding.specs(cfg), **decoder_specs(cfg, cross=cross)}
    return s


def decoder_cache_shape_specs(cfg: ModelConfig, batch: int, max_len: int,
                              dtype, *, cross: bool = False, enc_len: int = 0,
                              window_override=None):
    """Mirrors the segment structure with (shape, axes, dtype) leaves."""
    segments = []
    for period, repeats in plan_segments(cfg.layer_kinds()):
        blks = []
        for kind in period:
            cs = blocks.block_cache_specs(cfg, kind, batch, max_len, dtype,
                                          cross=cross, enc_len=enc_len,
                                          window=_block_window(cfg, kind, window_override))
            if repeats > 1:
                cs = {k: ((repeats, *shape), ("layers", *axes), dt)
                      for k, (shape, axes, dt) in cs.items()}
            blks.append(cs)
        segments.append(tuple(blks))
    return tuple(segments)


def _is_shape_leaf(x):
    return (isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple))


def _map_cache_specs(fn, cfg, batch, max_len, dtype, *, cross=False,
                     enc_len=0, window_override=None):
    shape_specs = decoder_cache_shape_specs(
        cfg, batch, max_len, dtype, cross=cross, enc_len=enc_len,
        window_override=window_override)
    return jax.tree.map(fn, shape_specs, is_leaf=_is_shape_leaf)


def init_caches(cfg, batch, max_len, dtype, *, cross=False, enc_len=0,
                window_override=None):
    def make(leaf):
        shape, axes, dt = leaf
        fill = -1 if dt == jnp.int32 else 0
        return jnp.full(shape, fill, dt)
    return _map_cache_specs(make, cfg, batch, max_len, dtype, cross=cross,
                            enc_len=enc_len, window_override=window_override)


def abstract_caches(cfg, batch, max_len, dtype, *, cross=False, enc_len=0,
                    window_override=None):
    def make(leaf):
        shape, axes, dt = leaf
        return jax.ShapeDtypeStruct(shape, dt)
    return _map_cache_specs(make, cfg, batch, max_len, dtype, cross=cross,
                            enc_len=enc_len, window_override=window_override)


def cache_pspecs(cfg, batch, max_len, dtype, rules, *, cross=False, enc_len=0,
                 window_override=None):
    def make(leaf):
        shape, axes, dt = leaf
        return rules.spec_for(axes, shape)
    return _map_cache_specs(make, cfg, batch, max_len, dtype, cross=cross,
                            enc_len=enc_len, window_override=window_override)


# ---------------------------------------------------------------------------
# Prefill-cache -> decode-cache conversion
# ---------------------------------------------------------------------------

def _pad_seq(x, axis, to_len):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, to_len - x.shape[axis])
    return jnp.pad(x, pad)


def _ring_slots(S: int, W: int):
    """Slot j for ring index i after S prefilled tokens (slot i holds the
    token whose position ≡ i (mod W), among the last W positions)."""
    i = jnp.arange(W)
    return S - W + ((i - (S % W)) % W)


def _prep_block_cache(bc, prefill_len, max_len, window, quant=""):
    if bc is None:
        return None
    S = prefill_len
    out = {}
    ring = bool(window) and 0 < window < max_len
    for name, x in bc.items():
        if name in ("k", "v"):
            axis = x.ndim - 3
            if ring:
                W = window
                x = (jnp.take(x, _ring_slots(S, W), axis=axis)
                     if S >= W else _pad_seq(x, axis, W))
            else:
                x = _pad_seq(x, axis, max_len)
            if quant == "int8":
                from repro.models.layers.attention import quantize_kv
                q, sc = quantize_kv(x)
                out[name] = q
                out[name + "_scale"] = sc
            else:
                out[name] = x
        elif name in ("ckv", "k_rope"):
            out[name] = _pad_seq(x, x.ndim - 2, max_len)
        else:
            out[name] = x
    if ring and "k" in bc:
        W = window
        lead = out["k"].shape[: out["k"].ndim - 3]
        if S >= W:
            pos1 = _ring_slots(S, W)
        else:
            pos1 = jnp.concatenate(
                [jnp.arange(S), jnp.full((W - S,), -1, jnp.int32)]).astype(jnp.int32)
        out["pos"] = jnp.broadcast_to(pos1.astype(jnp.int32), (*lead, W))
    return out


def prepare_decode_caches(cfg, caches, prefill_len: int, max_len: int, *,
                          window_override=None):
    """Convert prefill caches (seq length = prefill_len) into decode caches:
    full caches padded to max_len; windowed attention converted to the
    ring-buffer layout with true slot positions."""
    plan = plan_segments(cfg.layer_kinds())
    out_segments = []
    for seg_i, (period, repeats) in enumerate(plan):
        seg = caches[seg_i]
        new_blocks = []
        for b_i, kind in enumerate(period):
            w = _block_window(cfg, kind, window_override)
            new_blocks.append(_prep_block_cache(
                seg[b_i], prefill_len, max_len, w,
                quant=(cfg.kv_cache_quant if kind[0] == "attn"
                       and not cfg.use_mla else "")))
        out_segments.append(tuple(new_blocks))
    return tuple(out_segments)


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _block_window(cfg, kind, window_override: Optional[int]):
    mixer, _ = kind
    if mixer != "attn":
        return 0
    if cfg.hybrid_period > 0:
        return cfg.rglru.local_window
    if window_override is not None:
        return window_override
    return cfg.sliding_window


def decoder_apply(params, cfg: ModelConfig, x, *, mode: str, positions,
                  caches=None, cache_pos=None, mask_kind: str = "causal",
                  prefix_len=None, enc_out=None, enc_positions=None,
                  rules=None, window_override: Optional[int] = None,
                  return_cache: bool = False, use_rope: bool = True,
                  remat: bool = True):
    """x: (B,S,d) embeddings -> (hidden (B,S,d), new_caches, aux)."""
    plan = plan_segments(cfg.layer_kinds())
    aux_total = blocks.zero_aux()
    new_caches_all = []

    def apply_block(blk_params, kind, xx, blk_cache):
        return blocks.apply(
            blk_params, cfg, xx, kind, mode=mode, positions=positions,
            cache=blk_cache, cache_pos=cache_pos, mask_kind=mask_kind,
            window=_block_window(cfg, kind, window_override),
            prefix_len=prefix_len, enc_out=enc_out,
            enc_positions=enc_positions, rules=rules,
            return_cache=return_cache, use_rope=use_rope)

    for seg_i, (period, repeats) in enumerate(plan):
        seg_params = params["segments"][seg_i]
        seg_caches = caches[seg_i] if caches is not None else tuple(None for _ in period)

        if repeats == 1:
            new_seg_caches = []
            for b_i, kind in enumerate(period):
                x, nc, aux = apply_block(seg_params[b_i], kind, x, seg_caches[b_i])
                new_seg_caches.append(nc)
                aux_total = {k: aux_total[k] + aux[k] for k in aux_total}
            new_caches_all.append(tuple(new_seg_caches))
        elif cfg.force_unroll:
            # probe mode: unroll the stacked segment so HLO cost analysis
            # counts every layer (lax.scan bodies are counted once)
            reps_caches = []
            for r_i in range(repeats):
                take = lambda t: jax.tree.map(lambda a: a[r_i], t)
                blk_params = take(seg_params)
                blk_caches = (take(seg_caches)
                              if any(c is not None for c in seg_caches) else
                              tuple(None for _ in period))
                new_cs = []
                for b_i, kind in enumerate(period):
                    x, nc, aux = apply_block(blk_params[b_i], kind, x,
                                             blk_caches[b_i])
                    new_cs.append(nc)
                    aux_total = {k: aux_total[k] + aux[k] for k in aux_total}
                reps_caches.append(tuple(new_cs))
            if any(any(c is not None for c in rc) for rc in reps_caches):
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *reps_caches)
            else:
                stacked = reps_caches[0]
            new_caches_all.append(stacked)
        else:
            def seg_body(carry, xs):
                xx, aux_c = carry
                blk_params_stack, blk_caches_stack = xs
                new_cs = []
                for b_i, kind in enumerate(period):
                    cache_b = (blk_caches_stack[b_i]
                               if blk_caches_stack is not None else None)
                    xx, nc, aux = apply_block(blk_params_stack[b_i], kind, xx, cache_b)
                    new_cs.append(nc)
                    aux_c = {k: aux_c[k] + aux[k] for k in aux_c}
                return (xx, aux_c), tuple(new_cs)

            body = seg_body
            if remat and mode == "train":
                body = jax.checkpoint(seg_body)
            xs = (seg_params, seg_caches if any(c is not None for c in seg_caches) else None)
            (x, aux_total), seg_new_caches = jax.lax.scan(
                body, (x, aux_total), xs)
            new_caches_all.append(seg_new_caches)

    x = norms.apply(params["final_norm"], cfg, x)
    new_caches = tuple(new_caches_all) if return_cache or mode == "decode" else None
    return x, new_caches, aux_total
