"""Replicated durability layer (checkpoint/replication.py): per-shard WAL
ownership with cross-shard commit records, the segment shipper streaming
sealed segments to a follower sink, recover-from-follower helpers, and the
WAL corruption fuzz suite — random bit flips / truncations over sealed
segments must always yield quarantine-and-stop, never a silent skip or a
wrong replay."""
import os
import random
import shutil
import warnings

import pytest

from repro.checkpoint import faults
from repro.checkpoint.faults import FaultRule, FaultyFS, InjectedCrash
from repro.checkpoint.replication import (DirectorySink, SegmentShipper,
                                          ShardedWal, clone_from_follower,
                                          detect_shards, open_wal,
                                          restore_missing_from_follower)
from repro.checkpoint.wal import WriteAheadLog, atomic_write_bytes


def _flush(parts, ns_ids=None):
    rec = {"op": "sharded_flush", "parts": [[s, p] for s, p in parts]}
    if ns_ids is not None:
        rec["ns_ids"] = ns_ids
    return rec


def _replay(wal):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return list(wal.replay_records())


# -- commit protocol -----------------------------------------------------------

def test_sharded_flush_round_trips_through_decompose_and_reinflate(tmp_path):
    d = str(tmp_path / "w")
    wal = ShardedWal(d, 2)
    f1 = _flush([(0, {"rows": [1, 2]}), (1, {"rows": [3]})],
                ns_ids={"alice": 0, "bob": 1})
    wal.append(f1)
    wal.append({"op": "evict", "ns": "alice", "ids": [2]})
    # layout: parts live in the shard logs, the coordinator holds ONE
    # commit record per flush (never the vectors themselves)
    assert os.path.isfile(os.path.join(d, "shard-00", "wal-00000001.msgpack"))
    assert os.path.isfile(os.path.join(d, "shard-01", "wal-00000001.msgpack"))
    commit = wal.commit.read_segment(1)
    assert commit["op"] == "shard_commit"
    assert commit["parts"] == [[0, 1], [1, 1]]
    assert commit["ns_ids"] == {"alice": 0, "bob": 1}
    got = _replay(ShardedWal(d, 2))
    assert got == [(1, f1), (2, {"op": "evict", "ns": "alice", "ids": [2]})]


def test_shardedwal_rejects_single_shard_and_out_of_range_parts(tmp_path):
    with pytest.raises(ValueError):
        ShardedWal(str(tmp_path / "a"), 1)
    wal = ShardedWal(str(tmp_path / "b"), 2)
    with pytest.raises(ValueError):
        wal.append(_flush([(5, {"rows": [1]})]))


def test_crash_before_commit_record_leaves_invisible_orphan(tmp_path):
    """Shard parts land first; the flush is durable iff the commit record
    is.  Crash between the two => the shard segment is an orphan replay
    never references."""
    fs = FaultyFS(str(tmp_path),
                  rules=[FaultRule("replace", path_substr="w/wal-00000001")])
    d = str(tmp_path / "w")
    with faults.install(fs):
        wal = ShardedWal(d, 2)
        with pytest.raises(InjectedCrash):
            wal.append(_flush([(0, {"rows": [1]})]))
        fs.simulate_power_loss()
    # the shard part survived (it was fsync'd before the coordinator write)
    assert os.path.isfile(os.path.join(d, "shard-00", "wal-00000001.msgpack"))
    wal2 = ShardedWal(d, 2)
    assert _replay(wal2) == []
    assert wal2.replay_stopped_seq is None      # orphan, not corruption


def test_group_commit_is_all_or_nothing_across_shards(tmp_path):
    fs = FaultyFS(str(tmp_path),
                  rules=[FaultRule("replace", path_substr="w/wal-00000002")])
    d = str(tmp_path / "w")
    with faults.install(fs):
        wal = ShardedWal(d, 2)
        f1 = _flush([(0, {"rows": [1]})])
        wal.append(f1)
        with pytest.raises(InjectedCrash):
            wal.append_group([_flush([(0, {"rows": [2]}), (1, {"rows": [3]})]),
                              {"op": "evict", "ns": "a", "ids": [1]}])
        fs.simulate_power_loss()
    # both shards' parts of the crashed group are durable orphans ...
    assert os.path.isfile(os.path.join(d, "shard-00", "wal-00000002.msgpack"))
    assert os.path.isfile(os.path.join(d, "shard-01", "wal-00000001.msgpack"))
    # ... but the group as a whole never happened
    assert _replay(ShardedWal(d, 2)) == [(1, f1)]


def test_rotation_reaps_orphaned_and_covered_shard_segments(tmp_path):
    fs = FaultyFS(str(tmp_path),
                  rules=[FaultRule("replace", path_substr="w/wal-00000002",
                                   nth=1)])
    d = str(tmp_path / "w")
    with faults.install(fs):
        wal = ShardedWal(d, 2)
        wal.append(_flush([(0, {"rows": [1]})]))
        with pytest.raises(InjectedCrash):        # orphans shard-00 seq 2
            wal.append(_flush([(0, {"rows": [2]})]))
        fs.simulate_power_loss()
    wal = ShardedWal(d, 2)
    wal.append(_flush([(0, {"rows": [3]}), (1, {"rows": [4]})]))  # seq 2
    assert len(wal.shards[0].segment_seqs()) == 3   # incl. the orphan
    atomic_write_bytes(wal.snapshot_path(2), b"snapshot-bytes")
    info = wal.commit_snapshot(2, retain=1)
    # every commit is covered by the snapshot: all shard segments —
    # covered AND orphaned — are unreferenced now
    assert info["truncated_shard_segments"] == 4
    assert wal.shards[0].segment_seqs() == []
    assert wal.shards[1].segment_seqs() == []
    assert _replay(ShardedWal(d, 2)) == []


def test_rotation_keeps_shard_segments_still_referenced(tmp_path):
    d = str(tmp_path / "w")
    wal = ShardedWal(d, 2)
    wal.append(_flush([(0, {"rows": [1]})]))                    # seq 1
    f2 = _flush([(0, {"rows": [2]}), (1, {"rows": [3]})])
    wal.append(f2)                                              # seq 2
    atomic_write_bytes(wal.snapshot_path(1), b"snapshot-bytes")
    info = wal.commit_snapshot(1, retain=1)
    # commit 2 is past the snapshot: its parts must survive the GC
    assert info["truncated_shard_segments"] == 1                # only seq-1's
    assert wal.shards[0].segment_seqs() == [2]
    assert wal.shards[1].segment_seqs() == [1]
    assert _replay(ShardedWal(d, 2)) == [(2, f2)]


def test_missing_shard_part_stops_replay_at_the_commit_record(tmp_path):
    d = str(tmp_path / "w")
    wal = ShardedWal(d, 2)
    f1 = _flush([(0, {"rows": [1]}), (1, {"rows": [2]})])
    f2 = _flush([(0, {"rows": [3]}), (1, {"rows": [4]})])
    f3 = _flush([(1, {"rows": [5]})])
    for f in (f1, f2, f3):
        wal.append(f)
    os.unlink(os.path.join(d, "shard-01", "wal-00000002.msgpack"))  # f2's part
    wal2 = ShardedWal(d, 2)
    with pytest.warns(UserWarning, match="replay stopped"):
        got = list(wal2.replay_records())
    assert got == [(1, f1)]                     # consistent prefix, never
    assert wal2.replay_stopped_seq == 2         # a partial flush
    # quarantine the dead tail, remount, and keep appending cleanly
    with pytest.warns(UserWarning, match="quarantined"):
        wal2.quarantine_from(2)
    wal3 = ShardedWal(d, 2)
    f4 = _flush([(0, {"rows": [6]})])
    wal3.append(f4)
    got = _replay(ShardedWal(d, 2))
    assert [r for _, r in got] == [f1, f4]
    assert ShardedWal(d, 2).replay_stopped_seq is None


def test_corrupt_shard_part_stops_replay_at_the_commit_record(tmp_path):
    d = str(tmp_path / "w")
    wal = ShardedWal(d, 2)
    f1 = _flush([(1, {"rows": [1]})])
    f2 = _flush([(0, {"rows": [2]})])
    wal.append(f1), wal.append(f2)
    p = os.path.join(d, "shard-00", "wal-00000001.msgpack")
    with open(p, "rb") as f:
        raw = bytearray(f.read())
    raw[len(raw) // 2] ^= 0x40
    with open(p, "wb") as f:
        f.write(bytes(raw))
    wal2 = ShardedWal(d, 2)
    got = _replay(wal2)
    assert got == [(1, f1)]
    assert wal2.replay_stopped_seq == 2


# -- open / detect helpers -----------------------------------------------------

def test_detect_shards(tmp_path):
    assert detect_shards(str(tmp_path / "missing")) == 0
    d = tmp_path / "w"
    d.mkdir()
    assert detect_shards(str(d)) == 0
    (d / "shard-00").mkdir(), (d / "shard-01").mkdir()
    assert detect_shards(str(d)) == 2
    (d / "shard-03").mkdir()                    # gap: shard-02 lost
    with pytest.raises(ValueError, match="missing"):
        detect_shards(str(d))


def test_open_wal_autodetects_and_validates(tmp_path):
    fresh = str(tmp_path / "a")
    assert isinstance(open_wal(fresh), WriteAheadLog)
    sharded_dir = str(tmp_path / "b")
    wal = open_wal(sharded_dir, shards=4)
    assert isinstance(wal, ShardedWal) and wal.n_shards == 4
    auto = open_wal(sharded_dir)                # layout remembers the count
    assert isinstance(auto, ShardedWal) and auto.n_shards == 4
    with pytest.raises(ValueError, match="4-shard"):
        open_wal(sharded_dir, shards=3)
    assert isinstance(open_wal(str(tmp_path / "c"), shards=1), WriteAheadLog)


# -- segment shipping ----------------------------------------------------------

def test_shipper_streams_sealed_segments_to_the_sink(tmp_path):
    d, fdir = str(tmp_path / "w"), str(tmp_path / "follower")
    wal = WriteAheadLog(d)
    sink = DirectorySink(fdir)
    shipper = SegmentShipper(d, sink, mode="sync")
    wal.on_seal = shipper
    wal.append({"op": "a"})
    wal.append_group([{"op": "b"}, {"op": "c"}])
    assert sink.list() == ["wal-00000001.msgpack", "wal-00000002.msgpack"]
    assert shipper.counters == {"shipped": 2, "failed": 0, "queued": 0}
    for rel in sink.list():                     # byte-identical replicas
        with open(os.path.join(d, rel), "rb") as f:
            assert sink.get(rel) == f.read()


def test_shipper_covers_shard_logs_through_one_on_seal_hook(tmp_path):
    d, fdir = str(tmp_path / "w"), str(tmp_path / "follower")
    wal = ShardedWal(d, 2)
    sink = DirectorySink(fdir)
    wal.on_seal = SegmentShipper(d, sink, mode="sync")
    wal.append(_flush([(0, {"rows": [1]}), (1, {"rows": [2]})]))
    assert sink.list() == ["shard-00/wal-00000001.msgpack",
                           "shard-01/wal-00000001.msgpack",
                           "wal-00000001.msgpack"]


def test_ship_failure_is_counted_never_raised_into_append(tmp_path):
    class BrokenSink:
        def put(self, rel, blob):
            raise OSError("sink offline")

        def has(self, rel):
            return False

    d = str(tmp_path / "w")
    wal = WriteAheadLog(d)
    shipper = SegmentShipper(d, BrokenSink(), mode="sync")
    wal.on_seal = shipper
    with pytest.warns(UserWarning, match="ship failed"):
        seq = wal.append({"op": "a"})           # append itself succeeds:
    assert seq == 1                             # local fsync is durability,
    assert shipper.counters["failed"] == 1      # shipping is replication


def test_ship_fault_point_and_slow_sink_delay(tmp_path):
    fs = FaultyFS(str(tmp_path), rules=[
        FaultRule("ship", path_substr="wal-00000001"),
        FaultRule("ship", mode="delay", delay_s=0.01,
                  path_substr="wal-00000002")])
    d, fdir = str(tmp_path / "w"), str(tmp_path / "follower")
    with faults.install(fs):
        wal = WriteAheadLog(d)
        sink = DirectorySink(fdir)
        shipper = SegmentShipper(d, sink, mode="sync")
        wal.on_seal = shipper
        with pytest.warns(UserWarning, match="ship failed"):
            wal.append({"op": "a"})             # crash point: ship fails,
        wal.append({"op": "b"})                 # slow sink: just latency
    assert shipper.counters == {"shipped": 1, "failed": 1, "queued": 0}
    assert sink.list() == ["wal-00000002.msgpack"]
    assert [t[:2] for t in fs.trips] == [("ship", "crash"), ("ship", "delay")]


def test_async_shipper_drains_off_the_append_path(tmp_path):
    d, fdir = str(tmp_path / "w"), str(tmp_path / "follower")
    wal = WriteAheadLog(d)
    sink = DirectorySink(fdir)
    shipper = SegmentShipper(d, sink, mode="async")
    wal.on_seal = shipper
    try:
        for op in ("a", "b", "c"):
            wal.append({"op": op})
        shipper.drain()
        assert shipper.counters["shipped"] == 3
        assert len(sink.list()) == 3
    finally:
        shipper.close()


def test_ship_existing_backfills_only_what_the_sink_lacks(tmp_path):
    d, fdir = str(tmp_path / "w"), str(tmp_path / "follower")
    wal = ShardedWal(d, 2)
    wal.append(_flush([(0, {"rows": [1]})]))
    wal.append({"op": "evict", "ns": "a", "ids": [1]})
    sink = DirectorySink(fdir)
    shipper = SegmentShipper(d, sink, mode="sync")
    assert shipper.ship_existing() == 3         # 2 coordinator + 1 shard seg
    assert shipper.ship_existing() == 0         # idempotent
    assert len(sink.list()) == 3


# -- recover from follower -----------------------------------------------------

def test_restore_missing_skips_existing_and_quarantined_twins(tmp_path):
    fdir, d = str(tmp_path / "follower"), str(tmp_path / "data")
    sink = DirectorySink(fdir)
    sink.put("wal-00000001.msgpack", b"one")
    sink.put("wal-00000002.msgpack", b"two")
    sink.put("shard-00/wal-00000001.msgpack", b"part")
    os.makedirs(d)
    with open(os.path.join(d, "wal-00000001.msgpack"), "wb") as f:
        f.write(b"local-is-newer")
    # a quarantined twin means local recovery already rejected this file:
    # re-materializing it would resurrect the corrupt tail
    with open(os.path.join(d, "wal-00000002.msgpack.corrupt"), "wb") as f:
        f.write(b"dead")
    restored = restore_missing_from_follower(sink, d)
    assert restored == ["shard-00/wal-00000001.msgpack"]
    with open(os.path.join(d, "wal-00000001.msgpack"), "rb") as f:
        assert f.read() == b"local-is-newer"
    assert not os.path.exists(os.path.join(d, "wal-00000002.msgpack"))


def test_clone_from_follower_requires_empty_target(tmp_path):
    sink = DirectorySink(str(tmp_path / "follower"))
    sink.put("wal-00000001.msgpack", b"one")
    tgt = tmp_path / "data"
    tgt.mkdir()
    (tgt / "stale").write_bytes(b"x")
    with pytest.raises(ValueError, match="not empty"):
        clone_from_follower(sink, str(tgt))


def test_losing_the_host_entirely_recovers_from_shipped_segments(tmp_path):
    d, fdir = str(tmp_path / "w"), str(tmp_path / "follower")
    wal = ShardedWal(d, 2)
    sink = DirectorySink(fdir)
    shipper = SegmentShipper(d, sink, mode="sync")
    wal.on_seal = shipper
    flushes = [_flush([(i % 2, {"rows": [i]})], ns_ids={"t": i % 2})
               for i in range(5)]
    for f in flushes:
        wal.append(f)
    expected = _replay(ShardedWal(d, 2))
    shutil.rmtree(d)                            # the host is gone
    clone_from_follower(sink, d)
    recovered = open_wal(d)                     # autodetects 2 shards
    assert isinstance(recovered, ShardedWal) and recovered.n_shards == 2
    assert _replay(recovered) == expected
    assert recovered.replay_stopped_seq is None


# -- corruption fuzz: the recovery oracle --------------------------------------
#
# Property: whatever a bit flip or truncation does to one sealed segment,
# replay yields an EXACT PREFIX of the pristine record sequence and flags
# where it stopped — never a silently skipped or altered record.  After
# quarantining the flagged tail, a remount replays that same prefix
# cleanly.

def _corrupt_file(path, rng):
    with open(path, "rb") as f:
        raw = bytearray(f.read())
    if rng.random() < 0.4:
        cut = rng.randrange(0, len(raw))
        blob, what = bytes(raw[:cut]), f"truncate@{cut}"
    else:
        flips = rng.choice([1, 1, 2])
        picks = set()
        while len(picks) < flips:                # distinct bits: two flips
            picks.add((rng.randrange(len(raw)),  # must never cancel out
                       rng.randrange(8)))
        for i, b in picks:
            raw[i] ^= 1 << b
        blob, what = bytes(raw), f"bitflip x{flips}"
    with open(path, "wb") as f:
        f.write(blob)
    return what


def test_fuzz_plain_wal_corruption_always_stops_with_exact_prefix(tmp_path):
    rng = random.Random(0xC0FFEE)
    for trial in range(40):
        d = str(tmp_path / f"t{trial:02d}")
        wal = WriteAheadLog(d)
        wal.append({"op": "a", "trial": trial})
        wal.append_group([{"op": "b", "i": i} for i in range(3)])
        wal.append({"op": "c"})
        wal.append_group([{"op": "d", "i": i} for i in range(2)])
        wal.append({"op": "e"})
        pristine = _replay(WriteAheadLog(d))
        file_seqs = wal.segment_seqs()          # [1, 2, 5, 6, 8]
        victim = rng.choice(file_seqs)
        what = _corrupt_file(wal._seg_path(victim), rng)
        mounted = WriteAheadLog(d)
        got = _replay(mounted)
        expect = [(s, r) for s, r in pristine if wal.file_seq_of(s) < victim]
        assert got == expect, f"trial {trial} ({what} in seq {victim})"
        assert mounted.replay_stopped_seq == victim, \
            f"trial {trial} ({what} in seq {victim}): corruption not flagged"
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            mounted.quarantine_from(mounted.replay_stopped_seq)
        clean = WriteAheadLog(d)
        assert _replay(clean) == expect
        assert clean.replay_stopped_seq is None


def test_fuzz_sharded_wal_corruption_always_stops_with_exact_prefix(tmp_path):
    rng = random.Random(0xFEEDFACE)
    for trial in range(25):
        d = str(tmp_path / f"t{trial:02d}")
        wal = ShardedWal(d, 2)
        wal.append(_flush([(0, {"rows": [1]}), (1, {"rows": [2]})],
                          ns_ids={"t": 0}))
        wal.append({"op": "evict", "ns": "t", "ids": [1]})
        wal.append_group([_flush([(1, {"rows": [3]})]),
                          _flush([(0, {"rows": [4]}), (1, {"rows": [5]})])])
        wal.append(_flush([(0, {"rows": [6]})]))
        pristine = _replay(ShardedWal(d, 2))
        victims = []                            # every sealed segment file
        for dirpath, _, names in os.walk(d):
            victims += [os.path.join(dirpath, n) for n in sorted(names)
                        if n.startswith("wal-") and n.endswith(".msgpack")]
        victim = rng.choice(victims)
        what = _corrupt_file(victim, rng)
        mounted = ShardedWal(d, 2)
        got = _replay(mounted)
        label = f"trial {trial} ({what} in {os.path.relpath(victim, d)})"
        assert len(got) < len(pristine), f"{label}: corruption unnoticed"
        assert got == pristine[:len(got)], f"{label}: not an exact prefix"
        stopped = mounted.replay_stopped_seq
        assert stopped is not None, f"{label}: stop not flagged"
        # quarantine works at file granularity: a damaged shard part can
        # stop replay mid-group, and the group's earlier records fall with
        # the quarantined coordinator file (recovery snapshots the applied
        # prefix before dropping the tail — see docs/OPERATIONS.md)
        kept = [(s, r) for s, r in got if mounted.file_seq_of(s) < stopped]
        assert kept == pristine[:len(kept)], f"{label}: bad kept prefix"
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            mounted.quarantine_from(stopped)
        clean = ShardedWal(d, 2)
        assert _replay(clean) == kept
        assert clean.replay_stopped_seq is None
