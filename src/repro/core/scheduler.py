"""MemoryScheduler — continuous batching for memory operations.

`serving/scheduler.py`'s ContinuousBatcher admits queued generation
requests into free engine slots between decode steps; this is the same
idea applied to the memory layer's read/write path.  Real deployments are
many independent clients (SDK wrappers, server handlers, concurrent
agents) each issuing ONE operation at a time — exactly the traffic shape
that pays a solo embed call and a solo device launch per request.  The
scheduler turns that traffic back into the batched hot path the paper's
economics assume:

* `submit(request)` is thread-safe and returns a `concurrent.futures.
  Future[MemoryResponse]`; requests queue until the next tick.
* each tick collects up to `max_batch` requests inside a bounded
  micro-batch window (`tick_interval_s` from the first arrival, closing
  early when the batch fills).  Size `max_batch` to a power of two: the
  service pads every device batch to the next pow2 Q bucket, so a
  64-request tick costs exactly what a 33-request tick costs.
* consecutive RetrieveRequests in a tick run as ONE `MemoryService.
  execute` call — one embed, one masked `topk_mips`, one stacked BM25, one
  fused RRF launch — with per-request `top_k`/weights/stages honored
  inside the shared launches.  N clients submitting single retrieves in
  the same tick answer bit-identically to N sequential `retrieve()` calls
  (asserted in tests/test_scheduler.py).
* writes route through the existing LifecycleRuntime queue, so bounded-
  queue backpressure and WAL ordering are exactly what a direct caller
  gets.  With `flush_writes="tick"` (default) a tick that drained
  RecordRequests ends with ONE batched flush — one embed call, one bank
  append, one WAL record — and a durable ALL-write tick (several write
  requests, no retrieves: the multi-writer drain) group-commits its
  records into one fsync'd WAL segment (`LifecycleRuntime.group_commit`);
  every write future resolves only after that segment is on disk.  Mixed
  ticks keep per-op appends — grouping holds the runtime lock, and a
  retrieve's embed call must stay outside it.
* submission order is preserved within a tick, so a write submitted before
  a read is visible to it (read-your-writes through the runtime).

The tick's drain is no longer FIFO: an `AdmissionController`
(core/admission.py) owns per-tenant queues and the scheduler asks it to
*admit* at submit time (token-bucket rate limits, queue caps, fair-share
shedding — rejections raise `AdmissionError` with a retry-after hint) and
to *select* each tick's batch (strict priority classes, weighted
round-robin across tenants, FIFO within a tenant).  One tenant flooding
`submit()` can therefore no longer starve anyone: its backlog waits in
its own queue while every other tenant keeps its weight share of each
tick (asserted in tests/test_admission.py).  Selection decides only WHO
enters an oversubscribed tick; execution inside the tick returns to
global submission order, so cross-tenant side-effect ordering (evict
before compact), read-your-writes, and consecutive-retrieve launch
sharing are all exactly what the FIFO drain gave.  The default policy
has no limits and admits everything — a limit-free deployment behaves
byte-for-byte as before.

The daemon thread is optional: `run_tick_once()` is the tick body, public
so tests and single-threaded hosts can drive the identical policy
deterministically (mirroring `LifecycleRuntime.run_maintenance_once`).
"""
from __future__ import annotations

import contextlib
import threading
import time
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass
from typing import List, Optional, Sequence, Union

from repro.core.admission import (AdmissionController, AdmissionError,
                                  AdmissionPolicy, tenant_of)
from repro.core.api import (CompactRequest, EvictRequest, MemoryRequest,
                            MemoryResponse, RecordRequest, RetrieveRequest)
from repro.obs.telemetry import RECORD_LATENCY, get_telemetry

_REQUEST_TYPES = (RetrieveRequest, RecordRequest, EvictRequest,
                  CompactRequest)
_OP_NAMES = {RetrieveRequest: "retrieve", RecordRequest: "record",
             EvictRequest: "evict", CompactRequest: "compact"}


@dataclass
class _Pending:
    req: MemoryRequest
    future: Future
    t_submit: float
    tenant: str = ""
    seq: int = 0
    # the edge's Trace (obs/telemetry.py), when the submitter wants this
    # request's tick + plan stages recorded into its span tree
    trace: Optional[object] = None


class MemoryScheduler:
    def __init__(self, service, tick_interval_s: float = 0.002,
                 max_batch: int = 64, flush_writes: str = "tick",
                 start: bool = True, mount: bool = True,
                 admission: Union[AdmissionController, AdmissionPolicy,
                                  None] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if flush_writes not in ("tick", "defer"):
            raise ValueError(f"flush_writes {flush_writes!r} must be "
                             "'tick' or 'defer'")
        self.service = service
        self.tick_interval_s = float(tick_interval_s)
        self.max_batch = int(max_batch)
        self.flush_writes = flush_writes
        if admission is None or isinstance(admission, AdmissionPolicy):
            admission = AdmissionController(admission)
        self.admission = admission
        self._seq = 0
        self._cv = threading.Condition()
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        self._thread_ident: Optional[int] = None
        self.last_error: Optional[BaseException] = None
        self.counters = {"ticks": 0, "requests": 0, "retrieves": 0,
                         "retrieve_launches": 0, "write_flushes": 0,
                         "group_commits": 0, "max_tick_batch": 0}
        if mount:
            if getattr(service, "scheduler", None) is not None \
                    and not service.scheduler.closed:
                raise ValueError("service already has a scheduler mounted")
            service.scheduler = self
        self._mounted = mount
        if start:
            self.start()

    # -- submission ---------------------------------------------------------
    def submit(self, request: MemoryRequest,
               tenant: Optional[str] = None) -> Future:
        """Queue one typed request; resolves to a MemoryResponse at the end
        of the tick that executes it.  Thread-safe.  Raises AdmissionError
        when the tenant is over its rate limit or shed under load."""
        return self.submit_many([request], tenant=tenant)[0]

    def submit_many(self, requests: Sequence[MemoryRequest],
                    tenant: Optional[str] = None,
                    traces: Optional[Sequence] = None) -> List[Future]:
        """Queue several requests as one adjacent block (they share a tick
        and, for retrieves, one device launch — plus whatever other clients
        queued around them).  `tenant` pins the whole block to one QoS
        identity (the HTTP frontend passes its api-key tenant); without it
        each request's namespace prefix is the tenant.  Admission is
        all-or-nothing: a rejected block (AdmissionError) queues nothing.
        `traces` (parallel to `requests`, entries may be None) carries each
        request's edge Trace so the tick that executes it records its queue
        wait, the tick itself, and every plan stage into that tree."""
        for r in requests:
            if not isinstance(r, _REQUEST_TYPES):
                raise TypeError(
                    f"submit() takes typed requests "
                    f"({', '.join(t.__name__ for t in _REQUEST_TYPES)}), "
                    f"got {type(r).__name__}")
        tenants = [tenant if tenant is not None else tenant_of(r)
                   for r in requests]
        tr = list(traces) if traces is not None else [None] * len(tenants)
        counts: dict = {}
        for t in tenants:
            counts[t] = counts.get(t, 0) + 1
        now = time.monotonic()
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            try:
                self.admission.admit_batch(list(counts.items()))
            except AdmissionError as e:
                tel = get_telemetry()
                tel.inc("memori_admission_rejections",
                        help="request blocks rejected by admission control "
                             "(rate limit or load shed)")
                tel.event("admission_reject", tenants=sorted(counts),
                          requests=len(requests), error=str(e))
                raise
            pend = []
            for r, t, trc in zip(requests, tenants, tr):
                self._seq += 1
                pend.append(_Pending(r, Future(), now, t, seq=self._seq,
                                     trace=trc))
            for p in pend:
                self.admission.push(p.tenant, p)
            self._cv.notify_all()
        return [p.future for p in pend]

    def set_admission_policy(self, policy: AdmissionPolicy) -> None:
        """Swap the mounted admission policy without a restart (the
        frontend's authenticated reload endpoint lands here).  Queued
        requests are untouched; the next submit/select sees the new
        limits.  Thread-safe: swaps under the same lock submit holds."""
        with self._cv:
            self.admission.set_policy(policy)

    def can_submit(self) -> bool:
        """True when the sync service wrappers should route through this
        scheduler: it is accepting work, someone will run ticks, and the
        caller is not the scheduler thread itself (the tick body calls the
        service's engine directly — re-submitting would deadlock)."""
        return (not self._closed and self.running
                and threading.get_ident() != self._thread_ident)

    # -- tick body ----------------------------------------------------------
    def run_tick_once(self) -> dict:
        """Drain everything currently queued (up to max_batch) and execute
        it as one tick.  Public so tests and hosts without the daemon can
        drive the exact tick policy deterministically."""
        with self._cv:
            batch = self._drain_locked()
        return self._run_tick(batch)

    def _drain_locked(self) -> List[_Pending]:
        # admission decides WHICH requests enter an oversubscribed tick
        # (priority, WRR, fair share); within the tick, execution returns
        # to global submission order — every future in a tick resolves at
        # the same tick end, so intra-tick order buys no fairness, but it
        # does decide cross-tenant side-effect semantics (an evict
        # submitted before a compact must land before it) and keeps
        # consecutive retrieves sharing one launch exactly as before
        batch = self.admission.select(self.max_batch)
        batch.sort(key=lambda p: p.seq)
        return batch

    @staticmethod
    def _resolve(future: Future, resp: MemoryResponse) -> None:
        """Resolve a future, tolerating one already resolved (close() may
        have error-resolved a stranded request a wedged daemon later got
        around to)."""
        try:
            future.set_result(resp)
        except InvalidStateError:
            pass

    def _run_tick(self, batch: List[_Pending]) -> dict:
        if not batch:
            return {"requests": 0, "retrieve_launches": 0}
        svc = self.service
        tel = get_telemetry()
        t_tick = time.monotonic()
        # attach each request's queue wait to its trace: t_submit/t_tick are
        # monotonic, spans are perf_counter — back-compute the span start
        # from "now" so the clock bases never mix inside one tree
        batch_traces = [p.trace for p in batch if p.trace is not None]
        if batch_traces:
            now_perf = time.perf_counter()
            for p in batch:
                if p.trace is not None and not p.trace.finished:
                    queued = max(0.0, t_tick - p.t_submit)
                    p.trace.add_completed("queued", queued,
                                          t0=now_perf - queued)
        resolutions: List[tuple] = []          # (future, MemoryResponse)
        records: List[_Pending] = []
        launches = 0
        retrieves = 0

        def done(p: _Pending, resp: MemoryResponse) -> None:
            resp.queued_s = t_tick - p.t_submit
            resolutions.append((p.future, resp))

        def fail(p: _Pending, op: str, exc: BaseException) -> None:
            done(p, MemoryResponse(payload=None, op=op, status="error",
                                   error=repr(exc), exception=exc))

        # a durable ALL-write tick (the multi-writer drain: several record/
        # evict/compact requests, no retrieves) commits its WAL records as
        # ONE fsync'd segment.  Mixed ticks fall back to per-op appends:
        # group_commit holds the runtime lock for the whole block, and a
        # retrieve's embed call belongs OUTSIDE that lock (it must never
        # stall the flusher or blocked enqueuers).
        writes = sum(1 for p in batch
                     if not isinstance(p.req, RetrieveRequest))
        rt = getattr(svc, "runtime", None)
        group = (rt.group_commit() if rt is not None and rt.wal is not None
                 and writes > 1 and writes == len(batch)
                 else contextlib.nullcontext())
        grouped = not isinstance(group, contextlib.nullcontext)
        ginfo = None
        # the tick span closes (stack.close below) BEFORE any future
        # resolves, so a handler thread never serializes a trace this
        # thread is still writing
        stack = contextlib.ExitStack()
        if batch_traces:
            stack.enter_context(tel.activate(batch_traces))
            stack.enter_context(tel.span("scheduler.tick",
                                         batch_size=len(batch),
                                         grouped=grouped))
        try:
            with group as ginfo:
                i = 0
                while i < len(batch):
                    p = batch[i]
                    if isinstance(p.req, RetrieveRequest):
                        run = [p]
                        while i + len(run) < len(batch) and isinstance(
                                batch[i + len(run)].req, RetrieveRequest):
                            run.append(batch[i + len(run)])
                        t0 = time.monotonic()
                        try:
                            # the run's traces (a subset of the batch)
                            # receive the plan-stage spans execute records
                            with tel.activate([q.trace for q in run]):
                                payloads = svc.execute([q.req for q in run])
                        except BaseException as e:
                            for q in run:
                                fail(q, "retrieve", e)
                        else:
                            dt = time.monotonic() - t0
                            launches += 1
                            retrieves += len(run)
                            for q, pay in zip(run, payloads):
                                done(q, MemoryResponse(
                                    payload=pay, op="retrieve",
                                    service_s=dt, batch_size=len(run),
                                    token_count=getattr(pay, "token_count",
                                                        None),
                                    degraded=getattr(pay, "degraded",
                                                     False)))
                        i += len(run)
                        continue
                    t0 = time.monotonic()
                    try:
                        # write-class ops record only into their own trace
                        # (the batch-wide set would smear one tenant's
                        # evict into every tree in the tick)
                        with tel.activate([p.trace]):
                            if isinstance(p.req, RecordRequest):
                                with tel.span("record.enqueue"):
                                    self._enqueue_record(p.req)
                                records.append(p)
                            elif isinstance(p.req, EvictRequest):
                                with tel.span("evict"):
                                    n = (svc.evict_superseded(
                                             p.req.namespace)
                                         if p.req.superseded_only
                                         else svc.evict(p.req.namespace))
                                done(p, MemoryResponse(
                                    payload=n, op="evict",
                                    service_s=time.monotonic() - t0))
                            elif isinstance(p.req, CompactRequest):
                                with tel.span("compact"):
                                    payload = svc.compact()
                                done(p, MemoryResponse(
                                    payload=payload, op="compact",
                                    service_s=time.monotonic() - t0))
                    except BaseException as e:
                        fail(p, type(p.req).__name__, e)
                    i += 1
                if records:
                    self._finish_records(records, done, fail)
        except BaseException as e:
            # the group commit itself failed: every write-class future in
            # this tick resolves to an error — nothing is acknowledged as
            # durable that is not on disk (retrieve responses stand; reads
            # promise no durability)
            self.last_error = e
            resolutions = [(f, r) for f, r in resolutions
                           if r.op == "retrieve"]
            resolved = {id(f) for f, _ in resolutions}
            for p in batch:
                if id(p.future) not in resolved:
                    fail(p, "group", e)
        finally:
            stack.close()
        # futures resolve only after the (possibly grouped) WAL writes are
        # durable — a client never observes an ack for a lost write
        for fut, resp in resolutions:
            self._resolve(fut, resp)
        # counters mutate under the condition lock: stats() snapshots under
        # the same lock, so /v1/stats never reports a torn view of a tick
        with self._cv:
            c = self.counters
            if grouped and ginfo is not None and ginfo["appended"]:
                # count group segments actually written (not grouping
                # attempts: a failed append or a fail-stopped sink writes
                # nothing)
                c["group_commits"] += 1
            c["ticks"] += 1
            c["requests"] += len(batch)
            c["retrieves"] += retrieves
            c["retrieve_launches"] += launches
            c["max_tick_batch"] = max(c["max_tick_batch"], len(batch))
        return {"requests": len(batch), "retrieve_launches": launches}

    def _enqueue_record(self, req: RecordRequest) -> None:
        """Writes go through the existing runtime queue: same bounded-queue
        backpressure, same WAL ordering as a direct caller.  `"reject"`
        backpressure raises exactly as it would for a direct caller (the
        future carries the BackpressureError).  In `"block"` mode a full
        queue is drained here rather than waited on — the tick thread is
        itself the consumer, and a Condition.wait under the reentrant
        group lock could not release it."""
        svc = self.service
        rt = getattr(svc, "runtime", None)
        if rt is not None and rt.policy.max_pending is not None \
                and rt.policy.backpressure == "block":
            # drain-and-enqueue under ONE hold of the runtime lock: a
            # direct writer cannot refill the queue between the flush and
            # the enqueue, so the enqueue below can never reach the
            # Condition.wait
            with rt.lock:
                if svc.store.pending_count >= rt.policy.max_pending:
                    svc.store.flush()
                svc.enqueue(req.namespace, req.session_id,
                            list(req.messages),
                            conversation_id=req.conversation_id)
            return
        svc.enqueue(req.namespace, req.session_id, list(req.messages),
                    conversation_id=req.conversation_id)

    def _finish_records(self, records, done, fail) -> None:
        durable = getattr(self.service, "runtime", None) is not None and \
            self.service.runtime.wal is not None
        if self.flush_writes == "defer":
            for p in records:
                done(p, MemoryResponse(
                    payload={"queued": True, "durable": False},
                    op="record"))
            return
        tel = get_telemetry()
        t0 = time.monotonic()
        try:
            # one batched flush for every session this tick accepted (plus
            # anything else pending): one embed call, one bank append, one
            # WAL record.  Through the store under the runtime guard so the
            # commit hook still stamps flush times / wakes blocked
            # enqueuers.
            with tel.activate([p.trace for p in records]):
                with self.service._guard():
                    self.service.store.flush()
        except BaseException as e:
            for p in records:
                fail(p, "record", e)
            return
        with self._cv:
            self.counters["write_flushes"] += 1
        dt = time.monotonic() - t0
        tel.observe(RECORD_LATENCY, dt, n=len(records),
                    help="synchronous record (enqueue + flush) latency")
        for p in records:
            done(p, MemoryResponse(
                payload={"queued": True, "flushed": True,
                         "durable": durable},
                op="record", service_s=dt, batch_size=len(records)))

    # -- daemon -------------------------------------------------------------
    def _loop(self) -> None:
        self._thread_ident = threading.get_ident()
        while True:
            with self._cv:
                while not self.admission.total_queued and not self._closed:
                    self._cv.wait()
                if self._closed and not self.admission.total_queued:
                    return
                # bounded micro-batch window: wait out the tick interval
                # from the first arrival (letting concurrent clients join
                # this tick), closing early once the batch is full
                deadline = time.monotonic() + self.tick_interval_s
                while (self.admission.total_queued < self.max_batch
                       and not self._closed):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(timeout=remaining)
                batch = self._drain_locked()
            try:
                self._run_tick(batch)
            except BaseException as e:       # pragma: no cover - last resort
                self.last_error = e
                for p in batch:
                    if not p.future.done():
                        self._resolve(p.future, MemoryResponse(
                            payload=None, op="tick", status="error",
                            error=repr(e), exception=e))

    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._thread = threading.Thread(target=self._loop,
                                        name="memori-scheduler", daemon=True)
        self._thread.start()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self, timeout: float = 10.0) -> None:
        """Stop accepting work, drain everything still queued (no future is
        left hanging), unmount from the service.  Idempotent.

        If the daemon is wedged mid-tick past the join `timeout` (a stuck
        embedder, a dead device), the queued requests whose tick will never
        run are NOT left hanging their callers forever: each resolves to an
        error envelope (`status="error"`, timeout).  Only the requests the
        wedged tick already drained stay with it — if it ever finishes,
        their futures resolve normally (and its late set_result on anything
        we error-resolved is ignored)."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None \
                and self._thread is not threading.current_thread():
            self._thread.join(timeout=timeout)
        # drain only once the daemon has actually stopped: running ticks
        # from two threads at once would race the store.
        if self._thread is None or not self._thread.is_alive() \
                or self._thread is threading.current_thread():
            while True:
                with self._cv:
                    batch = self._drain_locked()
                if not batch:
                    break
                self._run_tick(batch)
        else:
            # wedged daemon: running its queue from this thread would race
            # the store, and leaving it queued would strand every caller
            # blocked on .result() — resolve to error envelopes instead
            with self._cv:
                stranded = self.admission.drain_all()
            for p in stranded:
                self._resolve(p.future, MemoryResponse(
                    payload=None, op=_OP_NAMES[type(p.req)], status="error",
                    error=f"scheduler close() timed out after {timeout}s "
                          "with the tick daemon wedged; this queued "
                          "request's tick never ran"))
        if self._mounted and getattr(self.service, "scheduler", None) is self:
            self.service.scheduler = None

    def __enter__(self) -> "MemoryScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        # counters snapshot under the same lock their writers hold, so a
        # concurrent tick can never be observed half-applied
        with self._cv:
            st = dict(self.counters,
                      queue_depth=self.admission.total_queued,
                      admission=self.admission.stats())
        st["running"] = self.running
        if st["retrieve_launches"]:
            st["avg_retrieves_per_launch"] = (st["retrieves"]
                                              / st["retrieve_launches"])
        return st
