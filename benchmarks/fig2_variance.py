"""Paper Figure 2 analogue: Memori accuracy mean ± std over n=3 runs
(three disjoint seed groups) per reasoning category."""
from __future__ import annotations

import statistics
import time

from benchmarks.common import evaluate
from repro.data.locomo_synth import CATEGORIES


def run(csv_rows):
    print("\n# Figure 2 — Memori accuracy mean ± std (n=3 runs)")
    t0 = time.time()
    runs = [evaluate("memori", seeds=(3 * i, 3 * i + 1)) for i in range(3)]
    us = (time.time() - t0) * 1e6 / 3
    for c in CATEGORIES:
        vals = [100 * r.per_category[c] for r in runs]
        mean = statistics.mean(vals)
        std = statistics.stdev(vals) if len(vals) > 1 else 0.0
        print(f"{c:14s} {mean:6.2f}% ± {std:5.2f}")
    overall = [100 * r.overall for r in runs]
    print(f"{'overall':14s} {statistics.mean(overall):6.2f}% ± "
          f"{statistics.stdev(overall):5.2f}")
    csv_rows.append(("fig2/overall_mean", us, f"{statistics.mean(overall):.2f}"))
    return csv_rows


if __name__ == "__main__":
    run([])
