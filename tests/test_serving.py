"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model_api import Model
from repro.serving.engine import Engine
from repro.serving.requests import Request
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import ContinuousBatcher

KEY = jax.random.PRNGKey(0)


def _engine(slots=3, max_len=48):
    cfg = get_config("memori-agent").reduced(layers=2, d_model=64)
    model = Model(cfg)
    params = model.init_params(KEY)
    return Engine(model, params, max_len=max_len, slots=slots), model, params, cfg


def test_all_requests_finish():
    eng, *_ = _engine()
    reqs = [Request(eng.tokenizer.encode(f"prompt number {i}"),
                    max_new_tokens=5) for i in range(8)]
    out = ContinuousBatcher(eng).run(reqs)
    assert len(out) == 8
    assert all(len(out[r.request_id].tokens) <= 5 for r in reqs)


def test_batched_decode_matches_sequential():
    """Greedy decode of the same prompt must be identical whether the slot
    shares the batch with other requests or runs alone."""
    eng, model, params, cfg = _engine(slots=3)
    prompt = eng.tokenizer.encode("the quick brown fox jumps")

    solo_eng, *_ = _engine(slots=1)
    solo = ContinuousBatcher(solo_eng).run(
        [Request(list(prompt), max_new_tokens=6)])
    solo_tokens = list(solo.values())[0].tokens

    reqs = [Request(eng.tokenizer.encode("completely different words here"),
                    max_new_tokens=6),
            Request(list(prompt), max_new_tokens=6),
            Request(eng.tokenizer.encode("yet another unrelated prompt"),
                    max_new_tokens=6)]
    out = ContinuousBatcher(eng).run(reqs)
    assert out[reqs[1].request_id].tokens == solo_tokens


def test_slot_reuse_after_finish():
    eng, *_ = _engine(slots=2)
    b = ContinuousBatcher(eng)
    reqs = [Request(eng.tokenizer.encode(f"req {i}"), max_new_tokens=3)
            for i in range(5)]
    out = b.run(reqs)
    assert len(out) == 5
    assert eng.stats["admitted"] == 5
    assert not eng.slot_active.any()


def test_sampler_greedy_and_topk():
    logits = jnp.asarray([[0.1, 2.0, -1.0, 0.5]])
    assert int(np.asarray(sample(logits, KEY, SamplerConfig()))[0]) == 1
    s = int(np.asarray(sample(logits, KEY,
                              SamplerConfig(temperature=1.0, top_k=2)))[0])
    assert s in (1, 3)   # top-2 = {1, 3}
