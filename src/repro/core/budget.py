"""Token budgeter: assembles the retrieved context under a hard token budget
(the paper's operating point: ~1,294 tokens/query ≈ 5% of full context).

Greedy by fused retrieval score; each triple pulls in its linked session
summary once (triples are never divorced from their context, paper §2.1);
anything that would overflow the budget is skipped.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.summaries import Summary, SummaryStore
from repro.core.triples import Triple
from repro.data.tokenizer import HashTokenizer, default_tokenizer


@dataclasses.dataclass
class BudgetedContext:
    triples: List[Triple]
    summaries: List[Summary]
    token_count: int


class TokenBudgeter:
    def __init__(self, budget: int = 1300,
                 tokenizer: HashTokenizer | None = None,
                 include_summaries: bool = True):
        self.budget = budget
        self.tokenizer = tokenizer or default_tokenizer()
        self.include_summaries = include_summaries

    def select(self, scored_triples: Sequence[Tuple[Triple, float]],
               summaries: SummaryStore) -> BudgetedContext:
        used = 0
        out_triples: List[Triple] = []
        out_summaries: List[Summary] = []
        seen_sessions = set()
        for triple, _score in scored_triples:
            cost = self.tokenizer.count(triple.render())
            extra = None
            skey = (triple.conversation_id, triple.session_id)
            if self.include_summaries and skey not in seen_sessions:
                extra = summaries.get(*skey)
                if extra is not None:
                    cost += self.tokenizer.count(extra.render())
            if used + cost > self.budget:
                continue
            used += cost
            out_triples.append(triple)
            if extra is not None:
                seen_sessions.add(skey)
                out_summaries.append(extra)
        return BudgetedContext(out_triples, out_summaries, used)
