"""Lifecycle runtime (core/lifecycle.py + checkpoint/wal.py): WAL-based
incremental persistence (recovery = snapshot + ordered replay, bit-identical
retrieval up to the last durable flush — including a kill -9 subprocess
crash test), the background flusher with bounded-queue backpressure,
policy-driven auto-compaction and snapshot rotation, and the preserved
zero-recompile / zero-upload steady state of the device-resident engine
across flush, compaction and rotation."""
import hashlib
import json
import os
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.checkpoint.wal import (CorruptSegmentError, WriteAheadLog,
                                  atomic_write_bytes)
from repro.common.utils import count_compiles
from repro.core import (BackpressureError, LifecyclePolicy, LifecycleRuntime,
                        MemoryService, MemoryStore, Message)
from repro.core import vector_index as vi_mod
from repro.core.embedder import HashEmbedder


def _session(texts, speaker="Caroline", ts=1700000000.0):
    return [Message(speaker, t, ts) for t in texts]


def _store(emb=None):
    return MemoryStore(emb or HashEmbedder(), use_kernel=False)


def _mounted(tmp_path, policy=None, start=False, emb=None):
    """(service, runtime) on a durable dir, daemon off unless asked."""
    store = _store(emb)
    rt = LifecycleRuntime(store, data_dir=str(tmp_path / "data"),
                          policy=policy, start=start)
    return MemoryService(runtime=rt, use_kernel=False, budget=800), rt


class CountingEmbedder(HashEmbedder):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.calls = 0

    def embed_texts(self, texts):
        self.calls += 1
        return super().embed_texts(texts)


# -- WAL mechanics -------------------------------------------------------------

def test_wal_append_is_atomic_self_describing_and_ordered(tmp_path):
    wal = WriteAheadLog(str(tmp_path))
    assert wal.append({"op": "a"}) == 1
    assert wal.append({"op": "b"}) == 2
    # stray tmp files (a crash mid-append) are invisible to the scan
    with open(os.path.join(str(tmp_path), "wal-00000099.msgpack.tmp"),
              "wb") as f:
        f.write(b"torn")
    assert wal.segment_seqs() == [1, 2]
    assert [rec["op"] for _, rec in wal.replay_records()] == ["a", "b"]
    assert [rec["op"] for _, rec in wal.replay_records(after_seq=1)] == ["b"]
    # a reopened log continues the seq numbering
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.append({"op": "c"}) == 3


def test_wal_group_append_one_file_consecutive_seqs(tmp_path):
    wal = WriteAheadLog(str(tmp_path))
    wal.append({"op": "a"})                                  # seq 1
    first, last = wal.append_group([{"op": "b"}, {"op": "c"}, {"op": "d"}])
    assert (first, last) == (2, 4)
    assert wal.segment_seqs() == [1, 2], "a group is ONE segment file"
    assert [(s, r["op"]) for s, r in wal.replay_records()] == \
        [(1, "a"), (2, "b"), (3, "c"), (4, "d")]
    # a snapshot boundary inside the seq numbering replays only the tail
    assert [r["op"] for s, r in wal.replay_records(after_seq=3)] == ["d"]
    assert wal.read_records(2) == [{"op": "b"}, {"op": "c"}, {"op": "d"}]
    with pytest.raises(CorruptSegmentError, match="group"):
        wal.read_segment(2)              # the single-record reader refuses
    # a reopened log continues numbering past the whole group run
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.append({"op": "e"}) == 5
    # a 1-record group degenerates to a classic segment
    assert wal2.append_group([{"op": "f"}]) == (6, 6)
    assert wal2.read_segment(6) == {"op": "f"}


def test_wal_replay_skips_covered_segments_without_reading(
        tmp_path, monkeypatch):
    """Segments fully covered by the snapshot are skipped by NAME — no
    read, no checksum (recovery I/O scales with the uncovered tail, not
    the retained log) — and a corrupt covered segment cannot stop replay."""
    wal = WriteAheadLog(str(tmp_path))
    for op in ("a", "b", "c"):
        wal.append({"op": op})
    with open(os.path.join(str(tmp_path), "wal-00000001.msgpack"),
              "wb") as f:
        f.write(b"garbage")              # covered AND corrupt
    reads = []
    real = wal.read_records

    def spy(seq):
        reads.append(seq)
        return real(seq)

    monkeypatch.setattr(wal, "read_records", spy)
    assert [r["op"] for _, r in wal.replay_records(after_seq=2)] == ["c"]
    assert reads == [3], f"covered segments were read: {reads}"


def test_wal_torn_group_segment_replays_all_or_nothing(tmp_path):
    """A corrupt/torn group segment must contribute NOTHING: recovery may
    never apply a prefix of a group (its records were acknowledged as one
    durability unit)."""
    wal = WriteAheadLog(str(tmp_path))
    wal.append({"op": "a"})
    wal.append_group([{"op": "b"}, {"op": "c"}])             # seqs 2-3
    wal.append({"op": "late"})                               # seq 4
    path = os.path.join(str(tmp_path), "wal-00000002.msgpack")
    with open(path, "rb") as f:
        blob = f.read()
    with open(path, "wb") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.warns(UserWarning, match="replay stopped"):
        ops = [r["op"] for _, r in wal.replay_records()]
    assert ops == ["a"], \
        "nothing from (or past) a torn group segment may be applied"
    # a corrupt group whose NAME looks covered but whose tail may straddle
    # past the snapshot coverage must also stop replay — applying seq 4 on
    # top of the unreadable (possibly-lost) seq 3 would build on a hole
    with pytest.warns(UserWarning, match="replay stopped"):
        got = [r["op"] for _, r in wal.replay_records(after_seq=2)]
    assert got == []


def test_wal_replay_stops_at_corruption(tmp_path):
    wal = WriteAheadLog(str(tmp_path))
    for op in ("a", "b", "c"):
        wal.append({"op": op})
    with open(os.path.join(str(tmp_path), "wal-00000002.msgpack"), "wb") as f:
        f.write(b"\x00garbage")
    with pytest.raises(CorruptSegmentError):
        wal.read_segment(2)
    with pytest.warns(UserWarning, match="replay stopped"):
        ops = [rec["op"] for _, rec in wal.replay_records()]
    assert ops == ["a"], "nothing past a corrupt segment may be applied"


def test_wal_rotation_truncates_only_fully_covered_segments(tmp_path):
    wal = WriteAheadLog(str(tmp_path))
    for i in range(3):
        wal.append({"op": f"r{i}"})
    atomic_write_bytes(wal.snapshot_path(3), b"snap3")
    wal.commit_snapshot(3, retain=2)
    assert wal.segment_seqs() == []
    for i in range(2):
        wal.append({"op": f"s{i}"})          # seqs 4, 5
    atomic_write_bytes(wal.snapshot_path(5), b"snap5")
    info = wal.commit_snapshot(5, retain=2)
    # both generations retained -> segments 4 and 5 must SURVIVE: the older
    # snapshot-3 generation still needs them to reach snapshot-5's state
    assert info["retained_snapshots"] == 2
    assert wal.segment_seqs() == [4, 5]
    wal.append({"op": "t0"})                 # seq 6
    atomic_write_bytes(wal.snapshot_path(6), b"snap6")
    info = wal.commit_snapshot(6, retain=2)
    # snapshot-3 aged out; oldest retained is snapshot-5 -> 4,5 truncate
    assert info["dropped_snapshots"] == 1
    assert sorted(s for s, _ in wal.snapshots()) == [5, 6]
    assert wal.segment_seqs() == [6]
    m = wal.read_manifest()
    assert [s["wal_through"] for s in m["snapshots"]] == [5, 6]


# -- incremental persistence: recovery == live store ---------------------------

QUERIES = [("alice/c0", "Which city does the user live in?"),
           ("bob/c0", "What pet was adopted?"),
           ("alice/c0", "What is the user's job?"),
           ("ghost/c0", "anything?")]


def _contexts_equal(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.text == w.text
        assert [t.text() for t in g.triples] == [t.text() for t in w.triples]
        assert g.token_count == w.token_count


def test_pure_wal_replay_is_bit_identical(tmp_path):
    svc, rt = _mounted(tmp_path)
    svc.record("alice/c0", "s0", _session(
        ["I live in Tallinn.", "I work as a botanist."], speaker="Alice"))
    svc.record("bob/c0", "s0", _session(
        ["I adopted a parrot named Olive."], speaker="Bob"))
    svc.record("alice/c0", "s1", _session(["I work as a welder."],
                                          speaker="Alice",
                                          ts=1700000100.0))
    svc.evict_superseded("alice/c0")
    svc.record("carol/c0", "s0", _session(["I collect stamps."],
                                          speaker="Carol"))
    svc.evict("carol/c0")
    svc.compact()
    want = svc.retrieve_batch(QUERIES)
    # no snapshot was ever written: recovery is ordered WAL replay alone
    restored = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                                     use_kernel=False, budget=800)
    _contexts_equal(restored.retrieve_batch(QUERIES), want)
    np.testing.assert_array_equal(restored.vindex.bank, svc.vindex.bank)
    np.testing.assert_array_equal(restored.vindex.alive(), svc.vindex.alive())
    assert restored.store.stats() == svc.store.stats()


def test_snapshot_plus_wal_tail_recovery(tmp_path):
    svc, rt = _mounted(tmp_path)
    svc.record("alice/c0", "s0", _session(["I live in Tallinn."],
                                          speaker="Alice"))
    rt.rotate()
    segs_after_rotate = svc.stats()["wal_segments"]
    svc.record("bob/c0", "s0", _session(
        ["I adopted a parrot named Olive."], speaker="Bob"))
    svc.record("alice/c0", "s1", _session(["I work as a welder."],
                                          speaker="Alice"))
    assert svc.stats()["wal_segments"] == segs_after_rotate + 2
    want = svc.retrieve_batch(QUERIES)
    restored = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                                     use_kernel=False, budget=800)
    _contexts_equal(restored.retrieve_batch(QUERIES), want)
    np.testing.assert_array_equal(restored.vindex.bank, svc.vindex.bank)


def test_corrupt_newest_snapshot_falls_back_a_generation(tmp_path):
    policy = LifecyclePolicy(snapshot_retain=2)
    svc, rt = _mounted(tmp_path, policy=policy)
    svc.record("alice/c0", "s0", _session(["I live in Tallinn."],
                                          speaker="Alice"))
    rt.rotate()
    svc.record("bob/c0", "s0", _session(["I adopted a parrot named Olive."],
                                        speaker="Bob"))
    rt.rotate()
    want = svc.retrieve_batch(QUERIES)
    newest = rt.wal.latest_snapshot()
    assert newest is not None
    with open(newest[1], "wb") as f:
        f.write(b"not a snapshot")
    with pytest.warns(UserWarning, match="unrestorable"):
        restored = MemoryService.recover(str(tmp_path / "data"),
                                         HashEmbedder(), use_kernel=False,
                                         budget=800)
    # older generation + the WAL tail it still covers == full state
    _contexts_equal(restored.retrieve_batch(QUERIES), want)


def test_recover_quarantines_unreplayable_tail_so_new_writes_survive(
        tmp_path):
    """A corrupt tail stops replay — but it must not keep shadowing the
    seq space: recovery quarantines the dead files and re-baselines, so
    records appended AFTER the remount survive the NEXT recovery (instead
    of being silently dropped behind the corrupt file forever)."""
    svc, rt = _mounted(tmp_path)
    svc.record("a/c0", "s0", _session(["I live in Tallinn."],
                                      speaker="A"))
    svc.record("b/c0", "s0", _session(["I live in Porto."], speaker="B"))
    last = rt.wal.segment_seqs()[-1]
    with open(os.path.join(rt.wal.dir, f"wal-{last:08d}.msgpack"),
              "wb") as f:
        f.write(b"garbage")              # media-corrupt the newest segment
    with pytest.warns(UserWarning) as rec:   # "replay stopped" + quarantine
        r1 = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                                   use_kernel=False, budget=800)
    assert any("quarantined" in str(w.message) for w in rec)
    q = "Which city does the user live in?"
    assert r1.retrieve("a/c0", q).triples, "prefix before the tear survives"
    assert not r1.retrieve("b/c0", q).triples, "torn tail is lost"
    # remounted service accepts new durable writes...
    r1.record("c/c0", "s0", _session(["I live in Quito."], speaker="C"))
    r1.close(final_snapshot=False)
    # ...and a SECOND recovery still sees them
    r2 = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                               use_kernel=False, budget=800)
    assert any(t.object == "quito" for t in r2.retrieve("c/c0", q).triples)
    assert r1.retrieve("a/c0", q).text == r2.retrieve("a/c0", q).text


def test_mounting_wal_on_populated_store_writes_baseline(tmp_path):
    store = _store()
    store.ingest("alice/c0", "s0", _session(["I live in Tallinn."],
                                            speaker="Alice"))
    rt = LifecycleRuntime(store, data_dir=str(tmp_path / "data"), start=False)
    svc = MemoryService(runtime=rt, use_kernel=False, budget=800)
    want = svc.retrieve_batch(QUERIES)
    restored = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                                     use_kernel=False, budget=800)
    _contexts_equal(restored.retrieve_batch(QUERIES), want)


def test_remounting_fresh_store_on_durable_dir_is_refused(tmp_path):
    """A directory with durable state must be recover()ed — mounting a new
    store over it would shadow the old data and the next rotation would
    destroy it."""
    svc, rt = _mounted(tmp_path)
    svc.record("alice/c0", "s0", _session(["I live in Tallinn."],
                                          speaker="Alice"))
    with pytest.raises(ValueError, match="recover"):
        LifecycleRuntime(_store(), data_dir=str(tmp_path / "data"),
                         start=False)
    # recover() remains the sanctioned way back in
    restored = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                                     use_kernel=False, budget=800)
    assert restored.stats()["bank_rows"] == svc.stats()["bank_rows"]


def test_read_path_drain_wakes_blocked_enqueuer(tmp_path):
    """Every queue drain — not just runtime.flush() — must wake blocked
    enqueuers: here the drain happens via the service's read-your-writes
    path while an enqueue is waiting on queue space, with no daemon."""
    policy = LifecyclePolicy(max_pending=1, backpressure="block",
                             enqueue_timeout_s=10.0)
    svc, rt = _mounted(tmp_path, policy=policy)
    svc.enqueue("a/c0", "s0", _session(["I live in Oslo."]))
    unblocked = threading.Event()

    def blocked_writer():
        svc.enqueue("a/c0", "s1", _session(["I work as a chef."]))
        unblocked.set()

    t = threading.Thread(target=blocked_writer)
    t.start()
    time.sleep(0.1)                      # let it reach the wait
    assert not unblocked.is_set()
    svc.retrieve("a/c0", "anything?")    # read-your-writes drains the queue
    assert unblocked.wait(timeout=5.0), \
        "read-path flush did not wake the blocked enqueuer"
    t.join(timeout=5.0)


def test_close_is_idempotent_and_final_snapshot_recovers(tmp_path):
    svc, rt = _mounted(tmp_path)
    svc.enqueue("alice/c0", "s0", _session(["I live in Tallinn."],
                                           speaker="Alice"))
    svc.close()
    svc.close()
    restored = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                                     use_kernel=False, budget=800)
    ctx = restored.retrieve("alice/c0", "Which city does the user live in?")
    assert any(t.object == "tallinn" for t in ctx.triples)


# -- crash recovery: kill -9 between WAL append and snapshot -------------------

_CRASH_CHILD = r"""
import hashlib, json, os, sys, time
import numpy as np
from repro.core import MemoryService, Message
from repro.core.embedder import HashEmbedder

d = sys.argv[1]
svc = MemoryService(HashEmbedder(), use_kernel=False,
                    data_dir=os.path.join(d, "data"))
cities = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi"]
for i, city in enumerate(cities):
    ns = "u%d/c0" % i
    svc.enqueue(ns, "s0", [
        Message("U", "I live in %s." % city, 1700000000.0),
        Message("U", "I adopted a gecko named G%d." % i, 1700000000.0)])
    svc.flush()                     # durability point: WAL segment on disk
    if i == 1:
        svc.rotate()                # one mid-stream snapshot generation
    queries = [("u%d/c0" % j, "Which city does the user live in?")
               for j in range(i + 1)]
    texts = [c.text for c in svc.retrieve_batch(queries)]
    bank = np.ascontiguousarray(svc.vindex.bank)
    exp = {"n": i + 1, "texts": texts, "bank_rows": int(bank.shape[0]),
           "bank_sha": hashlib.sha256(bank.tobytes()).hexdigest()}
    tmp = os.path.join(d, "expected.json.tmp")
    with open(tmp, "w") as f:
        json.dump(exp, f); f.flush(); os.fsync(f.fileno())
    os.replace(tmp, os.path.join(d, "expected.json"))
    print("FLUSHED %d" % (i + 1), flush=True)
print("DONE", flush=True)
time.sleep(60)
"""


def test_kill9_recovery_bit_identical_up_to_last_durable_flush(tmp_path):
    """SIGKILL the writer mid-soak (after >= 4 durable flushes, past a
    snapshot rotation, while later flushes are in flight), then recover:
    per-namespace retrieval and the bank-row prefix must be bit-identical
    to what the writer observed after its last durable flush."""
    proc = subprocess.Popen(
        [sys.executable, "-c", _CRASH_CHILD, str(tmp_path)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env={"PATH": os.environ.get("PATH", ""), "PYTHONPATH": "src",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    deadline = time.time() + 180
    killed = False
    try:
        for line in iter(proc.stdout.readline, ""):
            if line.startswith("FLUSHED") and int(line.split()[1]) >= 4:
                proc.kill()          # SIGKILL: no atexit, no final snapshot
                killed = True
                break
            if time.time() > deadline:
                break
    finally:
        if not killed:
            proc.kill()
        proc.wait()
    assert killed, f"writer never reached 4 flushes: {proc.stderr.read()}"

    with open(str(tmp_path / "expected.json")) as f:
        exp = json.load(f)
    assert exp["n"] >= 4
    restored = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                                     use_kernel=False, budget=800)
    # everything marked durable before the kill is present and identical;
    # later namespaces can't perturb earlier ones (namespace isolation)
    queries = [(f"u{j}/c0", "Which city does the user live in?")
               for j in range(exp["n"])]
    got = [c.text for c in restored.retrieve_batch(queries)]
    assert got == exp["texts"]
    bank = np.ascontiguousarray(restored.vindex.bank[: exp["bank_rows"]])
    assert restored.vindex.n >= exp["bank_rows"]
    assert hashlib.sha256(bank.tobytes()).hexdigest() == exp["bank_sha"]


# -- background flusher + backpressure -----------------------------------------

def test_background_flusher_drains_on_interval(tmp_path):
    emb = CountingEmbedder()
    policy = LifecyclePolicy(flush_interval_s=0.03, tick_s=0.01)
    svc, rt = _mounted(tmp_path, policy=policy, start=True, emb=emb)
    try:
        for u in range(5):
            svc.enqueue(f"u{u}/c0", "s0",
                        _session(["I live in Lisbon."], speaker=f"U{u}"))
        assert emb.calls == 0, "enqueue must not embed"
        deadline = time.time() + 10
        while svc.stats()["pending_depth"] and time.time() < deadline:
            time.sleep(0.01)
        assert svc.stats()["pending_depth"] == 0, "flusher never drained"
        assert emb.calls == 1, "drain must be ONE batched embed call"
    finally:
        rt.close(final_snapshot=False)


def test_backpressure_reject(tmp_path):
    policy = LifecyclePolicy(max_pending=2, backpressure="reject")
    svc, rt = _mounted(tmp_path, policy=policy)
    svc.enqueue("a/c0", "s0", _session(["I live in Oslo."]))
    svc.enqueue("a/c0", "s1", _session(["I work as a chef."]))
    with pytest.raises(BackpressureError, match="full"):
        svc.enqueue("a/c0", "s2", _session(["I adopted a cat."]))
    svc.flush()
    svc.enqueue("a/c0", "s2", _session(["I adopted a cat."]))  # room again


def test_backpressure_block_times_out_without_flusher(tmp_path):
    policy = LifecyclePolicy(max_pending=1, backpressure="block",
                             enqueue_timeout_s=0.05)
    svc, rt = _mounted(tmp_path, policy=policy)
    svc.enqueue("a/c0", "s0", _session(["I live in Oslo."]))
    t0 = time.monotonic()
    with pytest.raises(BackpressureError, match="blocked"):
        svc.enqueue("a/c0", "s1", _session(["I work as a chef."]))
    assert time.monotonic() - t0 >= 0.04


def test_backpressure_block_unblocked_by_daemon(tmp_path):
    policy = LifecyclePolicy(max_pending=1, backpressure="block",
                             flush_interval_s=0.01, tick_s=0.005,
                             enqueue_timeout_s=10.0)
    svc, rt = _mounted(tmp_path, policy=policy, start=True)
    try:
        svc.enqueue("a/c0", "s0", _session(["I live in Oslo."]))
        # blocks until the daemon drains the queue, then succeeds
        svc.enqueue("a/c0", "s1", _session(["I work as a chef."]))
        assert svc.stats()["pending_depth"] <= 1
    finally:
        rt.close(final_snapshot=False)


def test_blocked_enqueues_from_threads_all_land(tmp_path):
    policy = LifecyclePolicy(max_pending=2, backpressure="block",
                             flush_interval_s=0.01, tick_s=0.005,
                             enqueue_timeout_s=30.0)
    svc, rt = _mounted(tmp_path, policy=policy, start=True)
    errs = []

    def writer(u):
        try:
            for s in range(4):
                svc.enqueue(f"w{u}/c0", f"s{s}",
                            _session([f"I live in City{s}."], speaker=f"W{u}"))
        except BaseException as e:   # pragma: no cover - failure path
            errs.append(e)

    try:
        threads = [threading.Thread(target=writer, args=(u,))
                   for u in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert not errs
        svc.flush()
        st = svc.stats()
        assert st["pending_depth"] == 0
        assert sum(v["triples"] for v in st["per_namespace"].values()) == 16
    finally:
        rt.close(final_snapshot=False)


# -- policy-driven maintenance -------------------------------------------------

def test_auto_compaction_waits_for_idle_window(tmp_path):
    policy = LifecyclePolicy(compact_tombstone_ratio=0.2,
                             compact_min_tombstones=1, compact_idle_s=30.0)
    svc, rt = _mounted(tmp_path, policy=policy)
    svc.record("a/c0", "s0", _session(["I live in Oslo.",
                                       "I work as a chef."]))
    svc.record("b/c0", "s0", _session(["I adopted a cat."]))
    svc.evict("b/c0")
    assert svc.stats()["tombstones"] == 1
    assert rt.run_maintenance_once()["compacted"] is False, \
        "must not compact inside the activity window"
    rt._last_activity -= 60.0        # fast-forward into the idle window
    assert rt.run_maintenance_once()["compacted"] is True
    st = svc.stats()
    assert st["tombstones"] == 0
    assert st["lifecycle"]["auto_compactions"] == 1
    ctx = svc.retrieve("a/c0", "What is the user's job?")
    assert any(t.object == "chef" for t in ctx.triples)


def test_periodic_rotation_retention(tmp_path):
    policy = LifecyclePolicy(snapshot_interval_s=0.0, snapshot_retain=2)
    svc, rt = _mounted(tmp_path, policy=policy)
    for i in range(4):
        svc.record(f"u{i}/c0", "s0", _session([f"I live in City{i}."]))
        rt.run_maintenance_once()    # interval 0: rotates every tick
    assert len(rt.wal.snapshots()) == 2, "retention must prune generations"
    assert svc.stats()["lifecycle"]["rotations"] >= 4
    assert svc.stats()["last_snapshot_age_s"] is not None
    restored = MemoryService.recover(str(tmp_path / "data"), HashEmbedder(),
                                     use_kernel=False, budget=800)
    want = svc.retrieve_batch([(f"u{i}/c0", "Which city?") for i in range(4)])
    _contexts_equal(restored.retrieve_batch(
        [(f"u{i}/c0", "Which city?") for i in range(4)]), want)


def test_snapshot_age_uses_recorded_birth_not_mtime(tmp_path):
    """Satellite regression: the mount path used to age the on-disk
    generation by its file mtime against time.time() — a restore tool or a
    clock step that rewrites/doctors mtimes then mis-dated the generation.
    The birth recorded in the manifest at commit time is authoritative."""
    svc, rt = _mounted(tmp_path)
    svc.record("a/c0", "s0", _session(["I live in Oslo."]))
    rt.rotate()
    births = rt.wal.snapshot_births()
    through, path = rt.wal.latest_snapshot()
    assert through in births
    assert abs(births[through] - time.time()) < 60
    rt.close()
    # doctor the file mtime a day into the future (what a naive copy or a
    # clock step produces); the recorded birth must win on remount
    os.utime(path, (time.time() + 86400, time.time() + 86400))
    store = MemoryStore.restore(path, HashEmbedder(), use_kernel=False)
    rt2 = LifecycleRuntime(store, data_dir=str(tmp_path / "data"),
                           start=False, _recovered=True)
    age = time.monotonic() - rt2._last_snapshot_mono
    assert 0.0 <= age < 60, \
        f"age {age}s must come from the recorded birth, not the mtime"
    rt2.close()


def test_snapshot_age_falls_back_to_clamped_mtime_for_legacy_manifest(
        tmp_path):
    svc, rt = _mounted(tmp_path)
    svc.record("a/c0", "s0", _session(["I live in Oslo."]))
    rt.rotate()
    through, path = rt.wal.latest_snapshot()
    # a manifest written before births were recorded: entries lack born_unix
    rt.wal.write_manifest(rt.wal.snapshots())
    assert rt.wal.snapshot_births() == {}
    rt.close()
    os.utime(path, (time.time() + 86400, time.time() + 86400))
    store = MemoryStore.restore(path, HashEmbedder(), use_kernel=False)
    rt2 = LifecycleRuntime(store, data_dir=str(tmp_path / "data"),
                           start=False, _recovered=True)
    # future mtime is clamped to "born now": age >= 0, never negative (a
    # negative age would suppress interval rotation for a whole day)
    age = time.monotonic() - rt2._last_snapshot_mono
    assert 0.0 <= age < 60
    rt2.close()


def test_rotation_preserves_prior_generation_births(tmp_path):
    policy = LifecyclePolicy(snapshot_retain=2)
    svc, rt = _mounted(tmp_path, policy=policy)
    svc.record("a/c0", "s0", _session(["I live in Oslo."]))
    rt.rotate()
    first_births = rt.wal.snapshot_births()
    svc.record("b/c0", "s0", _session(["I live in Porto."]))
    rt.rotate()
    births = rt.wal.snapshot_births()
    assert len(births) == 2
    for through, born in first_births.items():
        if through in births:        # retained generation keeps its birth
            assert births[through] == born
    rt.close()


def test_stats_runtime_fields_present_with_and_without_runtime(tmp_path):
    plain = MemoryService(HashEmbedder(), use_kernel=False)
    st = plain.stats()
    assert st["pending_depth"] == 0 and st["wal_segments"] == 0
    assert st["last_snapshot_age_s"] is None
    svc, rt = _mounted(tmp_path)
    svc.enqueue("a/c0", "s0", _session(["I live in Oslo."]))
    st = svc.stats()
    assert st["pending_depth"] == 1
    assert st["last_snapshot_age_s"] is None      # nothing rotated yet
    svc.flush()
    assert svc.stats()["wal_segments"] == 1
    rt.rotate()
    st = svc.stats()
    assert st["wal_segments"] == 0 and st["last_snapshot_age_s"] >= 0.0


# -- property: interleaved ops vs an always-in-memory oracle -------------------

# hypothesis isn't baked into every image; only the property test skips
# when it's absent (the rest of this module must still run)
try:
    from hypothesis import given, settings, strategies as st_
    _HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    _HYPOTHESIS = False

    def given(*a, **kw):                   # noqa: D103 - stub decorator
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*a, **kw):
        return lambda fn: fn

    class st_:                              # noqa: N801 - strategy stub
        @staticmethod
        def one_of(*a):
            return None

        @staticmethod
        def tuples(*a):
            return None

        @staticmethod
        def just(*a):
            return None

        @staticmethod
        def integers(*a):
            return None

        @staticmethod
        def lists(*a, **kw):
            return None


_OP = st_.one_of(
    st_.tuples(st_.just("enqueue"), st_.integers(0, 3), st_.integers(0, 5)),
    st_.just(("flush",)),
    st_.tuples(st_.just("evict"), st_.integers(0, 3)),
    st_.tuples(st_.just("evict_sup"), st_.integers(0, 3)),
    st_.just(("compact",)),
    st_.just(("rotate",)),
)


@given(st_.lists(_OP, min_size=1, max_size=16))
@settings(max_examples=10, deadline=None)
def test_interleaved_lifecycle_ops_match_in_memory_oracle(ops):
    """enqueue/flush/evict/evict_superseded/compact/rotate interleaved
    arbitrarily: the WAL-journaled service, an oracle service that never
    persists anything, and a recovery from the journal must all answer
    identically."""
    with tempfile.TemporaryDirectory() as d:
        store = MemoryStore(HashEmbedder(), use_kernel=False)
        rt = LifecycleRuntime(store, data_dir=os.path.join(d, "data"),
                              start=False)
        svc = MemoryService(runtime=rt, use_kernel=False, budget=800)
        oracle = MemoryService(HashEmbedder(), use_kernel=False, budget=800)
        sid = 0
        for op in ops:
            if op[0] == "enqueue":
                _, u, j = op
                msgs = _session([f"I live in City{j}.",
                                 f"I adopted a pet named P{j}."],
                                speaker=f"U{u}")
                svc.enqueue(f"u{u}/c0", f"s{sid}", msgs)
                oracle.enqueue(f"u{u}/c0", f"s{sid}", msgs)
                sid += 1
            elif op[0] == "flush":
                svc.flush()
                oracle.flush()
            elif op[0] == "evict":
                assert svc.evict(f"u{op[1]}/c0") == \
                    oracle.evict(f"u{op[1]}/c0")
            elif op[0] == "evict_sup":
                assert svc.evict_superseded(f"u{op[1]}/c0") == \
                    oracle.evict_superseded(f"u{op[1]}/c0")
            elif op[0] == "compact":
                svc.compact()
                oracle.compact()
            elif op[0] == "rotate":
                rt.rotate()          # rotate flushes; mirror in the oracle
                oracle.flush()
        svc.flush()
        oracle.flush()
        queries = [(f"u{u}/c0", q) for u in range(4)
                   for q in ("Which city does the user live in?",
                             "What pet was adopted?")]
        want = oracle.retrieve_batch(queries)
        _contexts_equal(svc.retrieve_batch(queries), want)
        restored = MemoryService.recover(os.path.join(d, "data"),
                                         HashEmbedder(), use_kernel=False,
                                         budget=800)
        _contexts_equal(restored.retrieve_batch(queries), want)


# -- steady state: the engine guarantees survive the runtime -------------------

def test_runtime_preserves_zero_recompiles_and_zero_bank_uploads(
        monkeypatch, tmp_path):
    """The PR-3 acceptance contract, extended to the lifecycle runtime:
    across full runtime cycles — enqueue -> background-path flush ->
    retrieve_batch -> evict -> auto-compact -> snapshot rotation — the
    steady state stays at zero recompiles, zero bank-sized host->device
    transfers AND zero BM25 doc-block transfers (both the dense bank and
    the sparse (capacity, L) doc block repack device-side in place)."""
    policy = LifecyclePolicy(compact_tombstone_ratio=0.01,
                             compact_min_tombstones=1, compact_idle_s=0.0)
    svc, rt = _mounted(tmp_path, policy=policy)
    queries = [("perm0/c0", "Which city does the user live in?"),
               ("perm1/c0", "Which city does the user live in?"),
               ("nobody/c0", "Which city does the user live in?")]
    cap, dim = svc.vindex.capacity, svc.vindex.dim
    bm_block = svc.bm25._docs.shape[0] * svc.bm25.max_doc_len * 4

    def cycle(i):
        svc.enqueue(f"perm{i}/c0", "s0",
                    _session(["I live in Oslo."], speaker="P"))
        svc.enqueue(f"tmp{i}/c0", "s0",
                    _session(["I live in Quito."], speaker="T"))
        rt.flush()                       # one 2-row append
        svc.retrieve_batch(queries)      # fixed Q bucket
        svc.evict(f"tmp{i}/c0")          # one tombstone
        assert rt.run_maintenance_once()["compacted"]   # device-side repack
        rt.rotate()                      # snapshot + truncation (host only)

    for i in range(3):                   # warm every executable in the loop
        cycle(i)
    uploads, bm_uploads = [], []
    # vi_mod.jnp IS jax.numpy, shared with the bm25 module — one spy
    # observes both the bank-sized and the doc-block-sized transfers
    real_asarray = vi_mod.jnp.asarray

    def spy_asarray(x, *a, **kw):
        nbytes = getattr(x, "nbytes", 0)
        if nbytes >= cap * dim * 4:
            uploads.append(np.shape(x))
        elif nbytes >= bm_block:
            bm_uploads.append(np.shape(x))
        return real_asarray(x, *a, **kw)

    monkeypatch.setattr(vi_mod.jnp, "asarray", spy_asarray)
    with count_compiles() as cc:
        for i in range(3, 8):
            cycle(i)
    assert cc.count == 0, f"runtime cycle recompiled: {cc.msgs[:5]}"
    assert uploads == [], f"bank-sized host->device transfers: {uploads}"
    assert bm_uploads == [], \
        f"BM25 doc-block host->device transfers: {bm_uploads}"
    assert svc.vindex.capacity == cap, "compaction must keep the capacity"
    # and the data is still right after all that churn
    ctx = svc.retrieve("perm0/c0", "Which city does the user live in?")
    assert any(t.object == "oslo" for t in ctx.triples)
