"""Sharded memory service end to end: shard-wise bank placement with
retrieval parity against the unsharded oracle, graceful degradation (a
downed shard answers empty with the `degraded` flag while survivors stay
bit-identical), the degraded flag through the scheduler and the HTTP
envelope, zero-recompile/zero-upload steady state on the sharded path, and
the kill-a-shard acceptance test: SIGKILL one shard owner mid-traffic,
lose its disk, recover bit-identically from the follower's shipped WAL
segments."""
import hashlib
import json
import os
import shutil
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

import repro.core.shards as shards_mod
from repro.checkpoint.replication import (DirectorySink,
                                          restore_missing_from_follower)
from repro.common.utils import count_compiles
from repro.core import MemoryService, Message, RetrieveRequest
from repro.core.embedder import HashEmbedder

CITIES = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi"]
QUERY = "Which city does the user live in?"
TS = 1700000000.0


def _svc(shards=1, **kw):
    return MemoryService(HashEmbedder(), use_kernel=False, budget=800,
                         shards=shards, **kw)


def _fill(svc, n=6):
    for i, city in enumerate(CITIES[:n]):
        svc.enqueue(f"u{i}/c0", "s0", [
            Message("U", f"I live in {city}.", TS),
            Message("U", f"I like {city} food.", TS)])
    svc.flush()
    return svc


def _queries(n=6):
    return [(f"u{i}/c0", QUERY) for i in range(n)]


def _raw_reqs(n=6):
    return [RetrieveRequest(f"u{i}/c0", QUERY,
                            stages=("dense", "sparse", "fuse"))
            for i in range(n)]


# -- placement + parity --------------------------------------------------------

def test_sharded_retrieval_parity_with_unsharded_oracle():
    base, sh = _fill(_svc()), _fill(_svc(shards=4))
    want = base.retrieve_batch(_queries())
    got = sh.retrieve_batch(_queries())
    assert [c.text for c in got] == [c.text for c in want]
    assert [c.token_count for c in got] == [c.token_count for c in want]
    # the fused ranking itself is identical, not just the rendered text.
    # Global row ids legitimately differ (sharded flushes place sessions
    # shard-major), so compare the tenant-local ranking and its scores.
    raw_want = base.execute(_raw_reqs())
    raw_got = sh.execute(_raw_reqs())
    assert [r.triple_ids for r in raw_got] == \
        [r.triple_ids for r in raw_want]
    for g, w in zip(raw_got, raw_want):
        assert g.scores == pytest.approx(w.scores, rel=1e-5)
    assert not any(r.degraded for r in raw_got)
    # placement: every live row landed in its namespace's shard
    stats = sh.store.sharded.stats()
    assert sum(stats["per_shard_rows"]) == sh.vindex.n
    for i in range(6):
        ns = f"u{i}/c0"
        tid = sh.store.tenant(ns).ns_id
        assert sh.store.shard_of_namespace(ns) == tid % 4


def test_degraded_batch_serves_survivors_bit_identically():
    svc = _fill(_svc(shards=4))
    base = [c.text for c in svc.retrieve_batch(_queries())]
    down = svc.store.shard_of_namespace("u0/c0")
    victims = [i for i in range(6)
               if svc.store.shard_of_namespace(f"u{i}/c0") == down]
    survivors = [i for i in range(6) if i not in victims]
    assert victims and survivors
    svc.set_shard_down(down)
    assert svc.store.down_shards() == [down]
    got = svc.retrieve_batch(_queries())
    raw = svc.execute(_raw_reqs())
    for i in victims:                  # empty by design, flagged, no error
        assert got[i].degraded and not got[i].triples
        assert raw[i].degraded and raw[i].row_ids == []
    for i in survivors:                # bit-identical to the healthy batch
        assert not got[i].degraded and got[i].text == base[i]
        assert not raw[i].degraded
    svc.set_shard_up(down)
    healed = svc.retrieve_batch(_queries())
    assert [c.text for c in healed] == base
    assert not any(c.degraded for c in healed)


def test_writes_accumulate_while_shard_down_and_surface_after_mark_up():
    svc = _fill(_svc(shards=4))
    down = svc.store.shard_of_namespace("u0/c0")
    svc.set_shard_down(down)
    svc.enqueue("u0/c0", "s1",
                [Message("U", "I adopted a gecko named Gex.", TS)])
    svc.flush()                        # host truth keeps absorbing writes
    assert svc.retrieve("u0/c0", "Any pets?").degraded
    svc.set_shard_up(down)
    ctx = svc.retrieve("u0/c0", "Any pets?")
    assert not ctx.degraded
    assert any(t.object == "gex" for t in ctx.triples)


def test_degraded_flag_flows_through_scheduler_responses():
    svc = _fill(_svc(shards=4))
    down = svc.store.shard_of_namespace("u0/c0")
    sched = svc.start_scheduler(tick_interval_s=0.002, max_batch=16)
    try:
        svc.set_shard_down(down)
        futs = [sched.submit(RetrieveRequest(f"u{i}/c0", QUERY))
                for i in range(6)]
        resps = [f.result(timeout=30) for f in futs]
        for i, r in enumerate(resps):
            assert r.ok, r.error
            is_victim = svc.store.shard_of_namespace(f"u{i}/c0") == down
            assert r.degraded == is_victim
            assert r.payload.degraded == is_victim
    finally:
        sched.close()


def test_degraded_flag_in_http_response_envelope():
    import urllib.request
    from repro.serving.frontend import MemoryFrontend

    svc = _svc(shards=2)
    fe = MemoryFrontend(svc, {"key-acme": "acme", "key-beta": "beta"}).start()

    def call(path, body, key):
        req = urllib.request.Request(
            fe.address + path, data=json.dumps(body).encode(),
            headers={"Authorization": f"Bearer {key}"})
        with urllib.request.urlopen(req, timeout=30) as r:
            return json.loads(r.read().decode())

    try:
        for key, city in (("key-acme", "Lisbon"), ("key-beta", "Quito")):
            call("/v1/record", {
                "namespace": "conv0", "session_id": "s0",
                "messages": [{"speaker": "U", "text": f"I live in {city}.",
                              "timestamp": TS}]}, key)
        ns_beta = next(n for n in svc.namespaces() if n.startswith("beta"))
        ns_acme = next(n for n in svc.namespaces() if n.startswith("acme"))
        down = svc.store.shard_of_namespace(ns_beta)
        assert svc.store.shard_of_namespace(ns_acme) != down
        svc.set_shard_down(down)
        q = {"namespace": "conv0", "query": QUERY}
        beta = call("/v1/retrieve", q, "key-beta")
        acme = call("/v1/retrieve", q, "key-acme")
        assert beta["status"] == "ok" and beta["degraded"] is True
        assert beta["payload"]["degraded"] is True
        assert beta["payload"]["triples"] == []
        assert acme["degraded"] is False
        assert any("lisbon" in t["object"]
                   for t in acme["payload"]["triples"])
    finally:
        fe.close()


# -- residency guarantees on the sharded path ----------------------------------

def test_sharded_steady_state_no_recompile_no_bank_upload(monkeypatch):
    """Once warm, the sharded flush -> scatter -> search cycle mints zero
    executables and moves no bank-sized buffers host->device: sharding
    must not regress the single-device residency guarantees."""
    svc = _fill(_svc(shards=4))
    qs = _queries()
    svc.retrieve_batch(qs)             # first search: rebuild + compile
    for i in range(2):                 # warm the append/scatter pads
        svc.enqueue("u0/c0", f"w{i}", [Message("U", "I like Oslo food.", TS)])
        svc.flush()
        svc.retrieve_batch(qs)
    sb = svc.store.sharded
    assert not sb.stale
    slab = sb.n_slots * sb.dim * 4     # full-bank upload size, bytes
    uploads = []
    real_asarray = shards_mod.jnp.asarray

    def spy_asarray(x, *a, **kw):
        if getattr(x, "nbytes", 0) >= slab:
            uploads.append(np.shape(x))
        return real_asarray(x, *a, **kw)

    monkeypatch.setattr(shards_mod.jnp, "asarray", spy_asarray)
    with count_compiles() as cc:
        for i in range(5):
            svc.enqueue("u0/c0", f"x{i}",
                        [Message("U", "I like Oslo food.", TS)])
            svc.flush()
            got = svc.retrieve_batch(qs)
            assert len(got) == 6
    assert cc.count == 0, f"recompiled {cc.count}x: {cc.msgs[:3]}"
    assert uploads == [], f"bank-sized host->device transfers: {uploads}"


@pytest.mark.slow
def test_sharded_bank_spans_all_mesh_devices_with_parity():
    """shards=8 over a (4, 2) CPU device mesh: the device bank is laid out
    across all 8 devices and answers exactly like the single-device
    service.  Subprocess so the pytest parent keeps its one CPU device."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.core import MemoryService, Message
        from repro.core.embedder import HashEmbedder

        cities = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi",
                  "Lagos", "Lima"]

        def fill(svc):
            for i, c in enumerate(cities):
                svc.enqueue("u%d/c0" % i, "s0",
                            [Message("U", "I live in %s." % c, 1700000000.0)])
            svc.flush()
            return svc

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        svc = fill(MemoryService(HashEmbedder(), use_kernel=False,
                                 budget=800, shards=8, mesh=mesh))
        queries = [("u%d/c0" % i, "Which city does the user live in?")
                   for i in range(8)]
        texts = [c.text for c in svc.retrieve_batch(queries)]
        bank = svc.store.sharded.bank_device()
        assert len(bank.sharding.device_set) == 8, bank.sharding
        ref = fill(MemoryService(HashEmbedder(), use_kernel=False,
                                 budget=800))
        assert texts == [c.text for c in ref.retrieve_batch(queries)]
        print("MESH_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "MESH_OK" in out.stdout, out.stderr[-2000:]


# -- the acceptance test: kill a shard owner, recover from the follower --------

_KILL_CHILD = r"""
import hashlib, json, os, sys, time
import numpy as np
from repro.core import MemoryService, Message
from repro.core.embedder import HashEmbedder

d = sys.argv[1]
svc = MemoryService(HashEmbedder(), use_kernel=False, shards=2,
                    data_dir=os.path.join(d, "data"))
svc.attach_follower(os.path.join(d, "follower"))   # sync segment shipping
cities = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi"]
for i, city in enumerate(cities):
    ns = "u%d/c0" % i
    svc.enqueue(ns, "s0", [
        Message("U", "I live in %s." % city, 1700000000.0),
        Message("U", "I adopted a gecko named G%d." % i, 1700000000.0)])
    svc.flush()          # durable: shard parts + cross-shard commit record
    if i == 1:
        svc.rotate()     # mid-stream snapshot + shard-segment GC
    queries = [("u%d/c0" % j, "Which city does the user live in?")
               for j in range(i + 1)]
    texts = [c.text for c in svc.retrieve_batch(queries)]
    bank = np.ascontiguousarray(svc.vindex.bank)
    exp = {"n": i + 1, "texts": texts, "bank_rows": int(bank.shape[0]),
           "bank_sha": hashlib.sha256(bank.tobytes()).hexdigest()}
    tmp = os.path.join(d, "expected.json.tmp")
    with open(tmp, "w") as f:
        json.dump(exp, f); f.flush(); os.fsync(f.fileno())
    os.replace(tmp, os.path.join(d, "expected.json"))
    print("FLUSHED %d" % (i + 1), flush=True)
print("DONE", flush=True)
time.sleep(60)
"""


def test_kill_a_shard_recovery_from_follower_bit_identical(tmp_path):
    """SIGKILL the sharded writer mid-soak, then lose shard 1's disk
    entirely: re-materialize it from the follower's shipped segments and
    recover — retrieval and the bank-row prefix must be bit-identical to
    the writer's last durable commit.  Surviving-shard tenants answer
    (flagged degraded) even while the shard is marked down."""
    proc = subprocess.Popen(
        [sys.executable, "-c", _KILL_CHILD, str(tmp_path)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env={"PATH": os.environ.get("PATH", ""), "PYTHONPATH": "src",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    deadline = time.time() + 180
    killed = False
    try:
        for line in iter(proc.stdout.readline, ""):
            if line.startswith("FLUSHED") and int(line.split()[1]) >= 4:
                proc.kill()            # SIGKILL: no atexit, no final ship
                killed = True
                break
            if time.time() > deadline:
                break
    finally:
        if not killed:
            proc.kill()
        proc.wait()
    assert killed, f"writer never reached 4 flushes: {proc.stderr.read()}"

    with open(str(tmp_path / "expected.json")) as f:
        exp = json.load(f)
    assert exp["n"] >= 4
    data = str(tmp_path / "data")
    shutil.rmtree(os.path.join(data, "shard-01"))   # the shard's disk dies
    sink = DirectorySink(str(tmp_path / "follower"))
    restored = restore_missing_from_follower(sink, data)
    assert any(r.startswith("shard-01/") for r in restored), restored

    svc = MemoryService.recover(data, HashEmbedder(), use_kernel=False,
                                budget=800)
    assert svc.store.shards == 2                    # autodetected layout
    queries = [(f"u{j}/c0", QUERY) for j in range(exp["n"])]
    got = [c.text for c in svc.retrieve_batch(queries)]
    assert got == exp["texts"]
    bank = np.ascontiguousarray(svc.vindex.bank[: exp["bank_rows"]])
    assert svc.vindex.n >= exp["bank_rows"]
    assert hashlib.sha256(bank.tobytes()).hexdigest() == exp["bank_sha"]

    # degraded serving: with shard 1 marked down, shard-0 tenants answer
    # bit-identically and shard-1 tenants are flagged, not failed
    svc.set_shard_down(1)
    dg = svc.retrieve_batch(queries)
    for j in range(exp["n"]):
        if svc.store.shard_of_namespace(f"u{j}/c0") == 1:
            assert dg[j].degraded and not dg[j].triples
        else:
            assert not dg[j].degraded and dg[j].text == exp["texts"][j]
    svc.set_shard_up(1)
    assert [c.text for c in svc.retrieve_batch(queries)] == exp["texts"]
