"""Quickstart: the Memori persistent memory layer in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Ingest two chat sessions through Advanced Augmentation, answer questions
from the structured memory (and compare the token bill against stuffing
the full history into the prompt) — then lose the process and come back:
the service runs on a lifecycle runtime journaling every flush to a
write-ahead log, so a brand-new process recovers the exact same memory
with `MemoryService.recover` and answers identically.
"""
import tempfile
import time

from repro.core import LifecyclePolicy, MemoryService, Message
from repro.core.baselines import FullContextMemory
from repro.core.embedder import HashEmbedder

QUESTIONS = ["What does Ana work as now?",
             "What is the name of Ana's parrot?",
             "Where did Ben travel to?"]


def main():
    data_dir = tempfile.mkdtemp(prefix="memori-quickstart-")
    # the runtime owns everything between requests: durable WAL, background
    # flusher (drains the queue in ONE batched embed call), auto-compaction
    # and snapshot rotation — no manual flush() loops anywhere below
    policy = LifecyclePolicy(flush_interval_s=0.2, max_pending=64,
                             compact_tombstone_ratio=0.3)
    memory = MemoryService(HashEmbedder(), budget=1300, use_kernel=False,
                           policy=policy, data_dir=data_dir)
    full = FullContextMemory()

    t0 = time.time() - 14 * 86400
    sessions = {
        "s0": [
            Message("Ana", "Hey! Long time no see.", t0),
            Message("Ana", "I work as a data analyst these days.", t0),
            Message("Ana", "My favorite food is pad thai.", t0),
            Message("Ana", "I adopted a parrot named Mochi.", t0),
            Message("Ben", "Nice! I went to Iceland. The glaciers were unreal.", t0),
        ],
        "s1": [
            Message("Ana", "Big news since last time we talked!", t0 + 7 * 86400),
            Message("Ana", "I used to work as a data analyst, but now I am a chef.",
                    t0 + 7 * 86400),
            Message("Ben", "I bought a telescope last week.", t0 + 7 * 86400),
        ],
    }
    for sid, msgs in sessions.items():
        # enqueue is O(1); the background flusher batches the extraction +
        # embedding behind the scenes (reads still see pending sessions)
        memory.enqueue("demo/c0", sid, msgs)
        full.record_session("demo", sid, msgs)

    print("memory stats:", memory.stats(), "\n")
    for q in QUESTIONS:
        ctx = memory.retrieve("demo/c0", q)
        print(f"Q: {q}")
        print(f"  retrieved {len(ctx.triples)} triples, "
              f"{len(ctx.summaries)} summaries, {ctx.token_count} tokens "
              f"(full-context would be {full.retrieve(q).token_count})")
        for t in ctx.triples[:3]:
            print(f"    {t.render()}")
        print()

    prompt, ctx = memory.answer_prompt("demo/c0", "What does Ana work as now?")
    print("--- assembled LLM prompt (truncated) ---")
    print(prompt[:600])

    # persistence: close (final flush + snapshot), then recover in what
    # would normally be a fresh process — answers are bit-identical
    before = [memory.retrieve("demo/c0", q).text for q in QUESTIONS]
    memory.close()
    recovered = MemoryService.recover(data_dir, HashEmbedder(),
                                      use_kernel=False, budget=1300)
    after = [recovered.retrieve("demo/c0", q).text for q in QUESTIONS]
    print("\n--- durability ---")
    print(f"recovered from {data_dir}")
    print("recovered answers identical:", before == after)


if __name__ == "__main__":
    main()
