"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000.  RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427]."""
from repro.models.config import ModelConfig, RGLRUConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        arch_type="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,               # MQA on the local-attention layers
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        source="[arXiv:2402.19427]",
        hybrid_period=3,              # (rglru, rglru, local-attn) repeating
        rglru=RGLRUConfig(width=0, conv_width=4, local_window=2048,
                          c_exponent=8.0),
        act="gelu",
        mlp_gated=True,
        tie_embeddings=True,
        long_context_window=0,        # natively sub-quadratic (fixed-size caches)
    )
