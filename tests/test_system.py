"""End-to-end behaviour tests: the paper's claims on the synthetic LoCoMo.

These assert the *qualitative structure* of Tables 1 and 2:
  1. Memori accuracy ≈ full-context ceiling and >> raw-chunk RAG,
  2. Memori's context footprint is a small fraction (<10%) of full context,
  3. hybrid retrieval beats either retriever alone on planted facts.
"""
import collections

import pytest

from repro.core.baselines import FullContextMemory, RagChunkMemory
from repro.core.embedder import HashEmbedder
from repro.core.memory import MemoriMemory
from repro.data.locomo_synth import (CATEGORIES, generate_conversation, judge,
                                     oracle_read)
from repro.data.tokenizer import default_tokenizer

EMB = HashEmbedder()


def _run(mem, conv, salt):
    per_cat = collections.defaultdict(lambda: [0, 0])
    tokens = []
    for q in conv.questions:
        ctx = mem.retrieve(q.question)
        tokens.append(ctx.token_count)
        ok = judge(q, oracle_read(q, ctx.text, salt=salt))
        per_cat[q.category][0] += ok
        per_cat[q.category][1] += 1
    acc = (sum(v[0] for v in per_cat.values())
           / sum(v[1] for v in per_cat.values()))
    return acc, sum(tokens) / len(tokens), per_cat


@pytest.fixture(scope="module")
def systems():
    conv = generate_conversation(seed=1, n_sessions=8, noise_turns=60)
    mems = {
        "memori": MemoriMemory(EMB, budget=1300, use_kernel=False),
        "rag": RagChunkMemory(EMB, use_kernel=False),
        "full": FullContextMemory(),
    }
    for name, mem in mems.items():
        for sid, msgs in conv.sessions:
            mem.record_session(conv.conversation_id, sid, msgs)
    return conv, {name: _run(mem, conv, name) for name, mem in mems.items()}


def test_memori_beats_raw_rag(systems):
    _, res = systems
    assert res["memori"][0] > res["rag"][0] + 0.15


def test_memori_close_to_full_context_ceiling(systems):
    _, res = systems
    assert res["memori"][0] >= res["full"][0] - 0.10


def test_token_footprint_fraction(systems):
    conv, res = systems
    tok = default_tokenizer()
    full_tokens = res["full"][1]
    assert res["memori"][1] < 0.12 * full_tokens, \
        f"memori {res['memori'][1]} vs full {full_tokens}"


def test_all_categories_present(systems):
    conv, res = systems
    cats = {q.category for q in conv.questions}
    assert cats == set(CATEGORIES)


def test_single_hop_recall_high(systems):
    _, res = systems
    per_cat = res["memori"][2]
    sh = per_cat["single_hop"]
    assert sh[0] / sh[1] >= 0.8
