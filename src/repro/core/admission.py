"""Admission control for the memory scheduler — per-tenant QoS.

The PR-5 scheduler drained its queue strictly FIFO: one abusive client
flooding `submit()` pushed every other tenant's requests behind its own,
so the abuser dictated everyone's tail latency.  This module replaces the
FIFO drain with the slot/admission dataflow of `serving/engine.py` applied
to the memory layer: requests are *admitted* (or shed) at submit time, and
each tick *selects* its batch across per-tenant queues instead of popping
a shared deque.

Three mechanisms, all policy-driven (`AdmissionPolicy` / `TenantPolicy`):

* **weighted round-robin within a tick** — deficit round-robin over the
  tenants that have queued work: tenant i earns `weight_i` credits per
  round and spends one per granted request, so a tick's `max_batch` slots
  split proportionally to weight no matter how deep any one queue is —
  and each tenant is *capped* at its share, so a flood cannot absorb the
  slots lighter tenants left unused and inflate every tick's execution
  time (a tenant queueing alone still gets the whole tick).  A tenant's
  own requests stay FIFO (read-your-writes within a tenant is
  preserved); cross-tenant order inside a tick is irrelevant — namespaces
  are isolated, and every future in a tick resolves at the same tick end.
* **priority classes** — strict priority between classes (lower number
  wins): a tick grants no `PRIORITY_LOW` slot while any `PRIORITY_HIGH`
  tenant still has queued work.  WRR applies within each class.
* **rate limits + load shedding** — a per-tenant token bucket
  (`rate` req/s, `burst` capacity) rejects floods at submit time, a
  per-tenant queue cap (`max_queued`) bounds how much backlog any tenant
  can park, and a global cap (`max_queued_global`) sheds tenants sitting
  above their weight-proportional fair share while still admitting the
  tenants below it.  Every rejection raises `AdmissionError` carrying a
  `retry_after_s` hint — the HTTP frontend maps it to 429 + Retry-After.

The controller is deliberately lock-free: the scheduler calls it under
its own condition lock (`MemoryScheduler._cv`), which also makes the unit
deterministic — tests drive `admit` / `select` directly with an injected
clock.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

PRIORITY_HIGH = 0
PRIORITY_NORMAL = 1
PRIORITY_LOW = 2


class AdmissionError(RuntimeError):
    """A request was refused admission (rate limit / queue cap / overload).

    `reason` is one of "rate_limited" | "tenant_queue_full" | "overloaded";
    `retry_after_s` is the backoff hint the frontend puts on the wire
    (429 + Retry-After)."""

    def __init__(self, message: str, reason: str, retry_after_s: float,
                 tenant: str = ""):
        super().__init__(message)
        self.reason = reason
        self.retry_after_s = float(retry_after_s)
        self.tenant = tenant


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """One tenant's QoS contract (see docs/OPERATIONS.md for tuning).

    `weight` is the tenant's WRR share within its priority class;
    `priority` its class (strict between classes); `rate`/`burst` the
    token bucket (None = unlimited); `max_queued` its backlog cap
    (None = unbounded)."""
    weight: float = 1.0
    priority: int = PRIORITY_NORMAL
    rate: Optional[float] = None
    burst: int = 32
    max_queued: Optional[int] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError("weight must be > 0")
        if self.rate is not None and self.rate <= 0:
            raise ValueError("rate must be > 0 (or None for unlimited)")
        if self.burst < 1:
            raise ValueError("burst must be >= 1")
        if self.max_queued is not None and self.max_queued < 1:
            raise ValueError("max_queued must be >= 1")


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """The scheduler-wide QoS policy: a default tenant contract, explicit
    per-tenant overrides, and the global shed threshold.  The default
    policy (all None) admits everything in arrival order — byte-for-byte
    the behavior a limit-free deployment expects."""
    default: TenantPolicy = TenantPolicy()
    tenants: Mapping[str, TenantPolicy] = \
        dataclasses.field(default_factory=dict)
    max_queued_global: Optional[int] = None
    shed_retry_after_s: float = 0.5
    # how long a tenant that admitted work keeps its fair-share
    # reservation after its queue momentarily empties (closed-loop clients
    # are queue-empty exactly while their previous tick executes — without
    # the window, a flood grabs the whole tick in that gap)
    share_window_s: float = 0.1

    def __post_init__(self):
        if self.max_queued_global is not None and self.max_queued_global < 1:
            raise ValueError("max_queued_global must be >= 1")
        if self.share_window_s < 0:
            raise ValueError("share_window_s must be >= 0")

    def for_tenant(self, tenant: str) -> TenantPolicy:
        return self.tenants.get(tenant, self.default)


class _TenantState:
    __slots__ = ("policy", "queue", "deficit", "tokens", "refilled_at",
                 "last_admit", "admitted", "rate_limited", "shed")

    def __init__(self, policy: TenantPolicy, now: float):
        self.policy = policy
        self.queue: deque = deque()
        self.deficit = 0.0
        self.tokens = float(policy.burst)
        self.refilled_at = now
        self.last_admit = now
        self.admitted = 0
        self.rate_limited = 0
        self.shed = 0

    def refill(self, now: float) -> None:
        if self.policy.rate is None:
            return
        # clamp: a caller's `now` captured just before this state was
        # created would otherwise refill by a NEGATIVE elapsed time and
        # drain tokens the tenant never spent
        elapsed = max(0.0, now - self.refilled_at)
        self.tokens = min(float(self.policy.burst),
                          self.tokens + elapsed * self.policy.rate)
        self.refilled_at = max(now, self.refilled_at)


class AdmissionController:
    """Per-tenant queues + the admit/select policy over them.

    NOT internally locked: the scheduler serializes every call under its
    condition lock.  `clock` is injectable so rate-limit tests are
    deterministic."""

    def __init__(self, policy: Optional[AdmissionPolicy] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy or AdmissionPolicy()
        self.clock = clock
        self._tenants: Dict[str, _TenantState] = {}   # insertion-ordered
        self._rr_offset = 0
        self._total = 0
        self.counters = {"admitted": 0, "rate_limited": 0, "shed": 0,
                         "policy_reloads": 0}

    def _state(self, tenant: str) -> _TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            st = _TenantState(self.policy.for_tenant(tenant), self.clock())
            self._tenants[tenant] = st
        return st

    def set_policy(self, policy: AdmissionPolicy) -> None:
        """Swap the mounted policy in place — the dynamic-reload path (the
        frontend's authenticated admin endpoint, via
        `MemoryScheduler.set_admission_policy`).  Existing tenant states
        keep their queues and counters but re-bind to the new policy's
        contract: each bucket refills under the OLD rate first (tokens
        earned are kept), then clamps to the new burst so a shrunken limit
        takes effect immediately instead of after the old burst drains.
        Caller must hold whatever lock serializes admit/select (the
        scheduler's condition lock)."""
        if not isinstance(policy, AdmissionPolicy):
            raise TypeError(f"set_policy takes an AdmissionPolicy, got "
                            f"{type(policy).__name__}")
        now = self.clock()
        self.policy = policy
        for name, st in self._tenants.items():
            st.refill(now)               # settle earnings under the old rate
            st.policy = policy.for_tenant(name)
            st.tokens = min(st.tokens, float(st.policy.burst))
        self.counters["policy_reloads"] += 1

    # -- admit --------------------------------------------------------------
    def admit_batch(self, counts: Sequence[Tuple[str, int]]) -> None:
        """All-or-nothing admission of `n` requests per tenant: every
        check runs before any token is consumed or any counter moves, so a
        rejected submit_many leaves no half-admitted residue."""
        now = self.clock()
        states = []
        for tenant, n in counts:
            st = self._state(tenant)
            st.refill(now)
            p = st.policy
            if p.rate is not None and st.tokens < n:
                st.rate_limited += n
                self.counters["rate_limited"] += n
                raise AdmissionError(
                    f"tenant {tenant!r} over its rate limit "
                    f"({p.rate:g} req/s, burst {p.burst})",
                    reason="rate_limited",
                    retry_after_s=max(0.0, (n - st.tokens) / p.rate),
                    tenant=tenant)
            if p.max_queued is not None \
                    and len(st.queue) + n > p.max_queued:
                st.shed += n
                self.counters["shed"] += n
                raise AdmissionError(
                    f"tenant {tenant!r} backlog full "
                    f"({len(st.queue)}/{p.max_queued} queued)",
                    reason="tenant_queue_full",
                    retry_after_s=self.policy.shed_retry_after_s,
                    tenant=tenant)
            gcap = self.policy.max_queued_global
            if gcap is not None and self._total + n > gcap:
                # under global pressure, shed only the tenants sitting
                # above their weight-proportional fair share — the tenants
                # below it keep getting admitted (soft overflow), so one
                # flood cannot close the door on everyone
                if len(st.queue) + n > self._fair_share(st, gcap):
                    st.shed += n
                    self.counters["shed"] += n
                    raise AdmissionError(
                        f"queue overloaded ({self._total}/{gcap}) and "
                        f"tenant {tenant!r} is above its fair share",
                        reason="overloaded",
                        retry_after_s=self.policy.shed_retry_after_s,
                        tenant=tenant)
            states.append((st, n))
        for st, n in states:
            if st.policy.rate is not None:
                st.tokens -= n
            st.admitted += n
            st.last_admit = now
            self.counters["admitted"] += n

    def _fair_share(self, st: _TenantState, gcap: int) -> float:
        active = [s for s in self._tenants.values() if s.queue]
        if st not in active:
            active.append(st)
        total_w = sum(s.policy.weight for s in active)
        return max(1.0, gcap * st.policy.weight / total_w)

    # -- queues -------------------------------------------------------------
    def push(self, tenant: str, item) -> None:
        self._state(tenant).queue.append(item)
        self._total += 1

    @property
    def total_queued(self) -> int:
        return self._total

    def drain_all(self) -> List:
        """Empty every queue (tenant arrival order, FIFO within a tenant).
        Used by close() to resolve stranded futures."""
        out: List = []
        for st in self._tenants.values():
            out.extend(st.queue)
            st.queue.clear()
            st.deficit = 0.0
        self._total = 0
        return out

    # -- select (the tick's drain) ------------------------------------------
    def select(self, max_batch: int) -> List:
        """Pick up to `max_batch` queued items: strict priority between
        classes, deficit round-robin across the class's tenants, FIFO
        within each tenant.  Selection only decides WHO gets a slot — the
        scheduler re-sorts the selected batch into global submission order
        before executing it (intra-tick order is side-effect semantics,
        not fairness: every future in a tick resolves at the tick end).

        Slots are NOT work-conserving across tenants: each tenant is
        capped at its weight-proportional share of `max_batch`, frozen
        when its priority class first forms a ring this call.  A flooding
        tenant therefore cannot absorb the slots other tenants did not
        use — which would inflate the tick's batch (and its execution
        time, the thing every future in the tick waits on) far past what
        the well-behaved load alone needs.  A tenant queueing alone still
        gets the whole tick (its share of the ring is 1), so a
        single-tenant deployment keeps full batches."""
        out: List = []
        caps: Dict[int, int] = {}       # id(state) -> slot cap this call
        grants: Dict[int, int] = {}
        while len(out) < max_batch:
            active = [s for s in self._tenants.values() if s.queue
                      and grants.get(id(s), 0) < caps.get(id(s), max_batch)]
            if not active:
                break
            prio = min(s.policy.priority for s in active)
            ring = [s for s in active if s.policy.priority == prio]
            uncapped = [s for s in ring if id(s) not in caps]
            if uncapped:
                # entry-time fair share — computed over the class's queued
                # tenants PLUS its recently-admitting ones.  Closed-loop
                # clients are queue-empty exactly while their previous
                # tick executes; counting them for `share_window_s` after
                # their last admit stops a flood from claiming the whole
                # tick in that gap, while a tenant that is genuinely alone
                # (nobody else admitted within the window) still gets the
                # full batch
                now = self.clock()
                share = [s for s in self._tenants.values()
                         if s.policy.priority == prio
                         and (s.queue or now - s.last_admit
                              <= self.policy.share_window_s)]
                total_w = sum(s.policy.weight for s in share)
                for s in uncapped:
                    caps[id(s)] = max(1, math.ceil(
                        max_batch * s.policy.weight / total_w))
            # rotate the starting tenant across calls so equal-weight
            # tenants do not always drain in the same order
            start = self._rr_offset % len(ring)
            ring = ring[start:] + ring[:start]
            progressed = False
            for st in ring:
                if not st.queue or len(out) >= max_batch:
                    continue
                st.deficit += st.policy.weight
                take = min(int(st.deficit), len(st.queue),
                           max_batch - len(out),
                           caps[id(st)] - grants.get(id(st), 0))
                if take > 0:
                    for _ in range(take):
                        out.append(st.queue.popleft())
                    st.deficit -= take
                    self._total -= take
                    grants[id(st)] = grants.get(id(st), 0) + take
                    progressed = True
                if not st.queue:
                    # standard DRR: idle tenants bank no credit
                    st.deficit = 0.0
            if not progressed:
                # every below-cap deficit is still fractional (weights
                # < 1): loop — deficits grow by weight > 0 per round, so
                # progress is guaranteed (capped tenants left the active
                # set above)
                continue
        self._rr_offset += 1
        return out

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        per_tenant = {
            name: {"queued": len(st.queue), "admitted": st.admitted,
                   "rate_limited": st.rate_limited, "shed": st.shed,
                   "weight": st.policy.weight,
                   "priority": st.policy.priority}
            for name, st in self._tenants.items()}
        return dict(self.counters, queued=self._total, tenants=per_tenant)


# -- wire codec (the frontend's policy-reload endpoint) ----------------------
def tenant_policy_from_json(obj: dict) -> TenantPolicy:
    """One JSON object -> TenantPolicy, validated by the dataclass's own
    __post_init__ checks.  Unknown keys are rejected — a typo'd knob in an
    operator's reload payload must fail loudly, not silently no-op."""
    if not isinstance(obj, dict):
        raise ValueError("tenant policy must be a JSON object")
    known = {"weight", "priority", "rate", "burst", "max_queued"}
    unknown = sorted(set(obj) - known)
    if unknown:
        raise ValueError(f"unknown tenant policy keys {unknown}; "
                         f"known: {sorted(known)}")
    return TenantPolicy(
        weight=float(obj.get("weight", 1.0)),
        priority=int(obj.get("priority", PRIORITY_NORMAL)),
        rate=None if obj.get("rate") is None else float(obj["rate"]),
        burst=int(obj.get("burst", 32)),
        max_queued=(None if obj.get("max_queued") is None
                    else int(obj["max_queued"])))


def admission_policy_from_json(obj: dict) -> AdmissionPolicy:
    """The reload endpoint's body -> AdmissionPolicy."""
    if not isinstance(obj, dict):
        raise ValueError("admission policy must be a JSON object")
    known = {"default", "tenants", "max_queued_global", "shed_retry_after_s",
             "share_window_s"}
    unknown = sorted(set(obj) - known)
    if unknown:
        raise ValueError(f"unknown admission policy keys {unknown}; "
                         f"known: {sorted(known)}")
    tenants = obj.get("tenants", {})
    if not isinstance(tenants, dict):
        raise ValueError("'tenants' must be an object of per-tenant "
                         "policies")
    kw: dict = {
        "default": tenant_policy_from_json(obj.get("default", {})),
        "tenants": {str(k): tenant_policy_from_json(v)
                    for k, v in tenants.items()},
        "max_queued_global": (None if obj.get("max_queued_global") is None
                              else int(obj["max_queued_global"])),
    }
    if obj.get("shed_retry_after_s") is not None:
        kw["shed_retry_after_s"] = float(obj["shed_retry_after_s"])
    if obj.get("share_window_s") is not None:
        kw["share_window_s"] = float(obj["share_window_s"])
    return AdmissionPolicy(**kw)


def tenant_of(request) -> str:
    """Default tenant identity for in-process submissions: the namespace
    segment before the first '/' (the repo's `user/conversation` keying),
    or the whole namespace when it has no '/'.  Requests without a
    namespace (CompactRequest) belong to the system tenant.  The HTTP
    frontend overrides this with the api-key-derived tenant."""
    ns = getattr(request, "namespace", None)
    if ns is None:
        return "__system__"
    return ns.split("/", 1)[0] if "/" in ns else ns
