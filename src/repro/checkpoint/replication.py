"""Per-shard WAL ownership, cross-shard group commit, and segment shipping.

The sharded store journals one logical flush as several per-shard parts.
`ShardedWal` lays that out as:

    <dir>/
      MANIFEST.msgpack, snapshot-*.msgpack     coordinator (whole-store)
      wal-00000008.msgpack                     commit records + plain ops
      shard-00/wal-00000003.msgpack            shard 0's flush parts
      shard-01/wal-00000005.msgpack            shard 1's flush parts

A `sharded_flush` record's parts are appended to their owning shard's log
first (each an fsync'd atomic segment), and only then does ONE commit
record — `{"op": "shard_commit", "parts": [[shard, shard_seq], ...]}` —
land in the coordinator log.  **The group is durable iff the commit record
is durable**: a crash after some shard appends but before the commit
record leaves orphaned shard segments that replay never references (and
the next rotation reaps).  Replay walks the coordinator log in seq order
and re-inflates each commit record from its shard logs; a missing or
corrupt shard part stops replay at that commit record — the store state is
always a consistent prefix of the commit order, never a partial flush.

`SegmentShipper` streams every sealed segment (coordinator and shard logs
alike, via `WriteAheadLog.on_seal`) to a `Sink` — a follower directory or
an object store — so recovery works after losing the host, not just the
process: `restore_missing_from_follower` re-materializes the lost files
and the ordinary recovery path replays them.  Shipping is best-effort and
off the durability path (local fsync is the commit point; follower lag is
the replication RPO — see docs/OPERATIONS.md).
"""
from __future__ import annotations

import os
import queue
import re
import threading
import warnings
from typing import List, Optional, Tuple

from repro.checkpoint import faults
from repro.checkpoint.wal import (CorruptSegmentError, WriteAheadLog,
                                  atomic_write_bytes, fsync_dir)
from repro.obs.telemetry import get_telemetry

SHARD_DIR_RE = re.compile(r"^shard-(\d{2})$")


# -- sinks -------------------------------------------------------------------
class DirectorySink:
    """Follower-directory sink: relative paths mirrored under `root`, each
    file landed atomically (a follower never holds a torn segment).  Also
    the stand-in for an object store: put/get/has/list is the whole
    contract."""

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    def put(self, rel: str, blob: bytes) -> None:
        path = os.path.join(self.root, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        atomic_write_bytes(path, blob)

    def get(self, rel: str) -> bytes:
        with open(os.path.join(self.root, rel), "rb") as f:
            return f.read()

    def has(self, rel: str) -> bool:
        return os.path.isfile(os.path.join(self.root, rel))

    def list(self) -> List[str]:
        out = []
        for dirpath, _, names in os.walk(self.root):
            for name in names:
                out.append(os.path.relpath(os.path.join(dirpath, name),
                                           self.root))
        return sorted(out)


class SegmentShipper:
    """Streams sealed WAL segments to a sink.  Install as `wal.on_seal`.

    Shipping NEVER raises into the append path: the local fsync is the
    durability point, the follower is asynchronous replication.  A failed
    ship is counted and warned (`counters["failed"]`) — operators alert on
    it as replication lag.  `mode="sync"` ships inline (tests, small
    deployments); `mode="async"` hands sealed paths to a daemon thread so
    a slow sink cannot stall group commit.
    """

    def __init__(self, source_dir: str, sink, mode: str = "sync"):
        if mode not in ("sync", "async"):
            raise ValueError(f"mode {mode!r} must be 'sync' or 'async'")
        self.source_dir = os.path.abspath(source_dir)
        self.sink = sink
        self.mode = mode
        self.counters = {"shipped": 0, "failed": 0, "queued": 0}
        self._stop = object()
        if mode == "async":
            self._q: queue.Queue = queue.Queue()
            self._thread = threading.Thread(
                target=self._loop, name="wal-shipper", daemon=True)
            self._thread.start()

    def __call__(self, abs_path: str) -> None:
        rel = os.path.relpath(os.path.abspath(abs_path), self.source_dir)
        if self.mode == "sync":
            self._ship_one(rel)
        else:
            self.counters["queued"] += 1
            self._q.put(rel)

    def _ship_one(self, rel: str) -> None:
        tel = get_telemetry()
        try:
            with tel.span("replication.ship", segment=rel):
                faults.active().trip("ship", rel)
                with open(os.path.join(self.source_dir, rel), "rb") as f:
                    blob = f.read()
                self.sink.put(rel, blob)
            self.counters["shipped"] += 1
            tel.inc("memori_replication_shipped",
                    help="WAL segments shipped to the follower sink")
        except Exception as e:
            self.counters["failed"] += 1
            tel.inc("memori_replication_failed",
                    help="WAL segment ship failures (replication lag)")
            tel.event("replication_failed", segment=rel, error=str(e))
            warnings.warn(f"WAL segment ship failed for {rel}: {e}",
                          stacklevel=2)

    def ship_existing(self) -> int:
        """Backfill: ship every sealed segment the sink does not have yet
        (attach-follower on a log with history; also re-ship after an
        outage).  Returns how many were shipped."""
        n = 0
        for dirpath, _, names in os.walk(self.source_dir):
            for name in sorted(names):
                if not (name.startswith("wal-")
                        and name.endswith(".msgpack")):
                    continue
                abs_p = os.path.join(dirpath, name)
                rel = os.path.relpath(abs_p, self.source_dir)
                if not self.sink.has(rel):
                    self._ship_one(rel)
                    n += 1
        return n

    def _loop(self) -> None:
        while True:
            rel = self._q.get()
            if rel is self._stop:
                self._q.task_done()
                return
            self._ship_one(rel)
            self._q.task_done()

    def drain(self) -> None:
        """Block until every queued segment has been attempted."""
        if self.mode == "async":
            self._q.join()

    def close(self) -> None:
        if self.mode == "async":
            self._q.put(self._stop)
            self._q.join()
            self._thread.join(timeout=5)


# -- sharded WAL -------------------------------------------------------------
class ShardedWal:
    """Coordinator WAL + per-shard WALs, presenting the `WriteAheadLog`
    surface the lifecycle runtime mounts.  Seq numbers (and therefore
    snapshot coverage, quarantine, and `last_seq`) live in the COORDINATOR
    log; shard logs have private seq spaces referenced only by commit
    records."""

    def __init__(self, dirpath: str, n_shards: int):
        if n_shards < 2:
            raise ValueError("ShardedWal needs n_shards >= 2 (use "
                             "WriteAheadLog for a single shard)")
        self.n_shards = int(n_shards)
        self.commit = WriteAheadLog(dirpath)
        self.shards = [WriteAheadLog(os.path.join(dirpath, f"shard-{s:02d}"))
                       for s in range(self.n_shards)]
        self.replay_stopped_seq: Optional[int] = None

    # -- delegated surface -------------------------------------------------
    @property
    def dir(self) -> str:
        return self.commit.dir

    @property
    def last_seq(self) -> int:
        return self.commit.last_seq

    @property
    def on_seal(self):
        return self.commit.on_seal

    @on_seal.setter
    def on_seal(self, hook) -> None:
        """One hook observes every sealed segment, coordinator and shard
        logs alike (the shipper computes each file's relative path)."""
        self.commit.on_seal = hook
        for w in self.shards:
            w.on_seal = hook

    def segment_seqs(self) -> List[int]:
        return self.commit.segment_seqs()

    def snapshots(self) -> List[Tuple[int, str]]:
        return self.commit.snapshots()

    def latest_snapshot(self) -> Optional[Tuple[int, str]]:
        return self.commit.latest_snapshot()

    def snapshot_path(self, wal_through: int) -> str:
        return self.commit.snapshot_path(wal_through)

    def snapshot_births(self):
        return self.commit.snapshot_births()

    def write_manifest(self, snaps, births=None) -> None:
        self.commit.write_manifest(snaps, births)

    def read_manifest(self):
        return self.commit.read_manifest()

    def file_seq_of(self, record_seq: int) -> int:
        return self.commit.file_seq_of(record_seq)

    def quarantine_from(self, file_seq: int) -> List[str]:
        """Quarantines the coordinator tail.  Shard segments referenced
        only by the dead tail become unreferenced orphans — harmless to
        replay, reaped by the next rotation."""
        return self.commit.quarantine_from(file_seq)

    # -- append: shard parts first, then the commit record -----------------
    def _decompose(self, record: dict) -> dict:
        if not (isinstance(record, dict)
                and record.get("op") == "sharded_flush"):
            return record
        parts = []
        for shard, part in record["parts"]:
            s = int(shard)
            if not 0 <= s < self.n_shards:
                raise ValueError(f"flush part for shard {s} of "
                                 f"{self.n_shards}")
            parts.append([s, int(self.shards[s].append(part))])
        out = {"op": "shard_commit", "parts": parts}
        if "ns_ids" in record:
            out["ns_ids"] = record["ns_ids"]
        return out

    def append(self, record: dict) -> int:
        """Durably append one record.  A `sharded_flush` lands its parts in
        their shard logs first; the record — and with it the whole flush —
        is durable exactly when the coordinator commit record is.  A crash
        between the two leaves orphaned shard segments replay never sees."""
        return self.commit.append(self._decompose(record))

    def append_group(self, records: List[dict]) -> Tuple[int, int]:
        """Cross-shard group commit: every participating shard's segments
        are appended (each its own fsync'd atomic file), then ONE
        coordinator segment carries all the commit records — the group is
        durable iff that final segment is.  All-or-nothing under any
        crash."""
        return self.commit.append_group(
            [self._decompose(r) for r in list(records)])

    # -- replay ------------------------------------------------------------
    def _read_shard_record(self, shard: int, sseq: int) -> dict:
        w = self.shards[shard]
        fseq = w.file_seq_of(sseq)
        if fseq <= 0:
            raise CorruptSegmentError(
                f"shard {shard}: no segment holds record seq {sseq}")
        records = w.read_records(fseq)
        idx = sseq - fseq
        if not 0 <= idx < len(records):
            raise CorruptSegmentError(
                f"shard {shard}: segment {fseq} does not span seq {sseq}")
        return records[idx]

    def replay_records(self, after_seq: int = 0):
        """Yield (seq, record) in coordinator order, re-inflating each
        commit record from its shard logs.  A missing or corrupt shard
        part stops replay at that commit record's FILE (recorded in
        `replay_stopped_seq` for quarantine): the replayed state is always
        a consistent prefix of the commit order — never a flush with some
        shards' rows and not others."""
        self.replay_stopped_seq = None
        for seq, rec in self.commit.replay_records(after_seq):
            if isinstance(rec, dict) and rec.get("op") == "shard_commit":
                parts = []
                try:
                    for shard, sseq in rec["parts"]:
                        parts.append([int(shard), self._read_shard_record(
                            int(shard), int(sseq))])
                except (CorruptSegmentError, OSError, KeyError, ValueError,
                        IndexError, TypeError) as e:
                    self.replay_stopped_seq = self.commit.file_seq_of(seq)
                    warnings.warn(
                        f"sharded WAL replay stopped at commit seq {seq}: "
                        f"{e}", stacklevel=2)
                    return
                out = {"op": "sharded_flush", "parts": parts}
                if "ns_ids" in rec:
                    out["ns_ids"] = rec["ns_ids"]
                yield seq, out
            else:
                yield seq, rec
        if self.commit.replay_stopped_seq is not None:
            self.replay_stopped_seq = self.commit.replay_stopped_seq

    # -- rotation ----------------------------------------------------------
    def commit_snapshot(self, wal_through: int, retain: int = 2) -> dict:
        """Coordinator rotation first (manifest, snapshot retention,
        coordinator-segment truncation), then shard-log garbage collection:
        a shard segment survives only while some REMAINING commit record
        references a record seq inside it.  This reaps both segments whose
        commits the snapshot now covers and orphans from crashed group
        commits."""
        info = self.commit.commit_snapshot(wal_through, retain)
        referenced = [set() for _ in range(self.n_shards)]
        scan_ok = True
        for seq in self.commit.segment_seqs():
            try:
                for rec in self.commit.read_records(seq):
                    if isinstance(rec, dict) \
                            and rec.get("op") == "shard_commit":
                        for shard, sseq in rec["parts"]:
                            if 0 <= int(shard) < self.n_shards:
                                referenced[int(shard)].add(int(sseq))
            except CorruptSegmentError:
                # can't bound what the unreadable tail references — keep
                # every shard segment until quarantine clears it up
                scan_ok = False
                break
        dropped = 0
        if scan_ok:
            for s, w in enumerate(self.shards):
                pruned = False
                for fseq in w.segment_seqs():
                    count = w.segment_record_count(fseq)
                    if not any(fseq <= r < fseq + count
                               for r in referenced[s]):
                        faults.active().unlink(w._seg_path(fseq))
                        dropped += 1
                        pruned = True
                if pruned:
                    fsync_dir(w.dir)
        info["truncated_shard_segments"] = dropped
        return info


# -- open / recover helpers --------------------------------------------------
def detect_shards(dirpath: str) -> int:
    """Shard count a data directory was written with (0 = unsharded), from
    its `shard-NN/` subdirectories.  A gap in the numbering means lost
    shard logs — refuse to guess."""
    if not os.path.isdir(dirpath):
        return 0
    found = []
    for name in os.listdir(dirpath):
        m = SHARD_DIR_RE.match(name)
        if m and os.path.isdir(os.path.join(dirpath, name)):
            found.append(int(m.group(1)))
    if not found:
        return 0
    n = max(found) + 1
    missing = sorted(set(range(n)) - set(found))
    if missing:
        raise ValueError(
            f"{dirpath}: shard dirs present up to shard-{n - 1:02d} but "
            f"missing {missing} — restore them (e.g. "
            "restore_missing_from_follower) before mounting")
    return n


def open_wal(data_dir: str, shards: Optional[int] = None):
    """Open the right WAL flavor for a data directory: explicit `shards`
    wins (validated against what's on disk), otherwise autodetect from the
    `shard-NN/` layout, otherwise a plain `WriteAheadLog`."""
    detected = detect_shards(data_dir)
    if shards is None:
        n = detected
    else:
        n = int(shards)
        if detected and n != detected:
            raise ValueError(
                f"{data_dir} holds {detected}-shard WAL state but "
                f"shards={n} was requested")
    if n > 1:
        return ShardedWal(data_dir, n)
    return WriteAheadLog(data_dir)


def restore_missing_from_follower(sink, data_dir: str) -> List[str]:
    """Re-materialize every file the follower holds that the local data
    directory lost (the recover-from-follower step after losing a host or
    a shard's disk).  Existing local files are never overwritten — local
    state is newer than or equal to the follower's by construction.
    Returns the restored relative paths; ordinary recovery then replays
    them."""
    os.makedirs(data_dir, exist_ok=True)
    restored = []
    for rel in sink.list():
        local = os.path.join(data_dir, rel)
        if os.path.exists(local) or os.path.exists(local + ".corrupt"):
            continue
        os.makedirs(os.path.dirname(local), exist_ok=True)
        atomic_write_bytes(local, sink.get(rel))
        restored.append(rel)
    return restored


def clone_from_follower(sink, data_dir: str) -> List[str]:
    """Bootstrap an empty data directory purely from shipped segments
    (replay-from-genesis: the follower holds no snapshots)."""
    if os.path.isdir(data_dir) and os.listdir(data_dir):
        raise ValueError(f"clone target {data_dir} is not empty")
    return restore_missing_from_follower(sink, data_dir)
