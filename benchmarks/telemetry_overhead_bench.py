"""Telemetry overhead gate (PR 9): instrumentation must be ~free.

Closed-loop multi-client load over the scheduled retrieve path — the same
traffic shape as scheduler_bench — run twice per phase pair with the ONLY
difference being the process-wide telemetry registry: `enabled=False`
(every entry point a no-op — the uninstrumented baseline) vs
`enabled=True` with a live per-request trace, exactly what the HTTP
frontend does (start_trace -> activate -> submit with the trace ->
finish), so every measured request pays for its span tree (queue wait,
shared tick, every plan stage), the latency histograms and the counters.

Phases interleave OFF/ON `--pairs` times, alternating within-pair order.
The gated statistic is the MEDIAN of the within-pair p50 ratios: the two
phases of a pair run back to back under the same machine conditions, so
their ratio isolates the telemetry cost even when absolute latency
drifts several percent across the run (pooled or per-mode medians do
not — on a shared box the drift is larger than the effect).  The CI bar
from the PR: telemetry adds < 5% to p50 (`--assert-overhead 1.05`).

    PYTHONPATH=src python benchmarks/telemetry_overhead_bench.py \
        [--clients 4] [--seconds 0.5] [--pairs 10] \
        [--json BENCH_telemetry.json] [--assert-overhead 1.05]
"""
from __future__ import annotations

import argparse
import json
import statistics
import threading
import time

import numpy as np

from repro.core import MemoryScheduler, MemoryService, Message
from repro.core.api import RetrieveRequest
from repro.core.embedder import HashEmbedder
from repro.obs.telemetry import Telemetry, get_telemetry, set_telemetry

CITIES = ["Tallinn", "Porto", "Cusco", "Oslo", "Quito", "Hanoi", "Windhoek",
          "Sapporo"]
QUERIES = ["Which city does the user live in?",
           "What pet was adopted?",
           "What is the user's job?"]


def _build_service(tenants: int, sessions: int) -> MemoryService:
    svc = MemoryService(HashEmbedder(), use_kernel=False, budget=800)
    for u in range(tenants):
        for s in range(sessions):
            svc.record(f"u{u}/c0", f"s{s}", [
                Message("U", f"I live in {CITIES[(u + s) % len(CITIES)]}.",
                        1700000000.0 + s),
                Message("U", f"I adopted a pet named P{u}_{s}.",
                        1700000000.0 + s),
                Message("U", "I work as a welder.", 1700000000.0 + s)])
    return svc


def _closed_loop(sched: MemoryScheduler, tenants: int, clients: int,
                 seconds: float) -> dict:
    """Each client thread runs one traced retrieve at a time, the way the
    HTTP frontend drives the scheduler.  With telemetry disabled,
    start_trace returns None and the whole ceremony collapses to no-ops —
    the two modes run byte-identical client code."""
    lat: list[list[float]] = [[] for _ in range(clients)]
    stop = time.perf_counter() + seconds
    barrier = threading.Barrier(clients)

    def client(c: int) -> None:
        tel = get_telemetry()
        ns = f"u{c % tenants}/c0"
        barrier.wait()
        i = 0
        while time.perf_counter() < stop:
            t0 = time.perf_counter()
            tr = tel.start_trace(op="retrieve")
            req = RetrieveRequest(namespace=ns,
                                  query=QUERIES[i % len(QUERIES)])
            with tel.activate([tr]):
                fut = sched.submit_many([req], traces=[tr])[0]
            fut.result(timeout=60)
            tel.finish_trace(tr)
            lat[c].append(time.perf_counter() - t0)
            i += 1

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    flat = np.asarray([x for per in lat for x in per])
    return {
        "requests": int(flat.size),
        "throughput_rps": float(flat.size / wall),
        "p50_ms": float(np.percentile(flat, 50) * 1e3),
        "p99_ms": float(np.percentile(flat, 99) * 1e3),
    }, flat


def run(clients: int = 4, seconds: float = 0.5, pairs: int = 10,
        tenants: int = 8, sessions: int = 2, tick_interval: float = 0.002,
        max_batch: int = 64, json_path=None, assert_overhead=None) -> dict:
    prev_tel = get_telemetry()
    svc = _build_service(tenants, sessions)
    sched = MemoryScheduler(svc, tick_interval_s=tick_interval,
                            max_batch=max_batch)
    print(f"# Telemetry overhead bench: {clients} clients, "
          f"{pairs} interleaved off/on pairs, {seconds:.1f}s per phase, "
          f"{svc.stats()['bank_rows']} bank rows")
    report = {"clients": clients, "seconds": seconds, "pairs": pairs,
              "tenants": tenants, "phases": []}
    ratios_p50: list[float] = []
    ratios_rps: list[float] = []
    try:
        # warm executables + scheduler once, instrumented (worst case)
        set_telemetry(Telemetry())
        _closed_loop(sched, tenants, clients, min(seconds, 0.5))
        for pair in range(pairs):
            # alternate within-pair order: a systematic first/second-phase
            # effect (cache state, GC debt from the previous phase) would
            # otherwise bias one mode
            order = ("off", "on") if pair % 2 == 0 else ("on", "off")
            by_mode = {}
            for mode in order:
                set_telemetry(Telemetry(enabled=(mode == "on")))
                point, _ = _closed_loop(sched, tenants, clients, seconds)
                point["mode"] = mode
                by_mode[mode] = point
                report["phases"].append(point)
                print(f"pair {pair} {mode:>3}: "
                      f"{point['throughput_rps']:7.1f} rps  "
                      f"p50 {point['p50_ms']:.3f}ms  "
                      f"p99 {point['p99_ms']:.3f}ms")
            ratios_p50.append(by_mode["on"]["p50_ms"]
                              / by_mode["off"]["p50_ms"])
            ratios_rps.append(by_mode["on"]["throughput_rps"]
                              / by_mode["off"]["throughput_rps"])
    finally:
        sched.close()
        set_telemetry(prev_tel)
    report["pair_p50_ratios"] = ratios_p50
    report["overhead_p50"] = statistics.median(ratios_p50)
    report["throughput_ratio"] = statistics.median(ratios_rps)
    print(f"per-pair p50 ratios: "
          f"{', '.join(f'{r:.3f}' for r in ratios_p50)}")
    print(f"overhead {report['overhead_p50']:.4f}x p50 "
          f"(throughput ratio {report['throughput_ratio']:.4f})")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    if assert_overhead is not None \
            and report["overhead_p50"] > assert_overhead:
        raise AssertionError(
            f"telemetry costs {report['overhead_p50']:.4f}x the disabled "
            f"baseline p50 (gate: {assert_overhead:.2f}x)")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seconds", type=float, default=0.5,
                    help="per-phase duration")
    ap.add_argument("--pairs", type=int, default=10,
                    help="interleaved off/on phase pairs")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=2)
    ap.add_argument("--tick-interval", type=float, default=0.002)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH_telemetry.json artifact")
    ap.add_argument("--assert-overhead", type=float, default=None,
                    help="fail if instrumented p50 exceeds this x the "
                         "disabled-telemetry p50")
    args = ap.parse_args()
    run(clients=args.clients, seconds=args.seconds, pairs=args.pairs,
        tenants=args.tenants, sessions=args.sessions,
        tick_interval=args.tick_interval, max_batch=args.max_batch,
        json_path=args.json, assert_overhead=args.assert_overhead)
