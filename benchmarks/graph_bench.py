"""Graph-stage scoreboard: recall uplift + latency cost of k-hop expansion.

Plants graph-answerable chains (`locomo_synth.generate_conversation(...,
graph_chains=True)`: multi-hop entity chains and within-session temporal
succession) into a multi-tenant MemoryService, then asks every
GRAPH_CATEGORY question twice through the RAW plans — flat hybrid
(dense+sparse+fuse) vs graph-expanded (dense+sparse+graph+fuse) — and
scores **triple-level support recall**: a question counts as recalled when
the returned triples textually contain each of its evidence pairs.  Raw
plans (no token budgeting, no summaries) isolate exactly what the ISSUE
asks for: does the expansion stage surface chain triples the flat ranking
misses, and what does the extra launch cost?

Also asserts the device-residency contract end-to-end: after warmup, the
whole graph-plan batch re-executes with ZERO recompiles.

    JAX_PLATFORMS=cpu python benchmarks/graph_bench.py --json BENCH_graph.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.common.utils import count_compiles
from repro.core.api import RetrievalPlan, RetrieveRequest
from repro.core.embedder import HashEmbedder
from repro.core.service import MemoryService
from repro.data.locomo_synth import GRAPH_CATEGORIES, generate_conversation


def build(seeds, n_sessions, noise_turns):
    svc = MemoryService(HashEmbedder(), use_kernel=False, top_k=10)
    questions = []          # (namespace, Question)
    for seed in seeds:
        conv = generate_conversation(seed=seed, n_sessions=n_sessions,
                                     noise_turns=noise_turns,
                                     graph_chains=True)
        ns = conv.conversation_id
        for sid, msgs in conv.sessions:
            svc.record(ns, sid, msgs)
        questions.extend((ns, q) for q in conv.questions
                         if q.category in GRAPH_CATEGORIES)
    svc.flush()
    return svc, questions


def recalled(svc, ns, q, raw) -> bool:
    t = svc.store.get(ns)
    texts = [t.triples.get(tid).text().lower() for tid in raw.triple_ids]
    need = len(q.supports) if q.min_supports < 0 else q.min_supports
    hits = sum(1 for sup in q.supports
               if any(all(term.lower() in tx for term in sup)
                      for tx in texts))
    return hits >= need


def run_plan(svc, questions, plan, hops, repeats):
    reqs = [RetrieveRequest(ns, q.question, top_k=10,
                            hops=hops if plan.wants_graph else None)
            for ns, q in questions]
    outs = svc.execute(reqs, plan=plan)          # warm (compile + measure recall)
    per_cat = {c: [0, 0] for c in GRAPH_CATEGORIES}
    for (ns, q), raw in zip(questions, outs):
        per_cat[q.category][0] += recalled(svc, ns, q, raw)
        per_cat[q.category][1] += 1
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        svc.execute(reqs, plan=plan)
        times.append(time.perf_counter() - t0)
    times.sort()
    lat_ms = 1e3 * times[len(times) // 2]
    recall = {c: h / max(1, n) for c, (h, n) in per_cat.items()}
    overall = (sum(h for h, _ in per_cat.values())
               / max(1, sum(n for _, n in per_cat.values())))
    return reqs, recall, overall, lat_ms


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated conversation seeds")
    ap.add_argument("--sessions", type=int, default=6)
    ap.add_argument("--noise", type=int, default=40)
    ap.add_argument("--hops", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--assert-uplift", type=float, default=0.1,
                    help="required overall recall gain of graph over flat")
    ap.add_argument("--assert-latency-factor", type=float, default=5.0,
                    help="graph batch latency budget, as a multiple of flat")
    ap.add_argument("--json", dest="json_path", default=None)
    args = ap.parse_args(argv)

    seeds = [int(s) for s in args.seeds.split(",") if s != ""]
    svc, questions = build(seeds, args.sessions, args.noise)
    g = svc.store.graph
    print(f"store: {svc.store.vindex.n} rows, graph {g.n_nodes} nodes / "
          f"{g.n_edges} edges {g.edge_type_counts()}, "
          f"{len(questions)} graph questions")

    flat_plan = RetrievalPlan.raw()
    graph_plan = RetrievalPlan.graph_expanded(budget=False)
    _, flat_recall, flat_overall, flat_ms = run_plan(
        svc, questions, flat_plan, args.hops, args.repeats)
    graph_reqs, graph_recall, graph_overall, graph_ms = run_plan(
        svc, questions, graph_plan, args.hops, args.repeats)

    # steady state: with edge lanes growing WITHIN their capacity bucket,
    # the warmed graph-plan batch re-executes compile-free
    ns0 = questions[0][0]
    svc.store.link(ns0, "bench probe a", "bench probe b", "entity")
    with count_compiles() as cc:
        svc.execute(graph_reqs, plan=graph_plan)
        svc.store.link(ns0, "bench probe c", "bench probe d", "entity")
        svc.execute(graph_reqs, plan=graph_plan)
    zero_recompile = cc.count == 0

    uplift = graph_overall - flat_overall
    latency_factor = graph_ms / max(1e-9, flat_ms)
    result = {
        "bench": "graph_expansion",
        "questions": len(questions),
        "graph": {"nodes": g.n_nodes, "edges": g.n_edges,
                  **{f"edges_{k}": v
                     for k, v in g.edge_type_counts().items()}},
        "recall": {"flat": {"overall": flat_overall, **flat_recall},
                   "graph": {"overall": graph_overall, **graph_recall}},
        "uplift": uplift,
        "latency_ms": {"flat_batch_p50": flat_ms,
                       "graph_batch_p50": graph_ms,
                       "factor": latency_factor},
        "zero_recompile_steady_state": zero_recompile,
        "asserted": {"uplift_min": args.assert_uplift,
                     "latency_factor_max": args.assert_latency_factor},
    }
    print(json.dumps(result, indent=2))
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(result, f, indent=2)

    failures = []
    if not zero_recompile:
        failures.append(f"steady-state graph batch recompiled {cc.count}x")
    if uplift < args.assert_uplift:
        failures.append(f"recall uplift {uplift:.3f} < {args.assert_uplift}")
    if latency_factor > args.assert_latency_factor:
        failures.append(f"latency factor {latency_factor:.2f}x > "
                        f"{args.assert_latency_factor}x budget")
    if failures:
        print("FAIL: " + "; ".join(failures))
        return 1
    print(f"OK: recall {flat_overall:.3f} -> {graph_overall:.3f} "
          f"(+{uplift:.3f}) at {latency_factor:.2f}x flat latency, "
          f"zero recompiles")
    return 0


if __name__ == "__main__":
    sys.exit(main())
