"""Lifecycle runtime — everything that happens to a MemoryStore *between*
requests (the fourth pillar next to service, store and retrieval engine).

Three responsibilities, all policy-driven (`LifecyclePolicy`):

* **incremental persistence** — mounted on a durable directory, the runtime
  attaches itself as the store's `wal_sink`: every `flush()` (and evict /
  compact) durably appends a self-describing segment to a write-ahead log
  (`checkpoint/wal.py`, atomic tmp+fsync+rename) *before* the mutation is
  applied.  Recovery (`LifecycleRuntime.recover`) = newest restorable
  snapshot + ordered WAL replay through the store's own commit path, so a
  restored service answers `retrieve_batch` bit-identically to the
  pre-crash store up to the last durable flush.
* **background flusher** — a daemon thread drains the pending queue through
  the store's one-embed-call batched path every `flush_interval_s` seconds
  (or immediately when the bounded queue fills).  `enqueue()` applies
  backpressure once `max_pending` sessions are buffered: `"block"` waits
  for the flusher (bounded by `enqueue_timeout_s`), `"reject"` raises
  `BackpressureError` — either way the queue depth is bounded, so an
  enqueue-only client sees amortized O(1) cost.
* **policy-driven maintenance** — auto-compaction fires when the tombstone
  ratio crosses `compact_tombstone_ratio` during an idle window
  (`compact_idle_s` since the last client op), and snapshot rotation writes
  a fresh full snapshot every `snapshot_interval_s`, retains
  `snapshot_retain` generations, and truncates WAL segments every retained
  generation already covers.

Thread-safety is one coarse reentrant lock: the daemon, `enqueue`, and the
service's read path (which mounts `runtime.lock`) all serialize against it,
so maintenance never mutates the device-resident bank mid-search.  All the
maintenance primitives remain callable escape hatches (`flush`, `compact`,
`rotate`); `run_maintenance_once()` is the daemon's body, exposed so tests
and embedders without threads can drive the same policy deterministically.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
import warnings
from typing import Optional, Sequence

from repro.checkpoint.replication import (DirectorySink, SegmentShipper,
                                          open_wal)
from repro.core.extraction import Extractor, Message
from repro.core.store import MemoryStore
from repro.core.tiering import TierPolicy
from repro.obs.telemetry import get_telemetry


class BackpressureError(RuntimeError):
    """The pending queue is at `max_pending` and policy forbids waiting (or
    the wait timed out): the caller must slow down or drop the session."""


@dataclasses.dataclass(frozen=True)
class LifecyclePolicy:
    """Knobs of the lifecycle runtime (see docs/OPERATIONS.md).

    All intervals are seconds; `None` disables that behavior.  A policy
    with every trigger disabled is valid — the runtime is then just the
    WAL mount plus manual escape hatches."""
    flush_interval_s: Optional[float] = None   # time-based background flush
    max_pending: Optional[int] = None          # bounded pending queue
    backpressure: str = "block"                # "block" | "reject" when full
    enqueue_timeout_s: Optional[float] = 30.0  # block-mode wait bound
    compact_tombstone_ratio: Optional[float] = None  # auto-compact trigger
    compact_min_tombstones: int = 64           # don't churn tiny banks
    compact_idle_s: float = 1.0                # idle window before compacting
    snapshot_interval_s: Optional[float] = None  # periodic full snapshot
    snapshot_retain: int = 2                   # generations kept on disk
    tick_s: float = 0.05                       # daemon wake granularity
    tier: Optional[TierPolicy] = None          # hot/warm tiered residency

    def __post_init__(self):
        if self.backpressure not in ("block", "reject"):
            raise ValueError(f"backpressure {self.backpressure!r} must be "
                             "'block' or 'reject'")
        if self.max_pending is not None and self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        if self.snapshot_retain < 1:
            raise ValueError("snapshot_retain must be >= 1")

    @property
    def wants_daemon(self) -> bool:
        return (self.flush_interval_s is not None
                or self.compact_tombstone_ratio is not None
                or self.snapshot_interval_s is not None
                or self.tier is not None)


class LifecycleRuntime:
    def __init__(self, store: MemoryStore, data_dir: Optional[str] = None,
                 policy: Optional[LifecyclePolicy] = None,
                 start: bool = True, _recovered: bool = False):
        self.store = store
        self.policy = policy or LifecyclePolicy()
        # a sharded store journals through a ShardedWal (per-shard logs +
        # cross-shard commit records); unsharded stores keep the plain log.
        # Autodetect covers mounting over a directory whose layout is known
        # only from disk.
        self.wal = (open_wal(data_dir,
                             shards=(store.shards
                                     if getattr(store, "shards", 1) > 1
                                     else None))
                    if data_dir else None)
        self.shipper: Optional[SegmentShipper] = None
        self.lock = threading.RLock()
        self._can_enqueue = threading.Condition(self.lock)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self.last_error: Optional[BaseException] = None
        now = time.monotonic()
        self._last_flush = now
        self._last_activity = now
        self._last_snapshot_mono: Optional[float] = None
        self.counters = {"flushes": 0, "auto_compactions": 0, "rotations": 0}
        if self.wal is not None:
            snap = self.wal.latest_snapshot()
            has_prior = snap is not None or bool(self.wal.segment_seqs())
            if has_prior and not _recovered:
                # journaling a store that did NOT come out of this
                # directory on top of it would shadow the existing state —
                # and the next rotation would permanently destroy it
                raise ValueError(
                    f"{self.wal.dir} already holds durable state; recover "
                    "it (LifecycleRuntime.recover / MemoryService.recover) "
                    "instead of mounting a new store over it")
            if snap is not None:
                # age of the on-disk generation survives process restarts.
                # The birth recorded in the manifest at commit time is
                # authoritative — file mtime is only a fallback for
                # snapshots predating birth records, and is clamped to now
                # so a doctored/future mtime (restore tools, clock steps)
                # can never yield a generation "born in the future" that
                # indefinitely suppresses interval-based rotation
                born = self.wal.snapshot_births().get(snap[0])
                if born is None:
                    born = min(os.path.getmtime(snap[1]), time.time())
                age = max(0.0, time.time() - born)
                self._last_snapshot_mono = now - age
            if store.wal_sink is not None:
                raise ValueError("store already has a wal_sink attached")
            store.wal_sink = self.wal.append
            # mounting a fresh log onto a store that already holds state
            # would leave that state unrecoverable (the WAL only sees
            # mutations from now on) — write a baseline generation first
            if (not has_prior and (store.vindex.n or store.namespaces()
                                   or store.pending_count)):
                self.rotate()
        # hot/warm tiering: mount the TierManager on the store so the
        # write path notes activity and maintenance ticks drive
        # demotion/promotion (idempotent if the store already has one)
        if self.policy.tier is not None and store.tiers is None:
            store.attach_tiers(self.policy.tier)
        # every queue drain — background, read-your-writes, or a direct
        # store.flush() — must stamp the flush clock and wake blocked
        # enqueuers, so the bookkeeping hangs off the store's commit hook
        store.on_flush_commit = self._flush_committed
        if start and self.policy.wants_daemon:
            self.start()

    def _flush_committed(self, n_sessions: int) -> None:
        with self._can_enqueue:          # reentrant: safe if already held
            self._last_flush = time.monotonic()
            if n_sessions:
                self.counters["flushes"] += 1
            self._can_enqueue.notify_all()

    # -- recovery -----------------------------------------------------------
    @classmethod
    def recover(cls, data_dir: str, embedder,
                extractor: Optional[Extractor] = None, *,
                policy: Optional[LifecyclePolicy] = None, dim: int = 256,
                use_kernel: bool = True, tokenizer=None,
                start: bool = True, shards: Optional[int] = None,
                mesh=None) -> "LifecycleRuntime":
        """Rebuild a store from a durable directory: newest restorable
        snapshot generation (older generations are fallbacks if the newest
        fails to load) + ordered replay of every valid WAL segment past its
        coverage, through the store's own commit path.  `shards=None`
        autodetects the on-disk WAL layout, so a sharded directory recovers
        into a sharded store without the caller restating the topology."""
        wal = open_wal(data_dir, shards=shards)
        n_shards = getattr(wal, "n_shards", 1)
        store, after = None, 0
        for wal_through, path in reversed(wal.snapshots()):
            try:
                store = MemoryStore.restore(path, embedder,
                                            extractor=extractor,
                                            use_kernel=use_kernel,
                                            tokenizer=tokenizer,
                                            shards=n_shards, mesh=mesh)
                after = wal_through
                break
            except Exception as e:           # fall back a generation
                get_telemetry().event("recovery_snapshot_fallback",
                                      path=path, error=str(e))
                warnings.warn(f"snapshot {path} unrestorable ({e}); "
                              "falling back to an older generation",
                              stacklevel=2)
        if store is None:
            store = MemoryStore(embedder, extractor, dim=dim,
                                use_kernel=use_kernel, tokenizer=tokenizer,
                                shards=n_shards, mesh=mesh)
        poison_file = None
        for seq, record in wal.replay_records(after_seq=after):
            try:
                store.apply_wal(record)
            except Exception as e:
                # a record that fails to APPLY (e.g. a poison flush whose
                # embedder emitted garbage) must not brick the directory
                # forever: stop here — everything before it is a
                # consistent prefix, exactly like a torn tail
                poison_file = wal.file_seq_of(seq)
                warnings.warn(f"WAL replay stopped at seq {seq}: applying "
                              f"the record failed ({e!r}); recovered state "
                              "is the consistent prefix before it",
                              stacklevel=2)
                break
        # an un-replayable tail (corrupt or poison) must not keep shadowing
        # the seq space: left in place, every segment appended after the
        # remount would sit behind it and be silently dropped by the NEXT
        # recovery despite its acknowledged-durable fsync.  Quarantine the
        # dead files, then fold the recovered state into a fresh snapshot
        # generation so nothing recovered lives only in memory.
        dead_from = (poison_file if poison_file is not None
                     else wal.replay_stopped_seq)
        if dead_from is not None:
            wal.quarantine_from(dead_from)
        get_telemetry().event("recovery", dir=data_dir,
                              snapshot_through=after,
                              clean=dead_from is None,
                              quarantined_from=dead_from)
        rt = cls(store, data_dir=data_dir, policy=policy, start=start,
                 _recovered=True)
        if dead_from is not None:
            rt.rotate()
        return rt

    # -- replication --------------------------------------------------------
    def attach_follower(self, sink, mode: str = "sync") -> SegmentShipper:
        """Stream every sealed WAL segment (coordinator and shard logs
        alike) to `sink` — a directory path or any object with
        put/has/list — and backfill whatever history the sink is missing.
        Local fsync stays the durability point; the follower is async
        replication whose lag is the disaster-recovery RPO.  Returns the
        shipper (counters: shipped/failed/queued)."""
        if self.wal is None:
            raise RuntimeError("attach_follower needs a durable data_dir")
        if isinstance(sink, str):
            sink = DirectorySink(sink)
        shipper = SegmentShipper(self.wal.dir, sink, mode=mode)
        with self.lock:
            self.wal.on_seal = shipper
            self.shipper = shipper
        shipper.ship_existing()
        return shipper

    # -- write path with backpressure --------------------------------------
    def enqueue(self, namespace: str, session_id: str,
                messages: Sequence[Message],
                conversation_id: Optional[str] = None) -> None:
        """store.enqueue behind the bounded queue.  With `backpressure=
        "block"` a full queue waits for the flusher (the daemon drains a
        full queue on its next tick regardless of the flush interval); with
        `"reject"` it raises BackpressureError immediately."""
        with self._can_enqueue:
            if self._closed:
                raise RuntimeError(
                    "lifecycle runtime is closed: a durable service must "
                    "not accept writes it can no longer journal")
            self.note_activity()
            mp = self.policy.max_pending
            if mp is not None and self.store.pending_count >= mp:
                if self.policy.backpressure == "reject":
                    self._note_backpressure(namespace, "reject")
                    raise BackpressureError(
                        f"pending queue full ({self.store.pending_count}"
                        f"/{mp})")
                deadline = (None if self.policy.enqueue_timeout_s is None
                            else time.monotonic()
                            + self.policy.enqueue_timeout_s)
                while self.store.pending_count >= mp:
                    remaining = (None if deadline is None
                                 else deadline - time.monotonic())
                    if remaining is not None and remaining <= 0:
                        self._note_backpressure(namespace, "block_timeout")
                        raise BackpressureError(
                            f"enqueue blocked > "
                            f"{self.policy.enqueue_timeout_s}s on a full "
                            f"queue ({mp}) — is the flusher running?")
                    self._can_enqueue.wait(timeout=remaining)
            self.store.enqueue(namespace, session_id, messages,
                               conversation_id=conversation_id)

    def note_activity(self) -> None:
        """Client-facing ops call this; the idle window gating
        auto-compaction measures time since the last call."""
        self._last_activity = time.monotonic()

    def _note_backpressure(self, namespace: str, kind: str) -> None:
        tel = get_telemetry()
        tel.inc("memori_backpressure_rejections",
                help="enqueues rejected (or timed out) by bounded-queue "
                     "backpressure")
        tel.event("backpressure_reject", namespace=namespace, mode=kind,
                  pending=self.store.pending_count,
                  max_pending=self.policy.max_pending)

    @property
    def rejecting(self) -> bool:
        """True while an enqueue would raise BackpressureError right now:
        reject-mode backpressure with the bounded queue at capacity (the
        frontend's readiness probe reports 503 while this holds)."""
        mp = self.policy.max_pending
        return (mp is not None and self.policy.backpressure == "reject"
                and self.store.pending_count >= mp)

    # -- group commit -------------------------------------------------------
    @contextlib.contextmanager
    def group_commit(self):
        """Coalesce every WAL record the body emits into ONE fsync'd group
        segment (`WriteAheadLog.append_group`) written when the block
        exits.  The scheduler wraps a multi-writer tick in this so a tick's
        batched flush + evictions + compaction cost one fsync, not one per
        mutation.

        Commit-ordering contract: the runtime lock is held for the WHOLE
        block (mutations and their buffered records stay one atomic unit —
        no snapshot rotation, background flush or direct writer can
        interleave), and callers must not acknowledge any of the block's
        writes until this context has exited, because durability moves from
        per-mutation to the group boundary.  A crash inside the block loses
        the whole group, never a prefix — recovery replays exactly the
        groups that reached disk.  The buffered records are appended even
        when the body raises partway: whatever DID apply in memory must
        reach the journal, or every later record would replay against
        missing rows.  If the group append ITSELF fails (disk full, EIO),
        the in-memory store is irreversibly ahead of the journal — the
        runtime fail-stops: it detaches the sink, closes, and stops the
        daemon, so no later record is ever journaled on top of the hole
        (recovery then yields the consistent prefix through the last
        durable segment).  Within the block, callers must not wait on the
        runtime's condition (a Condition.wait under the reentrant lock held
        twice cannot release it) — drain a full queue instead of blocking
        on it."""
        info = {"appended": 0}           # yielded: records actually written
        if self.wal is None:
            yield info
            return
        with self.lock:
            if self.store.wal_sink is None:
                # a closed/unmounted store journals nothing; nothing to group
                yield info
                return
            buffered: list = []
            prev = self.store.wal_sink
            self.store.wal_sink = buffered.append
            try:
                yield info
            finally:
                self.store.wal_sink = prev
                if buffered:
                    try:
                        self.wal.append_group(buffered)
                        info["appended"] = len(buffered)
                    except BaseException as e:
                        # fail-stop: journaling anything further would
                        # build the log on top of a hole
                        self.last_error = e
                        self._closed = True
                        self._stop.set()
                        self.store.wal_sink = None
                        raise

    # -- maintenance primitives (escape hatches + daemon body) --------------
    def flush(self) -> int:
        with self.lock:
            # bookkeeping + waiter wakeup happen in _flush_committed (the
            # store's commit hook), shared with every other drain path
            return len(self.store.flush())

    def compact(self) -> dict:
        with self.lock:
            return self.store.compact()

    def rotate(self) -> dict:
        """Flush, write a full snapshot atomically, retire old generations,
        truncate covered WAL segments."""
        if self.wal is None:
            raise RuntimeError("rotate() needs a durable data_dir")
        tel = get_telemetry()
        with self.lock, tel.span("lifecycle.rotate"):
            self.flush()
            wal_through = self.wal.last_seq
            path = self.wal.snapshot_path(wal_through)
            nbytes = self.store.snapshot(path, atomic=True, fsync=True)
            info = self.wal.commit_snapshot(
                wal_through, retain=self.policy.snapshot_retain)
            self._last_snapshot_mono = time.monotonic()
            self.counters["rotations"] += 1
            tel.inc("memori_snapshot_rotations",
                    help="snapshot rotations (full snapshot + WAL "
                         "truncation)")
            info.update({"wal_through": wal_through, "bytes": nbytes,
                         "path": path})
            return info

    def run_maintenance_once(self) -> dict:
        """One daemon tick: time/fullness-triggered flush, idle-window
        auto-compaction, interval-driven snapshot rotation.  Public so
        tests (and hosts that bring their own scheduler) can drive the
        exact policy the daemon runs, deterministically."""
        p = self.policy
        did = {"flushed": 0, "compacted": False, "rotated": False,
               "tier": None}
        now = time.monotonic()
        with self.lock:
            pending = self.store.pending_count
            full = p.max_pending is not None and pending >= p.max_pending
            due = (p.flush_interval_s is not None and pending
                   and now - self._last_flush >= p.flush_interval_s)
            if full or due:
                did["flushed"] = self.flush()
            if p.compact_tombstone_ratio is not None:
                # O(1) counters, not store.stats(): this runs every tick
                dead, rows = self.store.vindex.n_dead, self.store.vindex.n
                idle = now - self._last_activity >= p.compact_idle_s
                if (idle and rows and dead >= p.compact_min_tombstones
                        and dead / rows >= p.compact_tombstone_ratio):
                    self.store.compact()
                    self.counters["auto_compactions"] += 1
                    did["compacted"] = True
            if (p.snapshot_interval_s is not None and self.wal is not None):
                ref = (self._last_snapshot_mono
                       if self._last_snapshot_mono is not None else 0.0)
                if now - ref >= p.snapshot_interval_s:
                    self.rotate()
                    did["rotated"] = True
            if self.store.tiers is not None:
                # promote namespaces marked by host-fallback retrieves,
                # demote the coldest past the hot-row budget — batched
                # pow2 device scatters, under the same lock as every
                # other bank mutation
                did["tier"] = self.store.tiers.tick()
        return did

    def _daemon(self) -> None:
        while not self._stop.wait(self.policy.tick_s):
            try:
                self.run_maintenance_once()
            except Exception as e:       # keep the runtime alive; surface it
                self.last_error = e
                warnings.warn(f"lifecycle maintenance failed: {e!r}",
                              stacklevel=2)

    # -- daemon control -----------------------------------------------------
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._daemon,
                                        name="memori-lifecycle", daemon=True)
        self._thread.start()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self, *, final_snapshot: bool = True) -> None:
        """Stop the daemon, drain the queue, and (with a durable dir)
        write a final snapshot generation.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        with self.lock:
            self.flush()
            if final_snapshot and self.wal is not None:
                self.rotate()
            if self.store.wal_sink is not None and self.wal is not None:
                self.store.wal_sink = None
            self.store.on_flush_commit = None
        if self.shipper is not None:
            self.shipper.close()         # async mode: drain the queue

    def __enter__(self) -> "LifecycleRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        """Operator counters merged into service.stats()."""
        return {
            "pending_depth": self.store.pending_count,
            "wal_segments": (len(self.wal.segment_seqs())
                             if self.wal is not None else 0),
            "last_snapshot_age_s": (
                time.monotonic() - self._last_snapshot_mono
                if self._last_snapshot_mono is not None else None),
            "lifecycle": dict(self.counters,
                              daemon_running=self.running,
                              durable=self.wal is not None),
            "replication": (dict(self.shipper.counters)
                            if self.shipper is not None else None),
        }
