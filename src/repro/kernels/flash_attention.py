"""Blocked causal flash attention (prefill/training), GQA-aware.

Grid: (B, K, num_q_blocks, num_kv_blocks) — kv innermost/sequential.
Per-(b, kv-head) the G grouped query heads ride along inside the block, so
GQA shares each K/V tile across its query group directly in VMEM (the reason
GQA exists).  Online-softmax state (m, l, acc) lives in VMEM scratch and the
output block is written on the last kv step.  Upper-triangular kv blocks are
skipped via pl.when (the causal-skip the pure-jnp path lacks — see
EXPERIMENTS.md §Perf).

VMEM budget per step (defaults bq=256, bk=512, D≤256, G≤8, f32 scratch):
q (G·bq·D) + k/v (2·bk·D) + acc (G·bq·D) ≈ 2-6 MiB — fits v5e's 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            block_q: int, block_k: int, num_kv_blocks: int, causal: bool,
            window: int, scale: float, t_valid: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_start = i * block_q
    k_start = j * block_k
    # causal skip: a kv block strictly above the diagonal contributes nothing
    needed = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(needed)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # (G, bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        ok = k_pos < t_valid          # mask padded cache tail
        if causal:
            ok = ok & (k_pos <= q_pos)
        if window > 0:
            ok = ok & (k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_s[...]
        l_prev = l_s[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_s[...] = l_prev * corr + p.sum(-1)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_s[...] = acc_s[...] * corr[..., None] + pv
        m_s[...] = m_new

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_s[...], 1e-37)
        o_ref[0, 0] = (acc_s[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, block_q: int = 256, block_k: int = 512,
                    interpret: bool = False):
    """q: (B, K, G, S, D); k, v: (B, K, T, D)  ->  (B, K, G, S, D)."""
    B, K, G, S, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, T)
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, Sp - S), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    nq, nk = Sp // bq, Tp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, num_kv_blocks=nk,
                          causal=causal, window=window, scale=scale,
                          t_valid=T),
        grid=(B, K, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),        # running max m
            pltpu.VMEM((G, bq), jnp.float32),        # running denom l
            pltpu.VMEM((G, bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :, :S]
