"""Telemetry — the process-wide observability spine of the memory layer.

The paper's pitch is cost-efficiency (1,294 tokens/query, 20x cheaper than
full context), but a serving stack can only *defend* numbers it can see:
where a request's latency goes once it enters the frontend, which plan
stage a slow tenant is paying for, how long an fsync stalls a group
commit.  This module is the one registry every layer reports into, built
from three primitives:

* **Metrics** — fixed-bucket latency `Histogram`s (numpy-backed bucket
  counts, exact Prometheus `_bucket`/`_sum`/`_count` semantics) and
  monotonic `Counter`s (`_total` suffix on the wire).  One tiny lock per
  metric; an `observe()` is a bisect + two in-place adds, cheap enough for
  every request on the hot path (CI gates the end-to-end overhead at
  < 5% p50 — benchmarks/telemetry_overhead_bench.py).
* **Traces** — per-request span trees.  A `Trace` is created at the edge
  (the HTTP frontend honors/emits `X-Request-Id`) and *activated* on
  whichever thread is currently doing the request's work; `span()` then
  records a timed child span into every active trace.  This is what makes
  batched execution traceable: a scheduler tick activates the traces of
  every request in the batch, so the shared `plan.dense` launch appears —
  with its batch size — in each request's own tree.  Finished traces land
  in a bounded ring buffer, retrievable by request id
  (`GET /v1/admin/trace/<id>`, or `debug: true` on a retrieve).
* **Events** — a bounded structured event log (ring buffer of dicts,
  optional JSONL file sink): slow queries over a configurable threshold,
  admission rejections, degraded-shard responses, backpressure, recovery.

Everything hangs off one process-wide registry (`get_telemetry()`);
`set_telemetry(Telemetry(enabled=False))` turns the whole layer into
no-ops (the overhead bench's baseline).  The registry never calls out
under its locks and never blocks, so it is safe to use inside the
lifecycle runtime's lock, the scheduler tick, and the WAL append path.
"""
from __future__ import annotations

import bisect
import contextlib
import json
import threading
import time
import uuid
from collections import deque
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

# canonical metric names (the acceptance set: retrieve/record/flush/fsync)
RETRIEVE_LATENCY = "memori_retrieve_latency_seconds"
RECORD_LATENCY = "memori_record_latency_seconds"
FLUSH_LATENCY = "memori_flush_latency_seconds"
FSYNC_LATENCY = "memori_fsync_latency_seconds"
GRAPH_EXPAND_LATENCY = "memori_graph_expand_latency_seconds"

# 100us .. 10s: wide enough for a CPU dev box and a production accelerator
# without reconfiguration; override per-histogram via buckets=
DEFAULT_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
                   0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 2 ** 53:
        return str(int(v))
    return repr(float(v))


class Counter:
    """Monotonic counter with classic Prometheus exposition (`_total`)."""

    mtype = "counter"
    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help or "monotonic counter"
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def exposition(self) -> List[str]:
        n = self.name + "_total"
        return [f"# HELP {n} {self.help}",
                f"# TYPE {n} counter",
                f"{n} {_fmt(self._value)}"]


class Histogram:
    """Fixed-bucket histogram with exact Prometheus semantics: cumulative
    `_bucket{le="..."}` counts (closed upper bounds, implicit `+Inf`),
    `_sum`, `_count`.  Bucket counts live in one int64 numpy array; an
    observe is a bisect + two in-place adds under a per-metric lock, so
    concurrent recorders never lose an observation and a scrape mid-storm
    always reads a consistent (counts, sum) pair."""

    mtype = "histogram"
    __slots__ = ("name", "help", "buckets", "_counts", "_sum", "_lock")

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help or "latency histogram (seconds)"
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError("a histogram needs at least one bucket bound")
        self.buckets = bounds
        self._counts = np.zeros(len(bounds) + 1, np.int64)  # [+Inf] last
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, value: float, n: int = 1) -> None:
        """Record `n` observations of `value` (n > 1 amortizes a batched
        launch whose per-request latency is the shared duration)."""
        v = float(value)
        # first bound >= v: Prometheus buckets are closed above (v <= le)
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += n
            self._sum += v * n

    def snapshot(self) -> Tuple[np.ndarray, float]:
        """(per-bucket counts copy, sum) read atomically."""
        with self._lock:
            return self._counts.copy(), float(self._sum)

    @property
    def count(self) -> int:
        return int(self._counts.sum())

    def exposition(self) -> List[str]:
        counts, total = self.snapshot()
        cum = np.cumsum(counts)
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        for b, c in zip(self.buckets, cum):
            lines.append(f'{self.name}_bucket{{le="{_fmt(b)}"}} {int(c)}')
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {int(cum[-1])}')
        lines.append(f"{self.name}_sum {_fmt(total)}")
        lines.append(f"{self.name}_count {int(cum[-1])}")
        return lines


class Span:
    """One timed operation inside a trace.  `t0` is absolute
    `time.perf_counter()`; serialization re-bases it on the trace start."""

    __slots__ = ("name", "t0", "duration_s", "attrs", "children")

    def __init__(self, name: str, t0: float,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.t0 = t0
        self.duration_s: Optional[float] = None
        self.attrs = attrs or {}
        self.children: List["Span"] = []

    def to_dict(self, base: float) -> dict:
        d: Dict[str, Any] = {"name": self.name,
                             "start_s": self.t0 - base,
                             "duration_s": self.duration_s}
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.children:
            d["children"] = [c.to_dict(base) for c in self.children]
        return d


class Trace:
    """A per-request span tree.  Only one thread works a trace at a time
    (the handler thread hands off to the tick thread at a span boundary),
    so the open-span stack needs no lock; serialization snapshots under
    the GIL."""

    def __init__(self, request_id: str, op: str = ""):
        self.request_id = request_id
        self.op = op
        self.started_unix = time.time()
        self.t0 = time.perf_counter()
        self.root = Span(op or "request", self.t0)
        self.duration_s: Optional[float] = None
        self.finished = False
        self._stack: List[Span] = [self.root]

    # -- span plumbing (called via Telemetry.span / add_completed) ----------
    def push(self, name: str, attrs: Optional[dict] = None) -> Span:
        sp = Span(name, time.perf_counter(), attrs)
        self._stack[-1].children.append(sp)
        self._stack.append(sp)
        return sp

    def pop(self, span: Span, duration_s: float) -> None:
        span.duration_s = duration_s
        # tolerate a child left open by an exception path: unwind to span
        while len(self._stack) > 1 and self._stack[-1] is not span:
            self._stack.pop()
        if len(self._stack) > 1 and self._stack[-1] is span:
            self._stack.pop()

    def add_completed(self, name: str, duration_s: float,
                      t0: Optional[float] = None, **attrs) -> Span:
        """Attach an already-measured span (e.g. queue wait, whose start
        predates the thread that reports it)."""
        sp = Span(name, t0 if t0 is not None
                  else time.perf_counter() - duration_s, attrs or None)
        sp.duration_s = duration_s
        self._stack[-1].children.append(sp)
        return sp

    def finish(self) -> None:
        if not self.finished:
            self.duration_s = time.perf_counter() - self.t0
            self.root.duration_s = self.duration_s
            self.finished = True

    def to_dict(self) -> dict:
        return {"request_id": self.request_id, "op": self.op,
                "started_unix": self.started_unix,
                "duration_s": self.duration_s,
                "root": self.root.to_dict(self.t0)}


class _SpanHandle:
    """What `Telemetry.span()` yields: set attributes on every span the
    context opened (one per active trace)."""

    __slots__ = ("_spans",)

    def __init__(self, spans: Tuple[Span, ...] = ()):
        self._spans = spans

    def set(self, **attrs) -> None:
        for sp in self._spans:
            sp.attrs.update(attrs)


_NULL_HANDLE = _SpanHandle()


def new_request_id() -> str:
    return uuid.uuid4().hex[:16]


def walk_spans(span_dict: dict) -> Iterator[dict]:
    """Depth-first walk of a serialized span tree (tests, tooling)."""
    yield span_dict
    for child in span_dict.get("children", ()):
        yield from walk_spans(child)


def span_names(trace_dict: dict) -> List[str]:
    return [s["name"] for s in walk_spans(trace_dict["root"])]


class Telemetry:
    """The process-wide registry: metrics + trace ring + event log.

    `enabled=False` turns every entry point into a near-free no-op — the
    overhead bench's baseline, and the escape hatch for hosts that want
    zero instrumentation cost.  `slow_query_s` is the structured-log
    threshold: any finished trace slower than it emits a `slow_query`
    event.  `event_sink` (a path or file-like) appends every event as one
    JSON line — the durable tail of the bounded in-memory ring."""

    def __init__(self, enabled: bool = True, trace_capacity: int = 512,
                 event_capacity: int = 1024,
                 slow_query_s: Optional[float] = 0.5,
                 event_sink=None):
        self.enabled = bool(enabled)
        self.slow_query_s = slow_query_s
        self._metrics: Dict[str, Any] = {}
        self._mlock = threading.Lock()
        self._traces: deque = deque(maxlen=int(trace_capacity))
        self._tlock = threading.Lock()
        self._events: deque = deque(maxlen=int(event_capacity))
        self._elock = threading.Lock()
        self._tls = threading.local()
        self._own_sink = isinstance(event_sink, str)
        self._sink = (open(event_sink, "a", encoding="utf-8")
                      if self._own_sink else event_sink)

    # -- metrics ------------------------------------------------------------
    def counter(self, name: str, help: str = "") -> Counter:
        m = self._metrics.get(name)
        if m is None:
            with self._mlock:
                m = self._metrics.setdefault(name, Counter(name, help))
        return m

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            with self._mlock:
                m = self._metrics.setdefault(name,
                                             Histogram(name, help, buckets))
        return m

    def inc(self, name: str, n: float = 1.0, help: str = "") -> None:
        if self.enabled:
            self.counter(name, help).inc(n)

    def observe(self, name: str, value: float, n: int = 1, help: str = "",
                buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        if self.enabled:
            self.histogram(name, help, buckets).observe(value, n)

    def metrics(self) -> List[Any]:
        """Registered metrics in registration order (for exposition)."""
        with self._mlock:
            return list(self._metrics.values())

    def render(self) -> str:
        """Prometheus text exposition of just the telemetry metrics."""
        lines: List[str] = []
        for m in self.metrics():
            lines.extend(m.exposition())
        return "\n".join(lines) + ("\n" if lines else "")

    # -- traces -------------------------------------------------------------
    def start_trace(self, request_id: Optional[str] = None,
                    op: str = "") -> Optional[Trace]:
        if not self.enabled:
            return None
        return Trace(request_id or new_request_id(), op=op)

    @contextlib.contextmanager
    def activate(self, traces: Sequence[Optional[Trace]]):
        """Make `traces` the current thread's active set: every `span()`
        inside the block records into each of them.  REPLACES the previous
        active set (restored on exit) — a scheduler tick activating a
        batch, then a retrieve run activating its subset, nests exactly."""
        if not self.enabled:
            yield
            return
        out: List[Trace] = []
        seen = set()
        for t in traces:
            if t is not None and not t.finished and id(t) not in seen:
                seen.add(id(t))
                out.append(t)
        prev = getattr(self._tls, "active", None)
        self._tls.active = out
        try:
            yield
        finally:
            self._tls.active = prev

    def current_traces(self) -> List[Trace]:
        return list(getattr(self._tls, "active", None) or ())

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """A timed child span in every active trace (no-op with none
        active — the duration is measured either way only if someone is
        listening: zero perf_counter calls when disabled)."""
        if not self.enabled:
            yield _NULL_HANDLE
            return
        active = getattr(self._tls, "active", None)
        if not active:
            yield _NULL_HANDLE
            return
        opened = [(tr, tr.push(name, dict(attrs))) for tr in active]
        t0 = time.perf_counter()
        try:
            yield _SpanHandle(tuple(sp for _, sp in opened))
        finally:
            dt = time.perf_counter() - t0
            for tr, sp in opened:
                tr.pop(sp, dt)

    def finish_trace(self, trace: Optional[Trace]) -> None:
        """Close a trace and push it into the ring buffer (oldest traces
        evict first).  Emits a `slow_query` event past the threshold.
        Idempotent — a safety `finally` may call it after the happy
        path already did."""
        if trace is None or not self.enabled or trace.finished:
            return
        trace.finish()
        with self._tlock:
            self._traces.append(trace)
        if (self.slow_query_s is not None
                and trace.duration_s is not None
                and trace.duration_s >= self.slow_query_s):
            self.inc("memori_slow_queries",
                     help="requests slower than the slow-query threshold")
            self.event("slow_query", request_id=trace.request_id,
                       op=trace.op, duration_s=trace.duration_s)

    def get_trace(self, request_id: str) -> Optional[dict]:
        """Most recent finished trace with this request id (None if it
        never existed or already evicted from the ring)."""
        with self._tlock:
            for tr in reversed(self._traces):
                if tr.request_id == request_id:
                    return tr.to_dict()
        return None

    def recent_traces(self, limit: int = 32) -> List[dict]:
        with self._tlock:
            snap = list(self._traces)[-limit:]
        return [t.to_dict() for t in snap]

    # -- structured events --------------------------------------------------
    def event(self, kind: str, **fields) -> None:
        """Append one structured event to the bounded ring (FIFO eviction)
        and, when a sink is mounted, as a JSON line.  Never raises: the
        event log is diagnostics, not a failure mode."""
        if not self.enabled:
            return
        ev = {"ts": time.time(), "kind": kind}
        ev.update(fields)
        with self._elock:
            self._events.append(ev)
            if self._sink is not None:
                try:
                    self._sink.write(json.dumps(ev, default=str) + "\n")
                    self._sink.flush()
                except Exception:
                    pass

    def events(self, kind: Optional[str] = None,
               limit: Optional[int] = None) -> List[dict]:
        with self._elock:
            out = [dict(e) for e in self._events
                   if kind is None or e["kind"] == kind]
        return out[-limit:] if limit else out

    def close(self) -> None:
        if self._own_sink and self._sink is not None:
            try:
                self._sink.close()
            finally:
                self._sink = None


# -- the process-wide registry ----------------------------------------------
_GLOBAL = Telemetry()


def get_telemetry() -> Telemetry:
    return _GLOBAL


def set_telemetry(telemetry: Telemetry) -> Telemetry:
    """Swap the process-wide registry (tests, the overhead bench's
    disabled baseline).  Returns the new registry."""
    global _GLOBAL
    _GLOBAL = telemetry
    return telemetry
