"""memori-agent — the paper's own serving model for the end-to-end examples:
a small dense LM (~100M class) served behind the MemoriClient SDK and used
by the train_100m example.  (The paper is LLM-agnostic; any zoo config can
take this role — this one is small enough to train/serve on the CI box.)"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="memori-agent",
        arch_type="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32768,
        source="[this paper: Memori serving default]",
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
        long_context_window=4096,
    )
