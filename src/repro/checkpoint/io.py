"""Pytree checkpointing via msgpack (orbax is unavailable offline).

Arrays are stored as (dtype, shape, raw bytes) keyed by tree path; the tree
structure itself is reconstructed against a reference pytree on load, so
loading is shape/dtype-validated.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def _path_key(path) -> str:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(out)


def save(path: str, tree: PyTree, *, atomic: bool = False,
         fsync: bool = False) -> int:
    """Returns bytes written.

    `atomic=True` routes through `checkpoint.wal.atomic_write_bytes`
    (tmp + fsync + rename + directory fsync — one audited implementation
    of the crash-durable write), so readers and crash recovery only ever
    see a complete checkpoint under `path`; it implies `fsync`.  Plain
    `fsync=True` flushes an in-place write to stable storage AND fsyncs
    the parent directory — a freshly created file whose direntry is not
    flushed can vanish wholesale on power loss even though its own fd was
    fsync'd.  The lifecycle runtime's snapshot rotation uses
    `atomic=True`."""
    entries = {}
    def rec(p, leaf):
        arr = np.asarray(leaf)
        entries[_path_key(p)] = {
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
        return leaf
    jax.tree_util.tree_map_with_path(rec, tree)
    blob = msgpack.packb(entries, use_bin_type=True)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    if atomic:
        from repro.checkpoint.wal import atomic_write_bytes
        atomic_write_bytes(path, blob)
        return len(blob)
    from repro.checkpoint import faults
    faults.active().write_file(path, blob, fsync=fsync)
    if fsync:
        faults.active().fsync_dir(os.path.dirname(os.path.abspath(path)))
    return len(blob)


def load_raw(path: str) -> dict:
    """Load a checkpoint as a flat {path_key: np.ndarray} dict without a
    reference pytree.  The entries are self-describing (dtype + shape), so
    this suits consumers whose structure is only known from the checkpoint
    itself (e.g. core/store.py snapshots).  Arrays are writable copies."""
    with open(path, "rb") as f:
        entries = msgpack.unpackb(f.read(), raw=False)
    out = {}
    for key, e in entries.items():
        arr = np.frombuffer(e["data"], dtype=np.dtype(e["dtype"]))
        out[key] = arr.reshape(e["shape"]).copy()
    return out


def load(path: str, like: PyTree) -> PyTree:
    """Load into the structure of `like` (shape/dtype-checked)."""
    with open(path, "rb") as f:
        entries = msgpack.unpackb(f.read(), raw=False)

    def rec(p, leaf):
        key = _path_key(p)
        if key not in entries:
            raise KeyError(f"checkpoint missing {key}")
        e = entries[key]
        arr = np.frombuffer(e["data"], dtype=np.dtype(e["dtype"]))
        arr = arr.reshape(e["shape"])
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: shape {arr.shape} != {want_shape}")
        return jnp.asarray(arr).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(rec, like)
