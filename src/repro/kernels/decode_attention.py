"""Flash-decode: single-token attention against a long KV cache.

Grid: (B, K, num_t_blocks) — cache dim innermost/sequential.  The one query
token (per kv-head group) stays resident in VMEM while (block_t, D) cache
tiles stream from HBM; online-softmax partials merge in VMEM scratch.  This
is the kernel shape that serves decode_32k / long_500k: arithmetic intensity
is O(1) FLOP/byte, so the roofline is HBM-bandwidth-bound and the only thing
that matters is streaming the cache exactly once at full bandwidth.

Valid-length masking comes from a per-batch kv_len operand so one compiled
kernel serves ragged batches (continuous batching in serving/engine.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_s, l_s, acc_s, *,
            block_t: int, num_t_blocks: int, scale: float, window: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    kv_len = len_ref[0]
    t_start = t * block_t

    @pl.when(t_start < kv_len)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bt, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = pos < kv_len
        if window > 0:
            ok = ok & (pos > kv_len - 1 - window)
        s = jnp.where(ok, s, NEG_INF)                  # (G, bt)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_s[...] = l_s[...] * corr + p.sum(-1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_s[...] = acc_s[...] * corr[..., None] + pv
        m_s[...] = m_new

    @pl.when(t == num_t_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_s[...], 1e-37)
        o_ref[0, 0] = (acc_s[...] / l[..., None]).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_len, *, scale=None, window: int = 0,
                     block_t: int = 512, interpret: bool = False):
    """q: (B, K, G, D) one token; k, v: (B, K, T, D); kv_len: (B,) i32
    (#valid cache slots, the new token already written).  -> (B, K, G, D)."""
    B, K, G, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    bt = min(block_t, T)
    Tp = -(-T // bt) * bt
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    nt = Tp // bt

    out = pl.pallas_call(
        functools.partial(_kernel, block_t=bt, num_t_blocks=nt, scale=scale,
                          window=window),
        grid=(B, K, nt),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1,), lambda b, h, t: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, kp, vp, kv_len.astype(jnp.int32))
    return out
